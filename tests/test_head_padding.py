"""TP head padding (§Perf H1): zero-padded q-heads + repeat-kv GQA must be
bit-for-bit equivalent to the logical-head model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (attn_apply, attn_cache_init, attn_decode,
                                    attn_init)


def _cfgs(kv=2):
    cfg0 = dataclasses.replace(get_config("toy-lm", "smoke"),
                               dtype="float32", n_kv_heads=kv)
    return cfg0, dataclasses.replace(cfg0, head_pad=16)


@pytest.mark.parametrize("kv", [1, 2, 4])
def test_padded_attention_matches_logical(key, kv):
    cfg0, cfgp = _cfgs(kv)
    p0, pp = attn_init(key, cfg0), attn_init(key, cfgp)
    np.testing.assert_allclose(
        np.asarray(pp["wq"][:, :cfg0.n_heads]), np.asarray(p0["wq"]))
    assert pp["wq"].shape[1] == 16 and pp["wo"].shape[0] == 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, cfg0.d_model))
    pos = jnp.arange(24)
    y0, k0, _ = attn_apply(p0, x, cfg=cfg0, positions=pos)
    yp, kp, _ = attn_apply(pp, x, cfg=cfgp, positions=pos)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yp), atol=1e-5)
    # caches stay logical-K
    assert k0.shape == kp.shape == (2, 24, kv, cfg0.d_head)


def test_padded_decode_matches_logical(key):
    cfg0, cfgp = _cfgs(kv=2)
    p0, pp = attn_init(key, cfg0), attn_init(key, cfgp)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 1, cfg0.d_model))
    c0 = attn_cache_init(cfg0, 2, 8)
    cp = attn_cache_init(cfgp, 2, 8)
    t = jnp.int32(0)
    y0, _ = attn_decode(p0, x, c0, t, cfg=cfg0)
    yp, _ = attn_decode(pp, x, cp, t, cfg=cfgp)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yp), atol=1e-5)


def test_head_routing_weights_apply_on_logical_heads(key):
    cfg0, cfgp = _cfgs(kv=2)
    pp = attn_init(key, cfgp)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, cfg0.d_model))
    hw = jax.random.uniform(jax.random.fold_in(key, 4),
                            (2, 8, cfg0.n_heads))   # logical H
    y, _, _ = attn_apply(pp, x, cfg=cfgp, positions=jnp.arange(8),
                         head_weights=hw)
    assert y.shape == (2, 8, cfg0.d_model)
    assert not np.isnan(np.asarray(y)).any()
