"""ElasticSpec/ElasticPolicy API: one compiled model, many budgets.

Covers the PR-1 acceptance properties:
  * the policy pytree round-trips through jax.jit without retrace;
  * traced-capacity routing == the old static-capacity routing per budget;
  * budget 1.0 reproduces the frozen teacher exactly (losslessness), even
    with trained LoRA adapters (they gate off at full budget);
  * the legacy ElasticConfig shim maps to identical spec/policy values;
  * ServingEngine honors per-request budgets, and mixed-budget batches
    reproduce per-budget separate runs on one compiled decode step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ElasticConfig, get_config
from repro.core.policy import (FULL_TOPK, ElasticPolicy, ElasticSpec,
                               as_spec_policy, capacity_anneal,
                               policy_from_config, solve_budget,
                               spec_from_config, _active_fraction)
from repro.models import forward, model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32

N_EXPERTS = 4


def _setup(key, **ecfg_kw):
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = ElasticConfig(**ecfg_kw)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def _batch(cfg, b=2, s=24, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32))}


FULL_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
               mha_head_topk=2, mlp_n_experts=N_EXPERTS, mlp_expert_topk=2,
               lora_rank=1)


def test_shim_maps_config_to_identical_spec_policy_values():
    ecfg = ElasticConfig(**FULL_KW, layers="even", distill_loss="rev_kl")
    spec = spec_from_config(ecfg)
    pol = policy_from_config(ecfg)
    assert spec == ElasticSpec(
        mlp_token_routed=True, mha_token_routed=True, mha_head_routed=True,
        mlp_n_experts=N_EXPERTS, expert_routed=True, vlm_routed=False,
        lora_rank=1, layers="even", distill_loss="rev_kl")
    assert pol.mlp_token_capacity == 0.5
    assert pol.mha_token_capacity == 0.5
    assert pol.mha_head_topk == 2
    assert pol.mlp_expert_topk == 2
    assert (pol.vlm_token_capacity, pol.theta, pol.student) == (1.0, 0.5, 1.0)
    # disabled routers map to "all" sentinels
    off = spec_from_config(ElasticConfig(mlp_token_capacity=None,
                                         mha_head_topk=None))
    assert not off.mha_token_routed and not off.mha_head_routed
    assert policy_from_config(ElasticConfig(mha_head_topk=None)
                              ).mha_head_topk == FULL_TOPK
    # the coercion entry point returns the same pair for legacy configs
    s2, p2 = as_spec_policy(ecfg)
    assert s2 == spec and p2 == pol


def test_policy_jit_roundtrip_no_retrace(key):
    cfg, ecfg, params, rp = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    batch = _batch(cfg)

    @jax.jit
    def fwd(rp, batch, policy):
        return forward(params, rp, batch, cfg, spec, mode="train",
                       policy=policy)[0]

    outs = {}
    for b in (0.25, 0.5, 0.75, 1.0):
        pol = ElasticPolicy.uniform(b, n_heads=cfg.n_heads,
                                    n_experts=N_EXPERTS)
        outs[b] = fwd(rp, batch, pol)
    assert fwd._cache_size() == 1, "policy pytree must not retrace"
    # and the budgets genuinely change the computation
    assert float(jnp.abs(outs[0.25] - outs[1.0]).max()) > 1e-3


@pytest.mark.parametrize("budget", [0.25, 0.5, 0.75])
def test_traced_capacity_equals_static_routing(key, budget):
    """One traced graph == the per-budget static (gather) compiles."""
    cfg, ecfg, params, rp = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    batch = _batch(cfg)
    ec = dataclasses.replace(
        ecfg, mlp_token_capacity=budget, mha_token_capacity=budget,
        mha_head_topk=max(1, round(budget * cfg.n_heads)),
        mlp_expert_topk=max(1, round(budget * N_EXPERTS)))
    l_static, _ = forward(params, rp, batch, cfg, ec, mode="train")
    pol = jax.tree.map(jnp.asarray, policy_from_config(ec))
    l_traced, _ = forward(params, rp, batch, cfg, spec, mode="train",
                          policy=pol)
    np.testing.assert_allclose(np.asarray(l_static), np.asarray(l_traced),
                               atol=1e-4)
    # inference threshold path too
    i_static, _ = forward(params, rp, batch, cfg, ec, mode="infer")
    i_traced, _ = forward(params, rp, batch, cfg, spec, mode="infer",
                          policy=pol)
    np.testing.assert_allclose(np.asarray(i_static), np.asarray(i_traced),
                               atol=1e-4)


def test_budget_one_reproduces_frozen_teacher(key):
    cfg, ecfg, params, rp = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    # make the LoRA adapters non-trivial: losslessness must gate them off
    flat, td = jax.tree_util.tree_flatten_with_path(rp)
    flat = [l + 0.1 if "'lora'" in jax.tree_util.keystr(p) else l
            for p, l in flat]
    rp = jax.tree_util.tree_unflatten(td, flat)
    # sanity: the perturbed adapters DO change sub-1 budgets
    batch0 = _batch(cfg)
    t0, _ = forward(params, None, batch0, cfg, None, mode="base")
    p08 = ElasticPolicy.uniform(0.8, n_heads=cfg.n_heads, n_experts=N_EXPERTS)
    s08, _ = forward(params, rp, batch0, cfg, spec, mode="train", policy=p08)
    assert float(jnp.abs(s08 - t0).max()) > 1e-3
    batch = _batch(cfg)
    teacher, _ = forward(params, None, batch, cfg, None, mode="base")
    for pol in (ElasticPolicy.uniform(1.0, n_heads=cfg.n_heads,
                                      n_experts=N_EXPERTS),
                ElasticPolicy.teacher(),       # student flag off
                solve_budget(cfg, spec, 1.0)):
        for mode in ("train", "infer"):
            out, _ = forward(params, rp, batch, cfg, spec, mode=mode,
                             policy=pol)
            np.testing.assert_allclose(np.asarray(out), np.asarray(teacher),
                                       atol=1e-5)


def test_per_layer_policy_schedule(key):
    cfg, ecfg, params, rp = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    batch = _batch(cfg)
    L = cfg.n_layers
    caps = jnp.linspace(0.4, 1.0, L)[:, None]          # (L, 1) schedule
    pol = ElasticPolicy.uniform(1.0, n_heads=cfg.n_heads,
                                n_experts=N_EXPERTS).replace(
        mlp_token_capacity=caps, mha_token_capacity=caps)
    assert pol.has_layer_dim
    assert float(pol.for_layer(0).mlp_token_capacity[0]) == pytest.approx(0.4)
    out, aux = forward(params, rp, batch, cfg, spec, mode="train", policy=pol)
    assert out.shape[-1] == cfg.padded_vocab
    assert 0.4 < float(aux.sel_rate) <= 1.0


def test_budget_solver_monotone_and_lossless_at_one():
    cfg = f32(get_config("toy-lm", "smoke"))
    spec = ElasticSpec(mlp_token_routed=True, mha_token_routed=True,
                       mha_head_routed=True, mlp_n_experts=N_EXPERTS,
                       expert_routed=True)
    fr = [_active_fraction(cfg, spec, s, ctx=1024)
          for s in (0.2, 0.5, 0.8, 1.0)]
    assert fr == sorted(fr) and fr[-1] == pytest.approx(1.0)
    caps = [float(solve_budget(cfg, spec, b).mlp_token_capacity)
            for b in (0.4, 0.6, 0.8)]
    assert caps == sorted(caps)
    full = solve_budget(cfg, spec, 1.0)
    assert float(full.mlp_token_capacity) == 1.0
    assert float(full.mha_head_topk) >= cfg.n_heads
    sched = capacity_anneal(1.0, 0.5, 10)
    assert sched(0) == pytest.approx(1.0)
    assert sched(10) == pytest.approx(0.5)
    assert sched(25) == pytest.approx(0.5)


def test_serving_mixed_budget_batch_matches_separate_runs(key):
    cfg, ecfg, params, rp = _setup(key, **FULL_KW)
    engine = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=4, max_seq=24)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(4)]
    budgets = [0.4, 0.7, 1.0, None]
    mixed = engine.generate([GenRequest(p, 4, budget=b)
                             for p, b in zip(prompts, budgets)])
    for p, b, got in zip(prompts, budgets, mixed):
        sep = engine.generate([GenRequest(p, 4, budget=b)])[0]
        np.testing.assert_array_equal(got, sep)
    # budgets ride the traced policy: exactly one compile each
    assert engine.compile_counts() == {"prefill": 1, "decode": 1}
    # budget 1.0 rows emit the frozen teacher's tokens
    teacher = ServingEngine(params, None, cfg, None, mode="base",
                            batch_size=4, max_seq=24)
    t_out = teacher.generate([GenRequest(prompts[2], 4)])[0]
    np.testing.assert_array_equal(mixed[2], t_out)
