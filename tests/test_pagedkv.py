"""Paged KV cache subsystem: page pool accounting, prefix sharing, chunked
prefill, CoW forks, and preemption-by-page-pressure.

Core acceptance properties:

* The paged engine is TOKEN-FOR-TOKEN identical to the ring engine on a
  mixed-budget staggered workload (greedy and seeded sampling) — the page
  indirection is a memory-layout change, never a numerics change.
* ``compile_counts() == {prefill: 1, decode: 1}`` for ANY mix of prompt
  lengths: chunked prefill collapses the ring engine's per-length prefill
  buckets into one graph.
* Pages are refcounted: prefix-sharing increfs survive until the LAST
  holder frees (cancel / EOS / length), then the pool drains to empty.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.pagedkv import PagePool, n_pages_for, prefix_keys
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32

# dense MLP: paged mode excludes moefied experts (expert-capacity buffers
# depend on the prefill chunking — see ServingEngine._validate_paged)
DENSE_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                mha_head_topk=2, lora_rank=1)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = ElasticConfig(**DENSE_KW)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


@pytest.fixture(scope="module")
def ring(setup):
    cfg, ecfg, params, rp = setup
    return ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=64)


@pytest.fixture(scope="module")
def paged(setup):
    cfg, ecfg, params, rp = setup
    return ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=64,
                         kv_layout="paged", page_size=8)


def _drain(eng, handles):
    while not all(h.done for h in handles):
        if eng.step() == 0:
            raise RuntimeError("engine stalled")


# ------------------------------ pool (unit) ----------------------------------

def test_pool_alloc_free_refcount():
    pool = PagePool(8, page_size=4, n_replicas=2)
    assert pool.pages_per_replica == 4 and pool.usable_per_replica == 3
    # last id of each replica range is the trash page, never allocatable
    assert pool.trash_page(0) == 3 and pool.trash_page(1) == 7
    a = pool.alloc(0, 3)
    assert sorted(a) == [0, 1, 2] and pool.alloc(0, 1) is None
    assert pool.can_alloc(1, 3) and not pool.can_alloc(1, 4)
    b = pool.alloc(1, 2)
    assert all(pool.replica_of(p) == 1 for p in b)
    pool.incref(a[0])
    pool.free(a)                      # a[0] survives at refcount 1
    assert pool.allocated == 3 and pool.n_free(0) == 2
    pool.free([a[0]])
    assert pool.n_free(0) == 3
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([a[0], a[0]])
    st = pool.stats()
    assert st["allocated"] == 2 and st["peak_allocated"] == 5


def test_pool_prefix_registry_purged_on_free():
    pool = PagePool(4, page_size=4)
    [p] = pool.alloc(0, 1)
    pool.register_prefix("k1", p)
    assert pool.lookup_prefix("k1", 0) == p
    assert pool.lookup_prefix("k1", 1) is None   # replica-local lookups
    pool.incref(p)
    pool.free([p])
    assert pool.lookup_prefix("k1", 0) == p      # still held by one ref
    pool.free([p])
    assert pool.lookup_prefix("k1", 0) is None   # last free purges the key
    assert pool.stats()["registered_prefixes"] == 0


def test_prefix_keys_chain_and_namespace():
    toks = list(range(20))
    ks = prefix_keys(toks, 8)
    assert len(ks) == 2                  # only FULL pages get keys
    # chained: a diverging EARLIER block changes every later key
    ks2 = prefix_keys([99] + toks[1:], 8)
    assert ks2[0] != ks[0] and ks2[1] != ks[1]
    # same prefix, later divergence: shared head key, distinct tail key
    ks3 = prefix_keys(toks[:8] + [99] + toks[9:], 8)
    assert ks3[0] == ks[0] and ks3[1] != ks[1]
    # the routing namespace (mode/budget/theta) splits the key space
    assert prefix_keys(toks, 8, namespace=("infer", 0.5, 0.5)) != \
        prefix_keys(toks, 8, namespace=("infer", 1.0, 0.5))
    assert n_pages_for(0, 8) == 0 and n_pages_for(1, 8) == 1 \
        and n_pages_for(8, 8) == 1 and n_pages_for(9, 8) == 2


# ----------------------- engine: parity + compile flatness -------------------

def test_paged_matches_ring_staggered_mixed_budgets(setup, ring, paged):
    """4 distinct prompt lengths, mixed budgets + one sampled row, admitted
    staggered into 2 slots: every output bit-matches the ring engine's solo
    run AND the chunked prefill keeps ONE compile across all lengths."""
    cfg, ecfg, params, rp = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, L, dtype=np.int32)
               for L in (5, 13, 16, 29)]
    reqs = [GenRequest(prompts[0], 6, budget=0.4),
            GenRequest(prompts[1], 6, budget=1.0),
            GenRequest(prompts[2], 6),
            GenRequest(prompts[3], 6, temperature=0.8, top_k=4, seed=11)]
    oracle = [ring.generate([r])[0] for r in reqs]
    h0 = paged.submit(reqs[0])
    paged.step(); paged.step()            # r0 mid-flight when r1 lands
    h1 = paged.submit(reqs[1])
    paged.step()
    h2, h3 = paged.submit(reqs[2]), paged.submit(reqs[3])
    handles = [h0, h1, h2, h3]
    _drain(paged, handles)
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)
    assert paged.compile_counts() == {"prefill": 1, "decode": 1}
    st = paged.paged_stats()
    assert st["allocated"] == 0 and st["free"] == st["usable"]


def test_prefix_sharing_refcounts_and_parity(setup, ring, paged):
    """Two live requests with a common 16-token prefix share its 2 full
    pages physically; outputs still match solo runs; the pool drains to
    zero after both finish (refcounted frees)."""
    cfg, ecfg, params, rp = setup
    rng = np.random.default_rng(1)
    pre = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    a = np.concatenate([pre, rng.integers(0, cfg.vocab_size, 4,
                                          dtype=np.int32)])
    b = np.concatenate([pre, rng.integers(0, cfg.vocab_size, 4,
                                          dtype=np.int32)])
    h1 = paged.submit(GenRequest(a, 4, budget=0.5))
    paged.step()
    h2 = paged.submit(GenRequest(b, 4, budget=0.5))
    paged.step()
    st = paged.paged_stats()
    assert st["shared"] == 2              # 16-token prefix @ page_size 8
    _drain(paged, [h1, h2])
    np.testing.assert_array_equal(
        np.asarray(h1.output), ring.generate([GenRequest(a, 4, budget=0.5)])[0])
    np.testing.assert_array_equal(
        np.asarray(h2.output), ring.generate([GenRequest(b, 4, budget=0.5)])[0])
    assert paged.paged_stats()["allocated"] == 0
    # different budgets must NOT share (namespaced keys: the token gate's
    # keep decisions — hence the page bytes — depend on the solved policy)
    h3 = paged.submit(GenRequest(a, 2, budget=0.5))
    paged.step()
    h4 = paged.submit(GenRequest(a, 2, budget=1.0))
    paged.step()
    assert paged.paged_stats()["shared"] == 0
    _drain(paged, [h3, h4])


def test_cancel_returns_shared_pages(setup, paged):
    cfg, ecfg, params, rp = setup
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, 20, dtype=np.int32)
    h1 = paged.submit(GenRequest(p, 8, budget=0.5))
    paged.step()
    h2 = paged.submit(GenRequest(p, 8, budget=0.5))
    paged.step()
    assert paged.paged_stats()["shared"] == 2
    assert paged.cancel(h1)
    # h2 still holds the shared pages: nothing recycled out from under it
    assert paged.paged_stats()["shared"] == 0
    assert paged.paged_stats()["allocated"] > 0
    assert paged.cancel(h2)
    assert paged.paged_stats()["allocated"] == 0


def test_fork_cow_bit_matches_independent_run(setup, ring, paged):
    """fork() mid-decode: the child shares full history pages, deep-copies
    only the partial tail (CoW), and — greedy — must emit EXACTLY what an
    independent request with prompt + parent-output-so-far emits."""
    cfg, ecfg, params, rp = setup
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, 11, dtype=np.int32)
    hp = paged.submit(GenRequest(p, 10, budget=0.7))
    for _ in range(5):
        paged.step()
    prefix_out = list(hp.output)
    assert 0 < len(prefix_out) < 10
    hc = paged.fork(hp)
    _drain(paged, [hp, hc])
    indep = ring.generate([GenRequest(
        np.concatenate([p, np.asarray(prefix_out, np.int32)]),
        10 - len(prefix_out), budget=0.7)])[0]
    np.testing.assert_array_equal(np.asarray(hc.output), indep)
    # greedy parent continues identically (fork never perturbs the parent)
    np.testing.assert_array_equal(
        np.asarray(hp.output[len(prefix_out):]), indep)
    assert paged.paged_stats()["allocated"] == 0
    with pytest.raises(ValueError, match="running"):
        paged.fork(hp)                    # finished requests cannot fork


def test_preemption_by_page_pressure_resumes_exactly(setup, ring):
    """A pool too small for two full-length requests forces an eviction;
    the preempted request re-queues as a continuation and still emits its
    solo-run tokens (position-keyed sampling + prompt+output re-prefill)."""
    cfg, ecfg, params, rp = setup
    rng = np.random.default_rng(4)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 24, dtype=np.int32),
                       10, budget=0.8) for _ in range(2)]
    oracle = [ring.generate([r])[0] for r in reqs]
    # 8 usable pages + 1 trash; each request needs ceil(34/8) = 5 pages at
    # full length, so both fit initially (3+3) but collide as they grow
    tiny = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                         max_seq=64, kv_layout="paged", page_size=8,
                         n_pages=9)
    handles = [tiny.submit(r) for r in reqs]
    steps = 0
    while not all(h.done for h in handles):
        assert tiny.step() > 0, "stalled"
        steps += 1
        assert steps < 200
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)
    assert tiny.paged_stats()["allocated"] == 0


def test_paged_validation(setup):
    cfg, ecfg, params, rp = setup
    moe = dataclasses.replace(ecfg, mlp_n_experts=4, mlp_expert_topk=2)
    with pytest.raises(ValueError, match="dense MLP"):
        ServingEngine(params, rp, cfg, moe, mode="infer",
                      batch_size=2, max_seq=32, kv_layout="paged")
    with pytest.raises(ValueError, match="kv_layout"):
        ServingEngine(params, rp, cfg, ecfg, batch_size=2, max_seq=32,
                      kv_layout="blocked")
    with pytest.raises(ValueError, match="infer/base"):
        ServingEngine(params, rp, cfg, ecfg, mode="train",
                      batch_size=2, max_seq=32, kv_layout="paged")
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                        max_seq=32, kv_layout="paged", page_size=8,
                        n_pages=4)               # 3 usable + 1 trash
    p = np.arange(30, dtype=np.int32)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(GenRequest(p, 2))             # needs 4 pages > 3 usable


# ------------------------- int8 cache bit-stability --------------------------

def test_int8_fork_and_preemption_bit_stable(setup):
    """int8 KV cache (docs/quantization.md): fork CoW and preemption replay
    are BIT-stable. Rows are quantized once at the write site, so a CoW
    deep-copied tail page and a re-prefilled continuation hold exactly the
    bytes an independent int8 solo run produces — greedy outputs match
    token-for-token across ring/paged layouts and across evictions."""
    cfg, ecfg, params, rp = setup
    kw = dict(mode="infer", batch_size=2, max_seq=64,
              kv_dtype="int8", weight_dtype="int8")
    ring8 = ServingEngine(params, rp, cfg, ecfg, **kw)
    paged8 = ServingEngine(params, rp, cfg, ecfg, kv_layout="paged",
                           page_size=8, **kw)
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, 11, dtype=np.int32)
    # ---- fork mid-decode: child == independent int8 run ----
    hp = paged8.submit(GenRequest(p, 10, budget=0.7))
    for _ in range(5):
        paged8.step()
    prefix_out = list(hp.output)
    assert 0 < len(prefix_out) < 10
    hc = paged8.fork(hp)
    _drain(paged8, [hp, hc])
    indep = ring8.generate([GenRequest(
        np.concatenate([p, np.asarray(prefix_out, np.int32)]),
        10 - len(prefix_out), budget=0.7)])[0]
    np.testing.assert_array_equal(np.asarray(hc.output), indep)
    np.testing.assert_array_equal(
        np.asarray(hp.output[len(prefix_out):]), indep)
    assert paged8.paged_stats()["allocated"] == 0
    # ---- preemption under page pressure: replay == solo int8 run ----
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 24, dtype=np.int32),
                       10, budget=0.8) for _ in range(2)]
    oracle = [ring8.generate([r])[0] for r in reqs]
    tiny = ServingEngine(params, rp, cfg, ecfg, kv_layout="paged",
                         page_size=8, n_pages=9, **kw)
    handles = [tiny.submit(r) for r in reqs]
    steps = 0
    while not all(h.done for h in handles):
        assert tiny.step() > 0, "stalled"
        steps += 1
        assert steps < 200
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)
    assert tiny.paged_stats()["allocated"] == 0
