"""Fault tolerance: watchdog EWMA straggler detection, run_resilient's
bitwise checkpoint replay, FailureInjector determinism — and the serving
twin, serve_resilient, which drains + re-meshes a live ServingEngine on a
replica failure instead of killing the server (promised by
runtime/fault_tolerance.py's docstring; asserted here)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.controller import SLOController
from repro.runtime.fault_tolerance import (FailureInjector, SimulatedFailure,
                                           StragglerWatchdog, maybe_escalate,
                                           run_resilient, serve_resilient)
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32


# ------------------------------ watchdog -------------------------------------

def test_watchdog_flags_slow_step_and_excludes_it_from_ewma():
    wd = StragglerWatchdog(threshold=2.0, decay=0.5)
    assert not wd.observe(0, 1.0)          # no EWMA yet: never flags
    assert not wd.observe(1, 1.5)          # 1.5 < 2.0 * 1.0
    assert wd.observe(2, 10.0)             # >> threshold * ewma: flagged
    assert [s for s, _, _ in wd.flagged] == [2]
    # the flagged sample is EXCLUDED from the baseline: ewma still tracks
    # what a healthy step costs
    assert wd.ewma == pytest.approx(0.5 * 1.0 + 0.5 * 1.5)
    # a healthy follow-up folds in normally
    assert not wd.observe(3, 1.25)
    assert wd.ewma == pytest.approx(0.5 * 1.25 + 0.5 * 1.25)


def test_watchdog_keeps_flagging_sustained_slowdown():
    """Regression for the EWMA-inflation bug: when flagged samples fed the
    EWMA, each flagged step multiplied the baseline by up to
    decay + (1-decay)*threshold, so a PERSISTENT straggler re-based the
    watchdog to the degraded speed and stopped being flagged after a
    handful of steps. Flagged samples must not move the baseline: a
    replica stuck at 10x cost is flagged on every single step."""
    wd = StragglerWatchdog(threshold=2.5, decay=0.9)
    for step in range(20):
        assert not wd.observe(step, 1.0)
    baseline = wd.ewma
    for step in range(20, 40):             # sustained 10x slowdown
        assert wd.observe(step, 10.0), f"stopped flagging at step {step}"
    assert wd.ewma == pytest.approx(baseline)   # baseline never inflated
    assert len(wd.flagged) == 20


# ----------------------- deterministic failure injection ----------------------

def test_failure_injector_fires_once_per_step():
    inj = FailureInjector(at_steps=(1, 3))
    inj.maybe_fail(0)
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail(1)
    inj.maybe_fail(1)                      # replay of step 1: no re-fire
    with pytest.raises(SimulatedFailure):
        inj.maybe_fail(3)
    assert inj.fired == {1, 3}


# --------------------------- resilient training loop -------------------------

def _toy_training(injector=None, watchdog=None, save_every=2):
    """A deterministic stand-in training loop: the 'model' state is a float
    vector evolved by a step-indexed update (the pipeline.batch_at contract
    — data depends only on the step), checkpoints are host snapshots."""
    state = {"w": np.arange(4, dtype=np.float64)}
    ckpt = {"step": 0, "w": state["w"].copy()}

    def do_step(step):
        rng = np.random.default_rng(step)            # deterministic data
        state["w"] = state["w"] * 1.25 + rng.normal(size=4)
        return {"step": step, "w": state["w"].copy()}

    def save(step):
        ckpt["step"], ckpt["w"] = step, state["w"].copy()

    def restore():
        state["w"] = ckpt["w"].copy()
        return ckpt["step"]

    metrics, restarts = run_resilient(
        start_step=0, total_steps=7, do_step=do_step, save=save,
        restore=restore, save_every=save_every, injector=injector,
        watchdog=watchdog)
    return state["w"], restarts


def test_run_resilient_replays_bitwise_after_failures():
    clean, r0 = _toy_training()
    assert r0 == 0
    # failures mid-interval AND on a would-be-checkpoint step: every replay
    # restores the latest checkpoint and re-runs the same step-indexed data,
    # so the final weights are BITWISE identical to the clean run
    faulty, r1 = _toy_training(injector=FailureInjector(at_steps=(3, 4, 6)))
    assert r1 == 3
    np.testing.assert_array_equal(clean, faulty)


def test_run_resilient_gives_up_after_max_restarts():
    def do_step(step):
        raise SimulatedFailure("permanently broken")

    with pytest.raises(SimulatedFailure):
        run_resilient(start_step=0, total_steps=3, do_step=do_step,
                      save=lambda s: None, restore=lambda: 0,
                      max_restarts=2)


# ---------------------------- resilient serving ------------------------------

def _serving_setup(key):
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                         mha_head_topk=2, mlp_n_experts=4, mlp_expert_topk=2,
                         lora_rank=1)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def test_serve_resilient_drains_and_remeshes_on_replica_failure(key):
    """A step failure mid-serve re-meshes the live engine (here onto the
    trivial 1x1 mesh — same reshard path the multi-device test exercises at
    2x4 -> 1x4) and every in-flight request resumes with identical tokens
    instead of the failure killing the server."""
    cfg, ecfg, params, rp = _serving_setup(key)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]
    reqs = [GenRequest(prompts[0], 8, budget=0.5),
            GenRequest(prompts[1], 8, budget=1.0),
            GenRequest(prompts[2], 8)]
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    oracle = [solo.generate([r])[0] for r in reqs]

    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    handles = [eng.submit(r) for r in reqs]
    wd = StragglerWatchdog()
    # first fallback shape needs 4096 devices (a "lost hosts" shape that no
    # longer fits): it must be SKIPPED, not kill the server
    steps, restarts = serve_resilient(
        eng, fallback_shapes=[(64, 64), (1, 1)], max_restarts=2,
        injector=FailureInjector(at_steps=(2,)), watchdog=wd)
    assert restarts == 1 and steps > 0
    assert eng.mesh is not None and dict(eng.mesh.shape) == {"data": 1,
                                                             "model": 1}
    assert all(h.done and h.finish_reason == "length" for h in handles)
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)


def test_serve_resilient_exhausts_restarts(key):
    cfg, ecfg, params, rp = _serving_setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=1, max_seq=16)
    eng.submit(GenRequest(np.arange(4, dtype=np.int32), 4))
    with pytest.raises(SimulatedFailure):
        serve_resilient(eng, max_restarts=1,
                        injector=FailureInjector(at_steps=(0, 1, 2)))


# ------------------- controller saturation -> remesh escalation ---------------

def _saturated_controller():
    """A controller already degraded to its floor and one eval away from
    asking for a remesh."""
    c = SLOController(floor=0.25, escalate_after=1, eval_interval_s=0.0)
    c.admission_budget = c.depth_budget = c.inflight_budget = 0.25
    return c


def test_maybe_escalate_remeshes_ring_engine(key):
    cfg, ecfg, params, rp = _serving_setup(key)
    ctrl = _saturated_controller()
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24, controller=ctrl)
    out = ctrl.update(1.0, queue_depth=100, capacity=2)
    assert out["escalate"] and ctrl.should_escalate
    shapes = [(64, 64), (1, 1)]          # unusable shape must be skipped
    assert maybe_escalate(eng, shapes)
    assert dict(eng.mesh.shape) == {"data": 1, "model": 1}
    assert not ctrl.should_escalate      # latch re-armed after handling
    assert shapes == []                  # consumed (unusable one dropped)


def test_maybe_escalate_declines_without_shapes_or_ring(key):
    cfg, ecfg, params, rp = _serving_setup(key)
    ctrl = _saturated_controller()
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24, controller=ctrl)
    ctrl.update(1.0, queue_depth=100, capacity=2)
    assert not maybe_escalate(eng, [])   # nothing to remesh onto
    # declining still re-arms the latch: the ask must not re-fire forever
    assert not ctrl.should_escalate


# ---------------------- replica-failure drill mid-burst -----------------------

def test_replica_failure_mid_burst_loses_no_inflight_requests(key):
    """The acceptance drill: a replica failure in the middle of a burst
    drains + re-meshes the live engine and EVERY submitted request still
    finishes with its full token budget — zero lost in-flight requests,
    tokens identical to a fault-free oracle."""
    from benchmarks.workloads import replay

    cfg, ecfg, params, rp = _serving_setup(key)
    rng = np.random.default_rng(13)
    # one prompt length: the ring engine compiles per plen, keep it to one
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                       6, budget=(0.5, 1.0)[i % 2], seed=i)
            for i in range(6)]
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    oracle = [solo.generate([r])[0] for r in reqs]

    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    arrive = np.arange(len(reqs)) * 1e-3          # burst: all near t=0
    handles, _dt, info = replay(
        eng, reqs, arrive, fallback_shapes=[(1, 1)],
        injector=FailureInjector(at_steps=(3,)),
        watchdog=StragglerWatchdog())
    assert info["restarts"] == 1
    assert all(h is not None and h.status == "done" for h in handles)
    assert all(h.finish_reason == "length" for h in handles)
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)
