"""Serving engine: batched generation, base-vs-elastic modes, greedy
consistency with the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_elastic
from repro.models import forward, model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32


def _setup(key, arch="toy-lm"):
    cfg = f32(get_config(arch, "smoke"))
    ecfg = get_elastic(arch, cfg)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def test_greedy_generation_matches_forward_rollout(key):
    cfg, ecfg, params, rp = _setup(key)
    engine = ServingEngine(params, rp, cfg, ecfg, mode="base",
                           batch_size=2, max_seq=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
               for _ in range(2)]
    outs = engine.generate([GenRequest(p, 8) for p in prompts])
    # oracle: repeated full forward + argmax
    for p, got in zip(prompts, outs):
        toks = list(p)
        for _ in range(8):
            logits, _ = forward(params, None,
                                {"tokens": jnp.asarray([toks])}, cfg, None,
                                mode="base")
            toks.append(int(jnp.argmax(logits[0, -1])))
        np.testing.assert_array_equal(got, np.asarray(toks[len(p):]))


def test_elastic_mode_changes_compute_path(key):
    cfg, ecfg, params, rp = _setup(key)
    e1 = ServingEngine(params, rp, cfg, ecfg, mode="base", batch_size=2,
                       max_seq=32)
    e2 = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                       max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 8)
            for _ in range(2)]
    a = e1.generate(reqs)
    b = e2.generate(reqs)
    assert all(len(x) == 8 for x in a + b)
    # untrained routers: outputs may differ, but must be valid token ids
    assert all((x >= 0).all() and (x < cfg.padded_vocab).all() for x in b)


def test_vlm_serving_with_image_context(key):
    cfg, ecfg, params, rp = _setup(key, "toy-vlm")
    engine = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=2, max_seq=32)
    rng = np.random.default_rng(2)
    img = jnp.asarray(rng.normal(size=(2, cfg.n_image_tokens,
                                       cfg.d_frontend)).astype(np.float32))
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 4)
            for _ in range(2)]
    outs = engine.generate(reqs, extra_inputs={"image_embeds": img})
    assert all(len(o) == 4 for o in outs)
