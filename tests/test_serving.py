"""Serving engine: batched generation, base-vs-elastic modes, greedy
consistency with the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_elastic
from repro.models import forward, model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32


def _setup(key, arch="toy-lm"):
    cfg = f32(get_config(arch, "smoke"))
    ecfg = get_elastic(arch, cfg)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def test_greedy_generation_matches_forward_rollout(key):
    cfg, ecfg, params, rp = _setup(key)
    engine = ServingEngine(params, rp, cfg, ecfg, mode="base",
                           batch_size=2, max_seq=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
               for _ in range(2)]
    outs = engine.generate([GenRequest(p, 8) for p in prompts])
    # oracle: repeated full forward + argmax
    for p, got in zip(prompts, outs):
        toks = list(p)
        for _ in range(8):
            logits, _ = forward(params, None,
                                {"tokens": jnp.asarray([toks])}, cfg, None,
                                mode="base")
            toks.append(int(jnp.argmax(logits[0, -1])))
        np.testing.assert_array_equal(got, np.asarray(toks[len(p):]))


def test_elastic_mode_changes_compute_path(key):
    cfg, ecfg, params, rp = _setup(key)
    e1 = ServingEngine(params, rp, cfg, ecfg, mode="base", batch_size=2,
                       max_seq=32)
    e2 = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                       max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 8)
            for _ in range(2)]
    a = e1.generate(reqs)
    b = e2.generate(reqs)
    assert all(len(x) == 8 for x in a + b)
    # untrained routers: outputs may differ, but must be valid token ids
    assert all((x >= 0).all() and (x < cfg.padded_vocab).all() for x in b)


def test_vlm_serving_with_image_context(key):
    cfg, ecfg, params, rp = _setup(key, "toy-vlm")
    engine = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=2, max_seq=32)
    rng = np.random.default_rng(2)
    img = jnp.asarray(rng.normal(size=(2, cfg.n_image_tokens,
                                       cfg.d_frontend)).astype(np.float32))
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 4)
            for _ in range(2)]
    outs = engine.generate(reqs, extra_inputs={"image_embeds": img})
    assert all(len(o) == 4 for o in outs)


def test_entry_points_donate_and_stay_compile_flat(key):
    """The jitted admit/decode graphs must (a) alias every declared-donated
    buffer in their lowerings, (b) actually consume donated inputs at run
    time, and (c) keep compile_counts at {prefill: 1, decode: 1} across a
    mixed-budget/-temperature workload (donation must not retrace)."""
    cfg, ecfg, params, rp = _setup(key)
    engine = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=2, max_seq=32)
    eps = engine.entry_points()
    for name, ep in eps.items():
        n_donated = sum(len(jax.tree.leaves(ep.args[i]))
                        for i in ep.donated)
        txt = ep.fn.lower(*ep.args, **ep.static).as_text()
        assert txt.count("tf.aliasing_output") == n_donated, \
            (name, n_donated, txt.count("tf.aliasing_output"))
    # run-time donation: a sacrificial copy of the decode args dies
    ep = eps["decode"]
    copies = tuple(jax.tree.map(jnp.copy, a) for a in ep.args)
    jax.block_until_ready(ep.fn(*copies, **ep.static))
    for i in ep.donated:
        assert all(leaf.is_deleted()
                   for leaf in jax.tree.leaves(copies[i])), i
    # compile flatness over budgets/temps/seeds (engine state is fresh —
    # the copies above were sacrificial, not the engine's live caches)
    rng = np.random.default_rng(3)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 4,
                       budget=b, temperature=t, top_k=k, seed=s)
            for b, t, k, s in [(0.4, 0.0, 0, 0), (1.0, 0.7, 3, 9)]]
    outs = engine.generate(reqs)
    assert all(len(o) == 4 for o in outs)
    assert engine.compile_counts() == {"prefill": 1, "decode": 1}
