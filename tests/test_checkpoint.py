"""Checkpointer: atomic async saves, checksum verification, keep-N, restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(key, scale=1.0):
    return {"a": jax.random.normal(key, (8, 8)) * scale,
            "b": {"c": jnp.arange(5, dtype=jnp.float32) * scale}}


def test_roundtrip(tmp_path, key):
    ck = Checkpointer(str(tmp_path))
    t = _tree(key)
    ck.save(3, t, extra={"step": 3}, blocking=True)
    assert ck.latest_step() == 3
    got, extra = ck.restore(3, jax.tree.map(jnp.zeros_like, t))
    assert extra == {"step": 3}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_gc(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(key, s), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_corruption_detected(tmp_path, key):
    ck = Checkpointer(str(tmp_path))
    t = _tree(key)
    ck.save(1, t, blocking=True)
    man = os.path.join(str(tmp_path), "step_0000000001", "manifest.json")
    m = json.load(open(man))
    k = next(iter(m["checksums"]))
    m["checksums"][k] += 1
    json.dump(m, open(man, "w"))
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, t)


def test_async_save_nonblocking_and_latest_wins(tmp_path, key):
    ck = Checkpointer(str(tmp_path), keep=5)
    for s in range(5):
        ck.save(s, _tree(key, float(s)))   # async
    ck.wait()
    got, _ = ck.restore(4, _tree(key))
    np.testing.assert_allclose(np.asarray(got["b"]["c"]),
                               np.arange(5, dtype=np.float32) * 4.0)


def test_restore_onto_sharding(tmp_path, key):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    t = _tree(key)
    ck.save(7, t, blocking=True)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    got, _ = ck.restore(7, t, shardings=sh)
    assert got["a"].sharding == NamedSharding(mesh, P())
