"""HLO text profiler: shape parsing and aggregation on a synthetic module."""
from repro.launch.hloprof import (biggest_tensors, profile_text, shape_bytes,
                                  top_table)

HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,4096,3584] parameter(0)
  %c = bf16[128,128] constant({...})
  %dot = bf16[16,4096,4096] dot(%p0, %p0), contracting_dims={2}
  %ar = f32[16,4096] all-reduce(%p0), replica_groups={}
  %gte = f32[16] get-tuple-element(%ar), index=0
  ROOT %conv = f32[16,4096,4096] convert(%dot)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[16,4096,3584]") == 16 * 4096 * 3584 * 4
    assert shape_bytes("bf16[128,128]") == 128 * 128 * 2
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16


def test_profile_skips_bookkeeping_ops():
    prof = profile_text(HLO)
    assert "parameter" not in prof
    assert "get-tuple-element" not in prof
    assert prof["dot"]["count"] == 1
    assert prof["dot"]["bytes"] == 16 * 4096 * 4096 * 2
    assert prof["all-reduce"]["count"] == 1


def test_biggest_tensors_sorted_desc():
    top = biggest_tensors(HLO, n=3)
    assert top[0][0] >= top[1][0] >= top[2][0]
    assert top[0][1] == "convert"          # f32[16,4096,4096] is largest


def test_top_table_renders():
    out = top_table(profile_text(HLO))
    assert "dot" in out and "TOTAL" in out
