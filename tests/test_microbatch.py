"""Gradient accumulation (microbatch) must match the single-shot step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_elastic
from repro.models import model_init, router_init
from repro.optim import cosine_schedule
from repro.training import init_train_state, make_train_step
from tests.conftest import f32


def test_microbatch_matches_full_batch(key):
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = get_elastic("toy-lm", cfg)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    batch = {"tokens": jax.random.randint(jax.random.fold_in(key, 2),
                                          (8, 32), 0, cfg.vocab_size)}
    lr = cosine_schedule(1e-3, 10)
    s1 = init_train_state(rp)
    s4 = init_train_state(rp)
    step1 = jax.jit(make_train_step(cfg, ecfg, lr=lr, chunked=True))
    step4 = jax.jit(make_train_step(cfg, ecfg, lr=lr, chunked=True,
                                    microbatch=4))
    s1, m1 = step1(s1, params, batch)
    s4, m4 = step4(s4, params, batch)
    # losses: microbatch averages per-slice losses; the distill KL is a
    # per-token mean so slicing changes only softmax-batch statistics -> the
    # values agree closely but not bitwise (top-k sets per slice differ).
    assert abs(m1["loss"] - m4["loss"]) / (abs(m1["loss"]) + 1e-6) < 0.05
    # router updates must be close (same direction, similar magnitude)
    g1 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s1.router_params)])
    g4 = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s4.router_params)])
    cos = float(jnp.sum(g1 * g4) /
                (jnp.linalg.norm(g1) * jnp.linalg.norm(g4) + 1e-9))
    assert cos > 0.99, cos
