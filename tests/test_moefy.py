"""Losslessness of the dense-MLP -> MoE block decomposition (paper §4.1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.moefy import moefy_mlp, unmoefy_mlp
from repro.models.layers import mlp_apply, mlp_init
from repro.models.moe import moe_apply


def _dense_params(key, d=32, f=64, gated=True):
    cfg = dataclasses.replace(get_config("toy-lm"), d_model=d, d_ff=f,
                              act="swiglu" if gated else "gelu",
                              dtype="float32")
    return mlp_init(key, cfg), cfg


def test_moefy_roundtrip(key):
    p, _ = _dense_params(key)
    back = unmoefy_mlp(moefy_mlp(p, 4))
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(back[k]))


def test_moefied_all_experts_equals_dense(key):
    """Block decomposition with all experts selected at weight 1 must equal
    the dense MLP bit-for-bit in f32 (the paper's normalization guarantee)."""
    p, cfg = _dense_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y_dense = mlp_apply(p, x, cfg.act)
    m = 4
    ep = moefy_mlp(p, m)
    router_w = jnp.zeros((cfg.d_model, m))   # uniform -> weights all 1
    y_moe, _ = moe_apply(ep, x, act=cfg.act, top_k=m, router_w=router_w,
                         normalize_to_m=True, capacity_factor=float(m),
                         seq_chunk=8)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_moe),
                               atol=1e-5)


def test_moefied_topk_is_subset_compute(key):
    """With k < M the moefied module output is the weighted sum of the
    selected experts only."""
    p, cfg = _dense_params(key)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, cfg.d_model))
    m = 4
    ep = moefy_mlp(p, m)
    router_w = jax.random.normal(jax.random.fold_in(key, 3),
                                 (cfg.d_model, m))
    y, _ = moe_apply(ep, x, act=cfg.act, top_k=2, router_w=router_w,
                     normalize_to_m=True, capacity_factor=4.0, seq_chunk=4)
    # manual: per-token top-2 experts, weighted
    logits = x @ router_w
    w = jax.nn.softmax(logits, -1) * m
    kth = jnp.sort(w, -1)[..., -2:-1]
    mask = w >= kth
    want = jnp.zeros_like(x)
    for e in range(m):
        he = x @ ep["wi"][e]
        ge = jax.nn.silu(x @ ep["wg"][e])
        ye = (ge * he) @ ep["wo"][e]
        want = want + ye * (w[..., e:e + 1] * mask[..., e:e + 1])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)
