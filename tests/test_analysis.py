"""repro.analysis: each rule fires on a golden *violating* fixture, stays
silent on the fixed twin, and the real repo graphs lint clean end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import (Finding, Report, Waiver, build_bundle, donation,
                            dtype_lint, host_sync, pallas_lint, retrace,
                            run_all, sharding_lint)
from repro.analysis.graphs import GraphBundle
from repro.training.serve import EntryPoint


def _mini(entries: dict) -> GraphBundle:
    """A bundle whose entry points are injected test fixtures."""
    return GraphBundle(None, None, None, None, None, _entries=dict(entries))


def _rules(finds):
    return {f.rule for f in finds}


# ------------------------------ retrace --------------------------------------

def test_retrace_flags_value_baked_static_scalar():
    bad = EntryPoint(jax.jit(lambda c, x: x * c, static_argnums=0),
                     (2, jnp.ones((4,), jnp.float32)), {})
    b = _mini({"bad": bad})
    assert _rules(retrace._value_dep(b, "bad")) == {"RETRACE-VALUE-DEP"}

    ok = EntryPoint(jax.jit(lambda c, x: x * c),
                    (jnp.float32(2), jnp.ones((4,), jnp.float32)), {})
    assert retrace._value_dep(_mini({"ok": ok}), "ok") == []


def test_retrace_arg_hygiene_rules():
    ep = EntryPoint(None, (jnp.asarray(0.5),        # weak-typed leaf
                           3,                        # raw Python scalar
                           jnp.int32(1)), {"bucket": [1, 2]})  # unhashable
    rules = _rules(retrace._lint_args("x", ep))
    assert rules == {"RETRACE-WEAK-TYPE", "RETRACE-PY-SCALAR",
                     "RETRACE-STATIC-UNHASHABLE"}
    clean = EntryPoint(None, (jnp.float32(0.5), jnp.int32(3)), {"bucket": 2})
    assert retrace._lint_args("x", clean) == []


# ------------------------------ sharding -------------------------------------

def _scatter_fixture(pin: bool):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def write(cache, rows, new):
        out = cache.at[jnp.arange(2)[:, None], rows].set(new)
        if pin:
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P()))
        return out

    return EntryPoint(write, (jnp.zeros((2, 16, 8), jnp.float32),
                              jnp.zeros((2, 3), jnp.int32),
                              jnp.ones((2, 3, 8), jnp.float32)), {})


def test_sharding_flags_unpinned_cache_scatter():
    finds = sharding_lint._cache_writes(
        _mini({"w": _scatter_fixture(pin=False)}), "w")
    assert _rules(finds) == {"SHARD-CACHE-WRITE"}
    assert sharding_lint._cache_writes(
        _mini({"w": _scatter_fixture(pin=True)}), "w") == []


def _paged_write_fixture(pin: bool):
    """A paged-pool append: per-slot scatter of one (K, Dh) row into the
    (n_pages, page_size, K, Dh) float pool at a dynamic (page, offset).
    The bool pvalid occupancy write rides along and — since the depth
    router made it a per-step scatter target — needs its own pin (the
    rank-2 branch of constrain_page_pool). Only the int32 page-TABLE
    update stays below SHARD-CACHE-WRITE's radar (integer bookkeeping;
    replication is cheap, pinning would add collectives)."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def append(pool, pvalid, table, pages, offs, new, ent):
        out = pool.at[pages, offs].set(new)
        pv = pvalid.at[pages, offs].set(True)
        if pin:
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P(("data",), None, "model", None)))
            pv = jax.lax.with_sharding_constraint(
                pv, NamedSharding(mesh, P(("data",), None)))
        tb = table.at[jnp.arange(2), 1].set(ent)   # int32 table: exempt
        return out, pv, tb

    return EntryPoint(append, (jnp.zeros((16, 8, 4, 32), jnp.float32),
                               jnp.zeros((16, 8), bool),
                               jnp.full((2, 4), -1, jnp.int32),
                               jnp.zeros((2,), jnp.int32),
                               jnp.zeros((2,), jnp.int32),
                               jnp.ones((2, 4, 32), jnp.float32),
                               jnp.zeros((2,), jnp.int32)), {})


def test_sharding_flags_unpinned_page_pool_write():
    """The paged-KV append pattern: the FLOAT pool scatter and the bool
    pvalid occupancy scatter must both be pinned (two findings when they
    are not); the int32 page-table scatter never fires regardless."""
    finds = sharding_lint._cache_writes(
        _mini({"w": _paged_write_fixture(pin=False)}), "w")
    assert _rules(finds) == {"SHARD-CACHE-WRITE"}
    assert len(finds) == 2               # pool + pvalid; table stays silent
    assert sharding_lint._cache_writes(
        _mini({"w": _paged_write_fixture(pin=True)}), "w") == []


def _mask_scatter_fixture(pin: bool):
    """The ring KV-validity mask write the depth router performs each
    decode step: a batch-indexed scatter of per-slot bits into the
    long-lived (B, S) bool `valid` ring. Unpinned, GSPMD replicates the
    whole bitmap per step — constrain_kv_mask exists to prevent exactly
    this. The int32 `pos` ring update rides along (rank-1: exempt)."""
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def write(valid, pos, bits):
        bi = jnp.arange(2)
        out = valid.at[bi, pos].set(bits)
        if pin:
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P(("data",), None)))
        np_ = pos.at[bi].set(pos + 1)              # int32 rank-1: exempt
        return out, np_

    return EntryPoint(write, (jnp.zeros((2, 16), bool),
                              jnp.zeros((2,), jnp.int32),
                              jnp.ones((2,), bool)), {})


def test_sharding_flags_unpinned_mask_scatter():
    """Golden fixture for the depth router's mask-leaf write sites: an
    unpinned batch-indexed scatter into the (B, S) bool validity ring is
    flagged; the constrain_kv_mask-style pinned twin is silent, and the
    pos bookkeeping write never fires."""
    finds = sharding_lint._cache_writes(
        _mini({"w": _mask_scatter_fixture(pin=False)}), "w")
    assert _rules(finds) == {"SHARD-CACHE-WRITE"}
    assert len(finds) == 1               # pos stays silent
    assert sharding_lint._cache_writes(
        _mini({"w": _mask_scatter_fixture(pin=True)}), "w") == []


# ------------------------------ host sync ------------------------------------

def test_host_sync_flags_callbacks_and_numpy_operands():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1

    ep = EntryPoint(f, (jnp.ones((4,)),), {})
    assert _rules(host_sync._callbacks(_mini({"f": ep}), "f")) \
        == {"HOST-CALLBACK"}

    np_ep = EntryPoint(None, (np.zeros((3,), np.float32),), {})
    assert _rules(host_sync._host_operands("g", np_ep)) == {"HOST-OPERAND"}
    dev_ep = EntryPoint(None, (jnp.zeros((3,), jnp.float32),), {})
    assert host_sync._host_operands("g", dev_ep) == []


# ------------------------------ donation -------------------------------------

def test_donation_flags_undonated_buffer():
    args = (jnp.ones((8,), jnp.float32), jnp.ones((8,), jnp.float32))
    # "train" name: GraphBundle.fresh_entry serves it straight from entries
    bad = EntryPoint(jax.jit(lambda a, b: (a + 1.0, b)), args, {},
                     donated=(0,))
    b = _mini({"train": bad})
    assert _rules(donation._static_check(b, "train")) == {"DONATE-MISSING"}
    assert _rules(donation._functional_check(b, "train")) == {"DONATE-DEAD"}

    good = EntryPoint(jax.jit(lambda a, b: (a + 1.0, b), donate_argnums=(0,)),
                      args, {}, donated=(0,))
    g = _mini({"train": good})
    assert donation._static_check(g, "train") == []
    assert donation._functional_check(g, "train") == []


# ------------------------------ dtype ----------------------------------------

def test_dtype_flags_large_bf16_upcast():
    def f(x):
        return x.astype(jnp.float32) + 1.0

    ep = EntryPoint(f, (jnp.zeros((512, 512), jnp.bfloat16),), {})
    assert _rules(dtype_lint._findings_for(_mini({"f": ep}), "f")) \
        == {"DTYPE-UPCAST"}
    # small upcasts (kernel-style scalars/reductions) stay silent
    small = EntryPoint(f, (jnp.zeros((8, 8), jnp.bfloat16),), {})
    assert dtype_lint._findings_for(_mini({"f": small}), "f") == []


def test_dtype_flags_quantized_hbm_dequant():
    """DTYPE-QUANT-HBM: a LARGE int8 -> f32 convert in a serve graph means
    a quantized cache/weight was dequantized OUTSIDE the kernels — HBM sees
    the f32 copy, forfeiting the bandwidth win. Small converts stay silent,
    train is exempt (fp32 masters), and the same convert INSIDE a
    pallas_call body (the fused-dequant pattern) never fires: the walker
    skips kernel sub-jaxprs, which IS the allowlist."""
    def f(q, s):
        return q.astype(jnp.float32) * s

    big = (jnp.zeros((512, 512), jnp.int8), jnp.ones((), jnp.float32))
    ep = EntryPoint(f, big, {})
    assert _rules(dtype_lint._findings_for(_mini({"f": ep}), "f")) \
        == {"DTYPE-QUANT-HBM"}
    assert dtype_lint._findings_for(_mini({"train": ep}), "train") == []
    small = EntryPoint(
        f, (jnp.zeros((8, 8), jnp.int8), jnp.ones((), jnp.float32)), {})
    assert dtype_lint._findings_for(_mini({"f": small}), "f") == []

    import jax.experimental.pallas as pl

    def kern(q_ref, s_ref, o_ref):
        o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]

    def fused(q, s):
        return pl.pallas_call(kern, out_shape=jax.ShapeDtypeStruct(
            (512, 512), jnp.float32), interpret=True)(q, s)

    inside = EntryPoint(fused, (jnp.zeros((512, 512), jnp.int8),
                                jnp.ones((512, 512), jnp.float32)), {})
    assert dtype_lint._findings_for(_mini({"k": inside}), "k") == []


# ------------------------------ pallas ---------------------------------------

def _rec(grid, block, shape, index_map, args=(), nsp_spec=None):
    import jax.experimental.pallas as pl
    kw = {"grid": grid,
          "in_specs": [pl.BlockSpec(block, index_map)],
          "out_specs": None,
          "out_shape": jax.ShapeDtypeStruct(shape, jnp.float32)}
    return {"kwargs": kw, "args": args or (jnp.zeros(shape, jnp.float32),)}


def test_pallas_flags_out_of_bounds_index_map():
    # grid runs to 4 but a (256,) operand only has cdiv(256,128)=2 blocks
    finds = pallas_lint.verify_record(
        "k", _rec((4,), (128,), (256,), lambda i: (i,)))
    assert "PAL-OOB" in _rules(finds)
    assert pallas_lint.verify_record(
        "k", _rec((2,), (128,), (256,), lambda i: (i,))) == []


def test_pallas_flags_misaligned_tile():
    finds = pallas_lint.verify_record(
        "k", _rec((2,), (100,), (200,), lambda i: (i,)))
    assert "PAL-ALIGN" in _rules(finds)


def test_pallas_flags_unprefetched_control_vector():
    finds = pallas_lint.verify_record(
        "k", _rec((2,), (1, 128), (2, 128), lambda i: (i, 0),
                  args=(jnp.zeros((2,), jnp.int32),)))
    assert "PAL-PREFETCH" in _rules(finds)


# ------------------------------ waivers / report -----------------------------

def test_waivers_silence_but_still_report():
    r = Report()
    finds = [Finding("RULE-A", "serve.decode", "boom"),
             Finding("RULE-B", "kernels.moe_gmm", "bang")]
    r.extend("p", finds, [Waiver.parse("RULE-A:serve.*")])
    assert [f.rule for f in r.findings] == ["RULE-B"]
    assert [f.rule for f in r.waived] == ["RULE-A"]
    assert not r.ok
    r2 = Report()
    r2.extend("p", finds, [Waiver("RULE-A"), Waiver("RULE-B")])
    assert r2.ok and len(r2.waived) == 2
    assert "2 waived" in r2.table()


# ------------------------------ the real repo --------------------------------

@pytest.mark.slow
def test_repo_graphs_lint_clean():
    """The shipped serving/training graphs and kernels produce ZERO
    findings — the exact gate the lint-graphs CI job enforces."""
    report = run_all(build_bundle(mesh_shape=(1, 1)))  # the CLI default
    assert report.ok and not report.findings, report.table(verbose=True)
    assert set(report.passes) == {"retrace", "sharding", "host_sync",
                                  "donation", "dtype", "pallas"}
