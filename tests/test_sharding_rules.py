"""Sharding rule table: divisibility fitting, cache specs, input specs.

Uses AbstractMesh (via the version-compatible ``abstract_mesh`` helper) so
the production (16,16) axis sizes are exercised without 256 devices."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.runtime.sharding import (_fit_spec, abstract_mesh, batch_spec,
                                    cache_specs_tree, param_specs)

MESH = abstract_mesh((16, 16), ("data", "model"))
POD_MESH = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_fit_spec_keeps_divisible():
    assert _fit_spec(P("model", None), (256, 64), MESH) == P("model", None)


def test_fit_spec_replicates_indivisible_param_dims():
    # qwen2 kv=4 heads can't shard 16-way -> replicate (NOT relocate to a
    # contraction dim, which would force partial-sum all-reduces; §Perf H1)
    assert _fit_spec(P(None, "model", None), (28, 4, 128), MESH) \
        == P(None, None, None)


def test_fit_spec_relocates_for_caches():
    # caches opt into relocation (HBM capacity over collectives)
    assert _fit_spec(P(None, "model", None), (28, 4, 128), MESH,
                     relocate=True) == P(None, None, "model")


def test_fit_spec_replicates_when_nothing_fits():
    assert _fit_spec(P(("data",), None), (1, 1), MESH) == P(None, None)


def test_fit_spec_tuple_axis():
    # ("pod","data") = 32-way; batch 256 divides, batch 8 does not
    assert _fit_spec(P(("pod", "data"), None), (256, 128), POD_MESH) \
        == P(("pod", "data"), None)
    assert _fit_spec(P(("pod", "data"), None), (8, 64), POD_MESH) \
        == P(None, None)


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "qwen2-7b",
                                  "granite-34b", "recurrentgemma-2b"])
def test_param_specs_divisible_on_production_mesh(arch):
    """Every param sharding must divide its dim (pjit argument contract)."""
    cfg = get_config(arch)
    from repro.models import model_init
    params = jax.eval_shape(
        lambda: model_init(jax.random.PRNGKey(0), cfg, None))
    specs = param_specs(params, MESH)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= MESH.shape[a]
            assert dim % size == 0, (leaf.shape, spec)


def test_cache_specs_pos_and_valid_are_rank_matched():
    cfg = get_config("phi3-medium-14b")
    from repro.models import cache_specs
    caches = cache_specs(cfg, 128, 1024)
    specs = cache_specs_tree(caches, cfg, MESH)
    flat_c = jax.tree.leaves(caches)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_c, flat_s):
        assert len(tuple(spec)) <= leaf.ndim, (leaf.shape, spec)


def test_batch_spec_uses_all_batch_axes():
    assert batch_spec(POD_MESH, 1) == P(("pod", "data"), None)
    assert batch_spec(MESH, 2) == P(("data",), None, None)
