"""Continuous-batching serving API: request lifecycle, slot scheduler,
admission packing, EOS, sampling, and compile-count flatness.

Core acceptance property: staggered admission into the live slot array
produces per-request outputs IDENTICAL to sequential one-at-a-time
``generate()`` runs (greedy and seeded sampling), while the admission and
decode jit caches stay at one entry each across mixed budgets, slots,
temperatures, and seeds.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ElasticConfig, get_config
from repro.launch.serve import _budget_list
from repro.runtime.scheduler import RequestHandle, SlotScheduler
from repro.models import model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32

FULL_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
               mha_head_topk=2, mlp_n_experts=4, mlp_expert_topk=2,
               lora_rank=1)


def _setup(key):
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = ElasticConfig(**FULL_KW)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def _prompts(cfg, n, plen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
            for _ in range(n)]


# --------------------------- slot scheduler (unit) ---------------------------

def _dummy(n):
    return [RequestHandle(request=None) for _ in range(n)]


def test_slot_scheduler_flop_packing_and_occupancy():
    sched = SlotScheduler(4, flop_budget=1.0)
    hs = _dummy(4)
    for h in hs:
        sched.enqueue(h, cost=0.4)
    admitted = sched.admit()
    # 0.4 + 0.4 <= 1.0 < 0.4 * 3: low budgets co-schedule 2-deep
    assert [h for _, h in admitted] == hs[:2]
    assert sched.active == 2 and sched.pending == 2
    assert sched.used_cost == pytest.approx(0.8)
    assert sched.admit() == []          # budget exhausted, queue waits
    sched.tick()
    sched.free(hs[0].slot)
    admitted = sched.admit()            # freed capacity admits exactly one
    assert [h for _, h in admitted] == [hs[2]]
    sched.tick()
    assert sched.occupancy == pytest.approx((2 + 2) / (2 * 4))
    # progress guarantee: an over-budget request still runs when idle
    big = SlotScheduler(2, flop_budget=0.3)
    h = _dummy(1)[0]
    big.enqueue(h, cost=1.0)
    assert [x for _, x in big.admit()] == [h]
    assert big.admit() == []


def test_zero_cost_requests_cannot_bypass_flop_budget():
    """Regression: a cost-0 request (a budget fraction rounding to ~no
    FLOPs) still occupies a decode-slot lane, so admission must charge it
    at least MIN_COST — otherwise unbounded zero-cost rows pack into one
    replica and the used-cost accounting reports a full replica as idle."""
    from repro.runtime.scheduler import MIN_COST
    sched = SlotScheduler(4, flop_budget=1.0)
    hs = _dummy(4)
    for h in hs:
        sched.enqueue(h, cost=0.0)
    sched.admit()
    assert sched.used_cost >= 4 * MIN_COST > 0.0
    # a preempted zero-cost continuation is floored too
    sched.free(hs[0].slot)
    sched.requeue_front(hs[0], 0.0)
    assert sched.queue[0][1] == MIN_COST


def test_admit_page_check_joint_packing():
    """admit(page_check=...) only places requests on replicas that can
    also page them, and a head request NO replica can page waits (FIFO —
    it never jumps the queue)."""
    sched = SlotScheduler(4, n_replicas=2)
    hs = _dummy(3)
    for h in hs:
        sched.enqueue(h, cost=1.0)
    # replica 0 has no pages: everything lands on replica 1
    admitted = sched.admit(page_check=lambda h, r: r == 1)
    assert [sched.replica_of(s) for s, _ in admitted] == [1, 1]
    assert sched.pending == 1
    # head request unpageable anywhere -> nobody admits (even with free
    # slots on replica 0)
    assert sched.admit(page_check=lambda h, r: False) == []
    assert sched.free_slots_in(0) and sched.pending == 1


def test_slot_scheduler_fifo_and_drop():
    sched = SlotScheduler(2)
    hs = _dummy(3)
    for h in hs:
        sched.enqueue(h, cost=1.0)
    assert [h for _, h in sched.admit()] == hs[:2]   # slot-limited FIFO
    assert sched.drop_queued(hs[2])
    assert not sched.drop_queued(hs[2])
    assert sched.pending == 0


# ------------------------- lifecycle on the real model -----------------------

def test_staggered_arrivals_match_sequential_generate(key):
    """Requests admitted mid-flight (mixed budgets, one sampled row) emit
    exactly the tokens a sequential per-request run emits, with flat
    compile counts."""
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    prompts = _prompts(cfg, 4)
    reqs = [GenRequest(prompts[0], 6, budget=0.4),
            GenRequest(prompts[1], 6, budget=1.0),
            GenRequest(prompts[2], 6),                       # engine default
            GenRequest(prompts[3], 6, temperature=0.8, top_k=4, seed=11)]
    h0 = eng.submit(reqs[0])
    eng.step(); eng.step()                # r0 is 2 tokens in when r1 lands
    h1 = eng.submit(reqs[1])
    eng.step()
    h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])  # queue: slots full
    handles = [h0, h1, h2, h3]
    while not all(h.done for h in handles):
        eng.step()
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}
    assert all(h.finish_reason == "length" for h in handles)
    # oracle: a fresh engine serving each request alone
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    for h, r in zip(handles, reqs):
        np.testing.assert_array_equal(
            np.asarray(h.output), solo.generate([r])[0])


def test_cancel_mid_flight_frees_slot(key):
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    prompts = _prompts(cfg, 3, seed=5)
    h0 = eng.submit(GenRequest(prompts[0], 8))
    h1 = eng.submit(GenRequest(prompts[1], 8))
    h2 = eng.submit(GenRequest(prompts[2], 8))
    eng.step()
    assert (h0.status, h1.status, h2.status) == ("running", "running",
                                                 "queued")
    victim_slot = h0.slot
    assert eng.cancel(h0)
    assert h0.done and h0.status == "cancelled"
    n_before = len(h0.output)
    eng.step()                            # h2 admitted into the freed slot
    assert h2.status == "running" and h2.slot == victim_slot
    while not (h1.done and h2.done):
        eng.step()
    assert len(h0.output) == n_before     # no tokens after cancel
    assert not eng.cancel(h0)             # idempotent on finished handles
    # survivors are unaffected by the cancel / slot reuse
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    np.testing.assert_array_equal(np.asarray(h1.output),
                                  solo.generate([GenRequest(prompts[1], 8)])[0])
    np.testing.assert_array_equal(np.asarray(h2.output),
                                  solo.generate([GenRequest(prompts[2], 8)])[0])


def test_eos_terminates_slot_early(key):
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    [prompt] = _prompts(cfg, 1, seed=9)
    full = eng.generate([GenRequest(prompt, 8)])[0]
    eos = int(full[2])                    # force a stop at the third token
    cut = int(np.argmax(full == eos))     # first occurrence
    out = eng.generate([GenRequest(prompt, 8, eos_id=eos)])[0]
    np.testing.assert_array_equal(out, full[:cut + 1])
    assert out[-1] == eos and len(out) < len(full)
    # engine-level default eos applies when the request leaves it unset,
    # and the slot frees immediately (engine goes idle at the stop)
    eng2 = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24, eos_id=eos)
    h = eng2.submit(GenRequest(prompt, 8))
    while not h.done:
        eng2.step()
    assert h.finish_reason == "eos"
    np.testing.assert_array_equal(np.asarray(h.output), full[:cut + 1])
    assert eng2.scheduler.active == 0 and not eng2.has_work


def test_sampling_seeded_reproducible_and_greedy_default(key):
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    [prompt] = _prompts(cfg, 1, seed=3)
    greedy = eng.generate([GenRequest(prompt, 6)])[0]
    r = GenRequest(prompt, 6, temperature=0.7, top_k=3, seed=42)
    a = eng.generate([r])[0]
    b = eng.generate([r])[0]
    np.testing.assert_array_equal(a, b)   # same seed -> same stream
    assert ((a >= 0) & (a < cfg.padded_vocab)).all()
    # temperature 0 bit-matches the greedy path even with sampling rows mixed
    mixed = eng.generate([GenRequest(prompt, 6),
                          GenRequest(prompt, 6, temperature=1.2, seed=7)])
    np.testing.assert_array_equal(mixed[0], greedy)
    # sampling knobs are traced: still one compile each
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}


def test_streaming_tokens_iterator(key):
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    [prompt] = _prompts(cfg, 1, seed=13)
    oracle = eng.generate([GenRequest(prompt, 5)])[0]
    h = eng.submit(GenRequest(prompt, 5))
    streamed = list(h.tokens())           # drives eng.step() itself
    assert h.done and h.finish_reason == "length"
    np.testing.assert_array_equal(np.asarray(streamed), oracle)
    assert h.result() == streamed         # idempotent after completion


def test_submit_validation_and_admission_costs(key):
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=4, max_seq=16, step_flop_budget=1.0)
    [prompt] = _prompts(cfg, 1)
    with pytest.raises(ValueError, match="budget"):
        eng.submit(GenRequest(prompt, 4, budget=1.5))
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(GenRequest(prompt, 100))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(GenRequest(np.zeros((0,), np.int32), 4))
    # admission cost = the request's roofline budget fraction
    for b in (0.3, 0.5, None):
        eng.submit(GenRequest(prompt, 4, budget=b))
    assert [c for _, c in eng.scheduler.queue] == [0.3, 0.5, 1.0]
    admitted = eng.scheduler.admit()      # 0.3 + 0.5 <= 1.0, teacher waits
    assert len(admitted) == 2 and eng.scheduler.pending == 1


def test_first_token_finish_does_not_stall_queue(key):
    """A request finishing on its prefill token (max_new=1 / instant EOS)
    counts as progress; queued work behind it must still be served."""
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=1, max_seq=12)
    [p] = _prompts(cfg, 1)
    outs = eng.generate([GenRequest(p, 1), GenRequest(p, 1)])
    assert [len(o) for o in outs] == [1, 1]
    np.testing.assert_array_equal(outs[0], outs[1])


# --------------------------- replica-aware packing ---------------------------

def test_replica_packing_balances_and_never_starves():
    """Admissions spread over the replica axis (least-loaded placement
    under a PER-REPLICA budget) instead of filling replica 0 first."""
    sched = SlotScheduler(8, flop_budget=1.0, n_replicas=2)
    hs = _dummy(6)
    for h in hs:
        sched.enqueue(h, cost=0.5)
    admitted = sched.admit()
    # 1.0 per replica fits two 0.5-cost rows on EACH replica: 4 admitted,
    # alternating replicas (0, 1, 0, 1), nobody queued behind a full
    # replica while the other idles
    assert [h for _, h in admitted] == hs[:4]
    assert [sched.replica_of(s) for s, _ in admitted] == [0, 1, 0, 1]
    assert sched.pending == 2
    assert sched.replica_used_cost(0) == pytest.approx(1.0)
    assert sched.replica_used_cost(1) == pytest.approx(1.0)
    assert sched.admit() == []            # both replicas at budget
    sched.free(admitted[0][0])            # replica 0 drains one row
    nxt = sched.admit()
    assert len(nxt) == 1 and sched.replica_of(nxt[0][0]) == 0
    # per-replica occupancy accounting
    sched.tick()
    assert sched.replica_occupancy == pytest.approx([0.5, 0.5])
    # progress guarantee is replica-aware too: idle scheduler admits an
    # over-budget request onto some replica
    big = SlotScheduler(4, flop_budget=0.3, n_replicas=2)
    h = _dummy(1)[0]
    big.enqueue(h, cost=1.0)
    assert [x for _, x in big.admit()] == [h]

    with pytest.raises(ValueError, match="multiple"):
        SlotScheduler(6, n_replicas=4)


def test_replica_cancel_frees_the_right_slot(key):
    """cancel() on a 2-replica engine frees exactly the cancelled request's
    (replica, slot) pair; the queued request lands in that hole and every
    survivor matches its solo run."""
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=4, max_seq=24, n_replicas=2)
    prompts = _prompts(cfg, 5, seed=21)
    hs = [eng.submit(GenRequest(p, 6)) for p in prompts]
    eng.step()
    # four running (two per replica), one queued
    assert [h.status for h in hs] == ["running"] * 4 + ["queued"]
    assert [eng.scheduler.replica_of(h.slot) for h in hs[:4]] == [0, 1, 0, 1]
    victim = hs[3]
    victim_slot, victim_replica = victim.slot, \
        eng.scheduler.replica_of(victim.slot)
    assert eng.cancel(victim)
    eng.step()                            # hs[4] admitted into the hole
    assert hs[4].slot == victim_slot
    assert eng.scheduler.replica_of(hs[4].slot) == victim_replica
    while not all(h.done for h in hs):
        eng.step()
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    for h, p in [(hs[0], prompts[0]), (hs[1], prompts[1]),
                 (hs[2], prompts[2]), (hs[4], prompts[4])]:
        np.testing.assert_array_equal(
            np.asarray(h.output), solo.generate([GenRequest(p, 6)])[0])


def test_staggered_multi_replica_decode_matches_solo(key):
    """Requests staggered across TWO replicas (mixed budgets, one sampled
    row) emit exactly their solo-run tokens with flat compile counts — the
    replica axis is scheduling-only, the compiled step never changes."""
    cfg, ecfg, params, rp = _setup(key)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=4, max_seq=24, n_replicas=2)
    prompts = _prompts(cfg, 4, seed=17)
    reqs = [GenRequest(prompts[0], 6, budget=0.4),
            GenRequest(prompts[1], 6, budget=1.0),
            GenRequest(prompts[2], 6),
            GenRequest(prompts[3], 6, temperature=0.8, top_k=4, seed=11)]
    h0 = eng.submit(reqs[0])
    eng.step(); eng.step()
    h1 = eng.submit(reqs[1])
    eng.step()
    h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
    handles = [h0, h1, h2, h3]
    while not all(h.done for h in handles):
        eng.step()
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}
    # both replicas actually served work
    assert {eng.scheduler.replica_of(h.slot) for h in handles} == {0, 1}
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                         batch_size=2, max_seq=24)
    for h, r in zip(handles, reqs):
        np.testing.assert_array_equal(
            np.asarray(h.output), solo.generate([r])[0])


# ------------------------------- CLI validation ------------------------------

def test_budget_list_rejects_out_of_range():
    import argparse
    assert _budget_list("0.5,1.0") == [0.5, 1.0]
    for bad in ("1.5", "0.5,2.0", "0", "-0.25", "abc"):
        with pytest.raises(argparse.ArgumentTypeError):
            _budget_list(bad)


# ------------------- deadline hygiene / drop / shed / reprice ----------------

def test_expire_deadlines_drops_before_prefill():
    """A queued request whose deadline passed is finished
    ``deadline_exceeded`` (status REJECTED) without ever taking a slot;
    requests without a deadline, or with one still in the future, stay."""
    sched = SlotScheduler(2)
    hs = _dummy(3)
    hs[0].deadline = 1.0            # already passed at now=2.0
    hs[1].deadline = 5.0            # still in the future
    hs[2].deadline = None
    for h in hs:
        sched.enqueue(h)
    expired = sched.expire_deadlines(now=2.0)
    assert expired == [hs[0]]
    assert hs[0].status == "rejected"
    assert hs[0].finish_reason == "deadline_exceeded"
    assert hs[0].slot is None and hs[0].output == []
    assert sched.pending == 2
    # the survivors admit normally, in FIFO order
    admitted = [h for _s, h in sched.admit()]
    assert admitted == [hs[1], hs[2]]


def test_drop_queued_is_tombstoned_and_skipped():
    """``drop_queued`` is O(1): the entry is tombstoned in place, excluded
    from every view, skipped by admission, and a double-drop is a no-op."""
    sched = SlotScheduler(4)
    hs = _dummy(4)
    for h in hs:
        sched.enqueue(h)
    assert sched.drop_queued(hs[1])
    assert not sched.drop_queued(hs[1])          # already gone
    assert sched.pending == 3
    assert [h for h, _c in sched.queue] == [hs[0], hs[2], hs[3]]
    admitted = [h for _s, h in sched.admit()]
    assert admitted == [hs[0], hs[2], hs[3]]
    assert sched.drop_queued(hs[0]) is False     # running, not queued


def test_admit_cost_cap_packs_denser():
    """Stage-1 degradation: with ``cost_cap`` every admission is charged
    the capped cost, so the same FLOP budget co-schedules more requests."""
    full = SlotScheduler(4, flop_budget=1.0)
    hs = _dummy(4)
    for h in hs:
        full.enqueue(h, cost=1.0)
    assert len(full.admit()) == 1                # uncapped: budget-limited
    capped = SlotScheduler(4, flop_budget=1.0)
    hs = _dummy(4)
    for h in hs:
        capped.enqueue(h, cost=1.0)
    out = capped.admit(cost_cap=0.25)
    assert len(out) == 4                         # 4 x 0.25 fits the budget
    assert all(capped.costs[s] == 0.25 for s, _h in out)


def test_shed_prefers_high_shed_order_then_newest():
    """Shed victims: most-sheddable class first (higher ``priority``),
    newest arrival first within a class — interactive work submitted
    earliest is the last to go."""
    sched = SlotScheduler(2)
    hs = _dummy(4)
    for h, tenant in zip(hs, ("int", "batch", "int", "batch")):
        h.tenant = tenant
        sched.enqueue(h)
    order = {"int": 0, "batch": 1}
    victims = sched.shed(3, priority=lambda h: order[h.tenant])
    assert victims == [hs[3], hs[1], hs[2]]      # batch newest-first, then int
    assert all(v.status == "rejected" and v.finish_reason == "rejected"
               for v in victims)
    assert sched.pending == 1
    assert [h for h, _c in sched.queue] == [hs[0]]


def test_reprice_grows_admission_headroom():
    """Stage-2 degradation: repricing a running slot's cost frees FLOP
    headroom, so the next ``admit`` fits work that previously had to wait.
    Repricing floors at MIN_COST and ignores freed slots."""
    from repro.runtime.scheduler import MIN_COST

    sched = SlotScheduler(2, flop_budget=1.0)
    h0, h1 = _dummy(2)
    sched.enqueue(h0, cost=1.0)
    (slot, _h), = sched.admit()
    sched.enqueue(h1, cost=0.5)
    assert sched.admit() == []                   # 1.0 + 0.5 over budget
    sched.reprice(slot, 0.25)
    assert [h for _s, h in sched.admit()] == [h1]
    sched.reprice(slot, 0.0)
    assert sched.costs[slot] == MIN_COST         # never free, never zero
    sched.free(h1.slot)
    sched.reprice(h1.slot, 5.0)
    assert sched.costs[h1.slot] == 0.0           # freed slots stay zero
