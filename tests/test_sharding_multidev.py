"""Multi-device sharding tests: run a real pjit distillation step, an
elastic re-mesh, and the SPMD serving engine on 8 fake CPU devices
(subprocess, so the main test process keeps 1 device). Proves the sharding
rules + shard_map distill loss + elastic resharding + sharded continuous
batching actually execute SPMD, not just lower."""
import os
import subprocess
import sys

import pytest


def _run_spmd_script(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config, get_elastic
from repro.models import model_init, router_init, forward
from repro.runtime import sharding as SH
from repro.runtime.elastic import make_mesh, rescale_training_state
from repro.training import init_train_state, make_train_step
from repro.optim import cosine_schedule

cfg = dataclasses.replace(get_config("qwen2-7b", "smoke"), dtype="float32")
ecfg = get_elastic("qwen2-7b", cfg)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

# ---- single device reference ----
step_ref = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=None)
s_ref, m_ref = jax.jit(step_ref)(init_train_state(rp), params, batch)

# ---- 2x4 mesh SPMD ----
mesh = make_mesh((2, 4), ("data", "model"))
p_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), params,
                    SH.param_shardings(params, mesh))
b_sh = {"tokens": jax.device_put(batch["tokens"],
                                 NamedSharding(mesh, P("data", None)))}
step = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=mesh)
with mesh:
    s_spmd, m_spmd = jax.jit(step)(init_train_state(rp), p_sh, b_sh)
# distill loss is exact under SPMD (distributed top-50 KL is exact math);
# the load-balance loss uses PER-SHARD batch statistics under the
# per-block shard_map (GShard-style per-group load loss: a mean of
# products != product of means), so total loss matches only loosely.
a, b = float(m_ref["distill"]), float(m_spmd["distill"])
assert abs(a - b) / max(abs(a), 1e-6) < 5e-3, ("distill", a, b)
a, b = float(m_ref["loss"]), float(m_spmd["loss"])
assert abs(a - b) / max(abs(a), 1e-6) < 5e-2, ("loss", a, b)

# updates point the same way (load-loss grads differ per-shard slightly)
va = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s_ref.router_params)])
vb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s_spmd.router_params)])
cos = float(jnp.sum(va * vb) / (jnp.linalg.norm(va) * jnp.linalg.norm(vb)))
assert cos > 0.999, f"router update cos {cos}"

# ---- elastic re-mesh: 8 -> 4 devices ----
mesh2 = make_mesh((1, 4), ("data", "model"))
p2, rp2, opt2 = rescale_training_state(
    params, s_spmd.router_params, s_spmd.opt, mesh2)
b2 = {"tokens": jax.device_put(batch["tokens"],
                               NamedSharding(mesh2, P("data", None)))}
step2 = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=mesh2)
from repro.training import TrainState
with mesh2:
    s3, m3 = jax.jit(step2)(TrainState(rp2, opt2, None), p2, b2)
assert np.isfinite(float(m3["loss"]))
print("SPMD-OK", float(m_ref["loss"]), float(m_spmd["loss"]), float(m3["loss"]))
"""


@pytest.mark.slow
def test_spmd_matches_single_device_and_elastic_remesh(tmp_path):
    assert "SPMD-OK" in _run_spmd_script(_SCRIPT)


_SERVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.elastic import make_mesh, valid_mesh_shapes
from repro.training import GenRequest, ServingEngine

cfg = dataclasses.replace(get_config("toy-lm", "smoke"), dtype="float32")
ecfg = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                     mha_head_topk=2, mlp_n_experts=4, mlp_expert_topk=2,
                     lora_rank=1)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
           for _ in range(4)]
reqs = [GenRequest(prompts[0], 6, budget=0.4),       # mixed budgets...
        GenRequest(prompts[1], 6, budget=1.0),
        GenRequest(prompts[2], 6),                   # ...engine default...
        GenRequest(prompts[3], 6, temperature=0.8, top_k=4, seed=11)]

# oracle: the single-device engine serving each request alone
solo = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                     max_seq=24)
oracle = [solo.generate([r])[0] for r in reqs]

# ---- sharded engine, staggered admissions, 2x4 (data, model) mesh ----
mesh = make_mesh((2, 4), ("data", "model"))
eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                    max_seq=24, mesh=mesh)
assert eng.scheduler.n_replicas == 2
h0 = eng.submit(reqs[0])
eng.step(); eng.step()            # r0 is 2 tokens in when r1 lands
h1 = eng.submit(reqs[1])
eng.step()
h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
handles = [h0, h1, h2, h3]
while not all(h.done for h in handles):
    eng.step()
assert eng.compile_counts() == {"prefill": 1, "decode": 1}, \
    eng.compile_counts()
# admission spread across BOTH replicas (least-loaded placement)
assert {eng.scheduler.replica_of(h.slot) for h in handles} == {0, 1}
for h, o in zip(handles, oracle):     # token-for-token vs single device
    np.testing.assert_array_equal(np.asarray(h.output), o)
print("SERVE-PARITY-OK")

# ---- donation survives SPMD: the sharded caches alias through the jits ----
from repro.launch.hloprof import input_output_alias
dec = eng.entry_points()["decode"]
n_donated = sum(len(jax.tree.leaves(dec.args[i])) for i in dec.donated)
with mesh:
    alias = input_output_alias(
        dec.fn.lower(*dec.args, **dec.static).compile().as_text())
assert len(alias) >= n_donated, (alias, n_donated)
print("SPMD-DONATE-OK")

# ---- live re-mesh mid-flight: 2x4 -> 1x4, identical greedy tokens ----
assert (1, 4) in valid_mesh_shapes(4, 4)
eng2 = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                     max_seq=24, mesh=mesh)
hs = [eng2.submit(r) for r in reqs]
eng2.step(); eng2.step()          # all four in flight, mid-generation
eng2.reshard(make_mesh((1, 4), ("data", "model")))
assert eng2.scheduler.n_replicas == 1
while not all(h.done for h in hs):
    eng2.step()
assert eng2.compile_counts() == {"prefill": 0, "decode": 1}  # post-remesh
for h, o in zip(hs, oracle):
    np.testing.assert_array_equal(np.asarray(h.output), o)
print("REMESH-OK")

# ---- one RoutingPlan sort per block still holds under the mesh ----
from repro.core import routing as R
from repro.core.policy import ElasticPolicy, ElasticSpec
spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
sp_params = model_init(key, cfg, spec)
sp_rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
from repro.models import forward
pol = ElasticPolicy.uniform(0.5, static=True)
batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
with mesh:
    before = R.PLAN_SORT_COUNT
    jax.jit(lambda rp, b: forward(sp_params, rp, b, cfg, spec, mode="train",
                                  policy=pol)[0]).lower(sp_rp, batch)
    assert R.PLAN_SORT_COUNT - before == 1, (R.PLAN_SORT_COUNT, before)
print("ONE-SORT-OK")

# ---- kernel dispatch lowers PER-SHARD under shard_map ----
# monkeypatch the kernel entry points (ops dispatches via module
# attributes) to record the shapes each shard's kernel call sees
from repro.kernels import ops as OPS
_dec = OPS._decode_mod
_fm = OPS._fused_mlp_mod
from repro.kernels import ref as KREF

B, L, H, K, Dh = 4, 16, 8, 4, 8
q = jax.random.normal(key, (B, 1, H, Dh), jnp.float32)
kc = jax.random.normal(jax.random.fold_in(key, 2), (B, L, K, Dh),
                       jnp.float32)
vc = jax.random.normal(jax.random.fold_in(key, 3), (B, L, K, Dh),
                       jnp.float32)
pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
t = jnp.asarray([3, 7, 5, 9], jnp.int32)
valid = pos <= t[:, None]

seen = []
orig = _dec.decode_attention
def probe(q, k, v, kv_pos, t, **kw):
    seen.append(q.shape)
    return orig(q, k, v, kv_pos, t, **kw)
_dec.decode_attention = probe
with mesh:
    got = jax.jit(lambda *a: OPS.decode_attention_sharded(
        *a, window=0, backend="interpret"))(q, kc, vc, pos, t, valid)
_dec.decode_attention = orig
# the kernel grid saw the LOCAL block: batch/data x heads/model
assert (B // 2, 1, H // 4, Dh) in seen, seen
np.testing.assert_allclose(
    np.asarray(got),
    np.asarray(KREF.decode_attention_ref(q, kc, vc, pos, t,
                                         kv_valid=valid)),
    rtol=1e-5, atol=1e-5)

S, D, F, Kb = 16, 8, 32, 8
x = jax.random.normal(key, (B, S, D), jnp.float32)
wi = jax.random.normal(jax.random.fold_in(key, 4), (D, F), jnp.float32) * .1
wo = jax.random.normal(jax.random.fold_in(key, 5), (F, D), jnp.float32) * .1
wg = jax.random.normal(jax.random.fold_in(key, 6), (D, F), jnp.float32) * .1
idx = jnp.tile(jnp.arange(Kb, dtype=jnp.int32)[None], (B, 1))
tw = jnp.ones((B, Kb), jnp.float32)
cnt = jnp.asarray([8, 5, 8, 3], jnp.int32)
seen2 = []
orig2 = _fm.fused_mlp_routed
def probe2(x, idx, wi, *a, **kw):
    seen2.append((x.shape, idx.shape, wi.shape))
    return orig2(x, idx, wi, *a, **kw)
_fm.fused_mlp_routed = probe2
with mesh:
    got = jax.jit(lambda *a: OPS.fused_mlp_routed_sharded(
        *a, act="swiglu", backend="interpret"))(x, idx, wi, wo, wg, tw, cnt)
_fm.fused_mlp_routed = orig2
# FFN dim sharded over model, plan idx replicated into every shard
assert ((B // 2, S, D), (B // 2, Kb), (D, F // 4)) in seen2, seen2
np.testing.assert_allclose(
    np.asarray(got),
    np.asarray(KREF.fused_mlp_routed_ref(x, idx, wi, wo, wg, tw,
                                         act="swiglu", valid_count=cnt)),
    rtol=1e-4, atol=1e-5)
print("KERNEL-SHARD-OK")
"""


_PAGED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.elastic import make_mesh
from repro.training import GenRequest, ServingEngine

cfg = dataclasses.replace(get_config("toy-lm", "smoke"), dtype="float32")
# dense MLP: paged mode excludes moefied experts (chunk-parity contract)
ecfg = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                     mha_head_topk=2, lora_rank=1)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
rng = np.random.default_rng(0)
# FOUR distinct prompt lengths: the chunked prefill must hold ONE compile.
# All-greedy rows: cross-mesh token parity is a GREEDY contract (the TP
# all-reduce changes float association by ~1e-6, which gumbel-perturbed
# sampling can amplify into a different token — same as the ring engine).
reqs = [GenRequest(rng.integers(0, cfg.vocab_size, L, dtype=np.int32), 6,
                   budget=b)
        for L, b in ((5, 0.4), (13, 1.0), (16, None), (29, 0.6))]

# oracle: single-device RING engine serving each request alone
solo = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                     max_seq=48)
oracle = [solo.generate([r])[0] for r in reqs]

# ---- paged engine, staggered admissions, 2x4 (data, model) mesh ----
mesh = make_mesh((2, 4), ("data", "model"))
eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                    max_seq=48, mesh=mesh, kv_layout="paged", page_size=8)
assert eng.scheduler.n_replicas == 2
h0 = eng.submit(reqs[0])
eng.step(); eng.step()            # r0 is 2 tokens in when r1 lands
h1 = eng.submit(reqs[1])
eng.step()
h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
handles = [h0, h1, h2, h3]
while not all(h.done for h in handles):
    assert eng.step() > 0
assert eng.compile_counts() == {"prefill": 1, "decode": 1}, \
    eng.compile_counts()
# admissions spread over BOTH replicas; page ids stay replica-local
assert {eng.scheduler.replica_of(h.slot) for h in handles} == {0, 1}
for h, o in zip(handles, oracle):     # token-for-token vs 1-device ring
    np.testing.assert_array_equal(np.asarray(h.output), o)
st = eng.paged_stats()
assert st["allocated"] == 0 and st["free"] == st["usable"], st
print("PAGED-SPMD-PARITY-OK")

# ---- prefix sharing + CoW fork still exact on the mesh ----
pre = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
a = np.concatenate([pre, rng.integers(0, cfg.vocab_size, 4, dtype=np.int32)])
hp = eng.submit(GenRequest(a, 8, budget=0.5))
for _ in range(3):
    eng.step()
head = list(hp.output)
hc = eng.fork(hp)
while not (hp.done and hc.done):
    assert eng.step() > 0
ind = solo.generate([GenRequest(
    np.concatenate([a, np.asarray(head, np.int32)]), 8 - len(head),
    budget=0.5)])[0]
np.testing.assert_array_equal(np.asarray(hc.output), ind)
np.testing.assert_array_equal(np.asarray(hp.output[len(head):]), ind)
assert eng.paged_stats()["allocated"] == 0
print("PAGED-SPMD-FORK-OK")
"""


@pytest.mark.slow
def test_paged_kv_spmd_parity(tmp_path):
    """Paged-KV acceptance on the production mesh: on a 2x4 (data, model)
    mesh the paged engine (block-paged pool, chunked prefill, per-replica
    page ranges) is token-for-token identical to the single-device ring
    engine across four distinct prompt lengths with ONE prefill compile,
    and a mid-decode CoW fork bit-matches an independent run."""
    out = _run_spmd_script(_PAGED_SCRIPT)
    for tag in ("PAGED-SPMD-PARITY-OK", "PAGED-SPMD-FORK-OK"):
        assert tag in out, out


@pytest.mark.slow
def test_sharded_serving_parity_and_live_remesh(tmp_path):
    """ISSUE 5 acceptance: on a 2x4 (data, model) mesh of 8 fake CPU
    devices, the sharded ServingEngine is token-for-token identical to the
    single-device engine on a mixed-budget staggered workload with flat
    compile counts; a mid-run reshard resumes with identical greedy tokens;
    RoutingPlan stays one-sort-per-block under the mesh; and the Pallas
    kernel entry points lower per-shard under shard_map."""
    out = _run_spmd_script(_SERVE_SCRIPT)
    for tag in ("SERVE-PARITY-OK", "SPMD-DONATE-OK", "REMESH-OK",
                "ONE-SORT-OK", "KERNEL-SHARD-OK"):
        assert tag in out, out


_QUANT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import numpy as np
from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.elastic import make_mesh
from repro.training import GenRequest, ServingEngine

cfg = dataclasses.replace(get_config("toy-lm", "smoke"), dtype="float32")
ecfg = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                     mha_head_topk=2, lora_rank=1)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
rng = np.random.default_rng(0)
# all-greedy rows: cross-mesh token parity is a greedy contract
reqs = [GenRequest(rng.integers(0, cfg.vocab_size, L, dtype=np.int32), 6,
                   budget=b)
        for L, b in ((5, 0.4), (13, 1.0), (16, None), (29, 0.6))]
kw = dict(mode="infer", max_seq=48, kv_dtype="int8", weight_dtype="int8")

# oracle: single-device int8 RING engine serving each request alone
solo = ServingEngine(params, rp, cfg, ecfg, batch_size=2, **kw)
oracle = [solo.generate([r])[0] for r in reqs]

# ---- int8 paged engine on the 2x4 production mesh, staggered ----
mesh = make_mesh((2, 4), ("data", "model"))
eng = ServingEngine(params, rp, cfg, ecfg, batch_size=4, mesh=mesh,
                    kv_layout="paged", page_size=8, **kw)
assert eng.scheduler.n_replicas == 2
h0 = eng.submit(reqs[0])
eng.step(); eng.step()            # r0 is 2 tokens in when r1 lands
h1 = eng.submit(reqs[1])
eng.step()
h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
handles = [h0, h1, h2, h3]
while not all(h.done for h in handles):
    assert eng.step() > 0
assert eng.compile_counts() == {"prefill": 1, "decode": 1}, \
    eng.compile_counts()
assert {eng.scheduler.replica_of(h.slot) for h in handles} == {0, 1}
for h, o in zip(handles, oracle):     # token-for-token vs 1-device int8
    np.testing.assert_array_equal(np.asarray(h.output), o)
st = eng.paged_stats()
assert st["allocated"] == 0 and st["free"] == st["usable"], st
# the int8 pools AND their f32 scale siblings live on the mesh (the
# sharding pins cover both leaves — docs/quantization.md)
from jax.sharding import NamedSharding
leaves = jax.tree.leaves(eng._caches)
assert any(str(l.dtype) == "int8" for l in leaves), \
    sorted({str(l.dtype) for l in leaves})
for l in leaves:
    assert isinstance(l.sharding, NamedSharding), l.sharding
print("QUANT-SPMD-PARITY-OK")
"""


_DEPTH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import numpy as np
from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.elastic import make_mesh
from repro.training import GenRequest, ServingEngine

cfg = dataclasses.replace(get_config("toy-lm", "smoke"), dtype="float32")
# depth router live: per-(slot, layer) whole-block skips, so decode writes
# NO KV at skipped layers — the per-layer KV-validity masks must keep
# staggered neighbors exact across the replicas
ecfg = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                     depth_capacity=0.75, lora_rank=1)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
rng = np.random.default_rng(0)
# all-greedy rows: cross-mesh token parity is a greedy contract
reqs = [GenRequest(rng.integers(0, cfg.vocab_size, L, dtype=np.int32), 6,
                   budget=b)
        for L, b in ((5, 0.4), (13, 1.0), (16, None), (29, 0.6))]

# oracle: single-device RING engine serving each request alone
solo = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                     max_seq=48)
oracle = [solo.generate([r])[0] for r in reqs]

for layout, kw in (("ring", {}), ("paged", {"page_size": 8})):
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                        max_seq=48, mesh=mesh, kv_layout=layout, **kw)
    assert eng.scheduler.n_replicas == 2
    h0 = eng.submit(reqs[0])
    eng.step(); eng.step()            # r0 is 2 tokens in when r1 lands
    h1 = eng.submit(reqs[1])
    eng.step()
    h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
    handles = [h0, h1, h2, h3]
    while not all(h.done for h in handles):
        assert eng.step() > 0
    # decode stays ONE compile with depth live; prefill is one for paged
    # (chunked prefill) and one PER DISTINCT PROMPT LENGTH for ring — the
    # documented ring cost this 4-length mix deliberately exercises
    want_prefill = 1 if layout == "paged" else len({len(r.prompt)
                                                    for r in reqs})
    assert eng.compile_counts() == {"prefill": want_prefill, "decode": 1}, \
        eng.compile_counts()
    assert {eng.scheduler.replica_of(h.slot) for h in handles} == {0, 1}
    for h, o in zip(handles, oracle):   # token-for-token vs 1-device ring
        np.testing.assert_array_equal(np.asarray(h.output), o)
    # the per-layer KV-validity mask leaves live ON the mesh (the
    # constrain_kv_mask / constrain_page_pool pins cover them)
    from jax.sharding import NamedSharding
    for l in jax.tree.leaves(eng._caches):
        assert isinstance(l.sharding, NamedSharding), l.sharding
    print(f"DEPTH-SPMD-{layout.upper()}-OK")
"""


@pytest.mark.slow
def test_depth_serving_spmd_parity(tmp_path):
    """Elastic depth acceptance on the production mesh: with the depth
    router live (per-(slot, layer) whole-block skips writing NO KV at
    skipped layers), both cache layouts on a 2x4 (data, model) mesh are
    token-for-token identical to the single-device ring engine on a
    staggered mixed-budget workload, compile counts stay flat, and every
    cache leaf — including the per-layer KV-validity masks — is placed on
    the mesh."""
    out = _run_spmd_script(_DEPTH_SCRIPT)
    for tag in ("DEPTH-SPMD-RING-OK", "DEPTH-SPMD-PAGED-OK"):
        assert tag in out, out


@pytest.mark.slow
def test_quantized_serving_spmd_parity(tmp_path):
    """int8 KV + int8 weights on the 2x4 (data, model) mesh: the sharded
    paged engine is token-for-token identical to the single-device int8
    ring engine on a staggered mixed-budget workload, compile counts stay
    flat, the pool drains, and every cache leaf (int8 pool + f32 scale
    sibling) is placed on the mesh."""
    out = _run_spmd_script(_QUANT_SCRIPT)
    assert "QUANT-SPMD-PARITY-OK" in out, out
