"""Multi-device sharding tests: run a real pjit distillation step and an
elastic re-mesh on 8 fake CPU devices (subprocess, so the main test process
keeps 1 device). Proves the sharding rules + shard_map distill loss + elastic
resharding actually execute SPMD, not just lower."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_config, get_elastic
from repro.models import model_init, router_init, forward
from repro.runtime import sharding as SH
from repro.runtime.elastic import make_mesh, rescale_training_state
from repro.training import init_train_state, make_train_step
from repro.optim import cosine_schedule

cfg = dataclasses.replace(get_config("qwen2-7b", "smoke"), dtype="float32")
ecfg = get_elastic("qwen2-7b", cfg)
key = jax.random.PRNGKey(0)
params = model_init(key, cfg, ecfg)
rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

# ---- single device reference ----
step_ref = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=None)
s_ref, m_ref = jax.jit(step_ref)(init_train_state(rp), params, batch)

# ---- 2x4 mesh SPMD ----
mesh = make_mesh((2, 4), ("data", "model"))
p_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), params,
                    SH.param_shardings(params, mesh))
b_sh = {"tokens": jax.device_put(batch["tokens"],
                                 NamedSharding(mesh, P("data", None)))}
step = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=mesh)
with mesh:
    s_spmd, m_spmd = jax.jit(step)(init_train_state(rp), p_sh, b_sh)
# distill loss is exact under SPMD (distributed top-50 KL is exact math);
# the load-balance loss uses PER-SHARD batch statistics under the
# per-block shard_map (GShard-style per-group load loss: a mean of
# products != product of means), so total loss matches only loosely.
a, b = float(m_ref["distill"]), float(m_spmd["distill"])
assert abs(a - b) / max(abs(a), 1e-6) < 5e-3, ("distill", a, b)
a, b = float(m_ref["loss"]), float(m_spmd["loss"])
assert abs(a - b) / max(abs(a), 1e-6) < 5e-2, ("loss", a, b)

# updates point the same way (load-loss grads differ per-shard slightly)
va = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s_ref.router_params)])
vb = jnp.concatenate([x.ravel() for x in jax.tree.leaves(s_spmd.router_params)])
cos = float(jnp.sum(va * vb) / (jnp.linalg.norm(va) * jnp.linalg.norm(vb)))
assert cos > 0.999, f"router update cos {cos}"

# ---- elastic re-mesh: 8 -> 4 devices ----
mesh2 = make_mesh((1, 4), ("data", "model"))
p2, rp2, opt2 = rescale_training_state(
    params, s_spmd.router_params, s_spmd.opt, mesh2)
b2 = {"tokens": jax.device_put(batch["tokens"],
                               NamedSharding(mesh2, P("data", None)))}
step2 = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-3, 10), mesh=mesh2)
from repro.training import TrainState
with mesh2:
    s3, m3 = jax.jit(step2)(TrainState(rp2, opt2, None), p2, b2)
assert np.isfinite(float(m3["loss"]))
print("SPMD-OK", float(m_ref["loss"]), float(m_spmd["loss"]), float(m3["loss"]))
"""


@pytest.mark.slow
def test_spmd_matches_single_device_and_elastic_remesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SPMD-OK" in r.stdout
