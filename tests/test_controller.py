"""SLO controller: staged degradation, hysteretic restore, determinism.

Three layers:

* unit — the degrade/restore ladder, hysteresis band edges, shed sizing,
  the saturation -> escalate edge, budget quantization.
* determinism — a recorded metric trace replays to a BIT-identical budget
  trajectory (including the hysteresis band and the saturation->remesh
  edge), with the wall clock monkeypatched to raise: the controller may
  only ever see injected time.
* engine integration — a FakeClock-driven ServingEngine under synthetic
  overload walks every degradation stage while ``compile_counts()`` stays
  at ``{prefill: 1, decode: 1}`` (the one-compile contract survives the
  controller), shed requests end ``rejected`` with a Retry-After hint,
  and expired deadlines end ``deadline_exceeded`` without burning a
  prefill.
"""
import math
import time

import jax
import numpy as np
import pytest

from repro.configs import ElasticConfig, get_config
from repro.models import model_init, router_init
from repro.runtime.controller import (BUDGET_QUANTUM, SLOController,
                                      SLOTarget, _quantize)
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32


def _ctrl(**kw):
    base = dict(targets={"default": SLOTarget(p95_ttft_ms=100.0)},
                floor=0.25, step_down=0.25, step_up=0.25,
                window=16, min_samples=1, eval_interval_s=0.0,
                hysteresis=0.7, patience=2, queue_factor=1.0,
                escalate_after=2, sample_ttl_s=100.0)
    base.update(kw)
    return SLOController(**base)


# --------------------------------- unit --------------------------------------

def test_degrade_ladder_admission_depth_inflight_then_shed_then_escalate():
    c = _ctrl()
    t = 0.0
    # sustained violation: TTFT 5x over target
    for _ in range(3):                      # 1.0 -> 0.75 -> 0.5 -> 0.25
        c.record_ttft("default", 0, 500.0, t=t)
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert c.admission_budget == 0.25 and c.depth_budget == 1.0
    for _ in range(3):                      # then the depth stage
        c.record_ttft("default", 0, 500.0, t=t)
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert c.depth_budget == 0.25 and c.inflight_budget == 1.0
    assert c.depth_cap() == 0.25
    for _ in range(3):                      # then the in-flight stage
        c.record_ttft("default", 0, 500.0, t=t)
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert c.inflight_budget == 0.25
    # saturated at the floor: shed the backlog beyond queue_factor*capacity
    out = c.update(t, queue_depth=10, capacity=4)
    assert out["shed"] == 6 and not out["escalate"]
    t += 1.0
    out = c.update(t, queue_depth=10, capacity=4)   # escalate_after=2
    assert out["escalate"] and c.should_escalate
    assert [k for _t, k, _v in c.events] == [
        "degrade_admission"] * 3 + ["degrade_depth"] * 3 + [
        "degrade_inflight"] * 3 + ["shed", "shed", "escalate"]
    c.notify_remeshed()
    assert not c.should_escalate


def test_hysteresis_band_holds_then_restores_inflight_first():
    c = _ctrl(patience=2)
    c.admission_budget = c.inflight_budget = 0.5
    c.depth_budget = 0.75
    t = 0.0
    # inside the band (hysteresis <= ratio <= 1): hold, never restore
    for _ in range(5):
        c.record_ttft("default", 0, 80.0, t=t)      # ratio 0.8
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert (c.admission_budget, c.inflight_budget) == (0.5, 0.5)
    # comfortably healthy: restore every `patience` evals, in-flight first
    c._ttft.clear()
    for _ in range(2):
        c.record_ttft("default", 0, 10.0, t=t)      # ratio 0.1
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert (c.admission_budget, c.inflight_budget) == (0.5, 0.75)
    # 4 restores left (inflight x1, depth x1, admission x2), patience=2
    for _ in range(8):
        c.record_ttft("default", 0, 10.0, t=t)
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    assert (c.admission_budget, c.depth_budget,
            c.inflight_budget) == (1.0, 1.0, 1.0)
    # restored all the way: both caps clear
    assert c.admission_cap() is None and c.depth_cap() is None


def test_queue_pressure_alone_degrades_and_samples_expire():
    c = _ctrl(sample_ttl_s=5.0)
    out = c.update(0.0, queue_depth=9, capacity=4)  # ratio 2.25, no samples
    assert out["evaluated"] and out["ratio"] == pytest.approx(2.25)
    assert c.admission_budget == 0.75
    # a stale overload sample must not pin the ratio forever
    c.record_ttft("default", 0, 1000.0, t=1.0)
    assert c.pressure() == pytest.approx(10.0)
    c.update(10.0, queue_depth=0, capacity=4)       # t - ttl expires it
    assert c.pressure() == 0.0


def test_budgets_stay_on_quantized_lattice():
    c = _ctrl(step_down=0.37, floor=0.2)            # awkward steps
    t = 0.0
    for _ in range(6):
        c.record_ttft("default", 0, 500.0, t=t)
        c.update(t, queue_depth=0, capacity=4)
        t += 1.0
    for b in (c.admission_budget, c.inflight_budget, c.floor):
        assert b == pytest.approx(round(b / BUDGET_QUANTUM) * BUDGET_QUANTUM)
    assert _quantize(0.001) == BUDGET_QUANTUM       # never quantizes to 0


def test_retry_after_scales_with_violation():
    c = _ctrl(retry_after_s=2.0)
    assert c.retry_after(0.5) == 2.0                # never below the base
    assert c.retry_after(3.0) == 6.0


def test_rate_limit_honors_eval_interval():
    c = _ctrl(eval_interval_s=1.0)
    assert c.update(0.0, queue_depth=9, capacity=4)["evaluated"]
    assert not c.update(0.5, queue_depth=9, capacity=4)["evaluated"]
    assert c.update(1.0, queue_depth=9, capacity=4)["evaluated"]
    assert len(c.trajectory) == 2


# ------------------------------ determinism -----------------------------------

def _recorded_trace():
    """A synthetic recorded trace: healthy -> overload (degrade to floor,
    shed, escalate) -> remesh -> recovery (hysteresis crossing, full
    restore). Timestamps and latencies are all injected."""
    rng = np.random.default_rng(42)
    events = []
    t = 0.0
    for phase, (n, ms_lo, ms_hi, depth) in enumerate(
            [(20, 10, 40, 0), (30, 300, 900, 12), (40, 5, 30, 0)]):
        for _ in range(n):
            t += float(rng.uniform(0.05, 0.2))
            cls = "default" if rng.uniform() < 0.8 else "batch"
            events.append(("ttft", t, cls, int(rng.integers(0, 2)),
                           float(rng.uniform(ms_lo, ms_hi))))
            events.append(("itl", t, cls, int(rng.integers(0, 2)),
                           float(rng.uniform(ms_lo / 10, ms_hi / 10))))
            events.append(("update", t, depth))
        if phase == 1:
            events.append(("remesh", t))
    return events


def _replay_trace(events):
    c = SLOController(
        targets={"default": SLOTarget(p95_ttft_ms=100.0, p95_itl_ms=50.0),
                 "batch": SLOTarget(p95_ttft_ms=400.0, shed_order=1)},
        floor=0.25, step_up=0.25, eval_interval_s=0.1, min_samples=2,
        patience=1, escalate_after=8, sample_ttl_s=0.5)
    for ev in events:
        if ev[0] == "ttft":
            c.record_ttft(ev[2], ev[3], ev[4], t=ev[1])
        elif ev[0] == "itl":
            c.record_itl(ev[2], ev[3], ev[4], t=ev[1])
        elif ev[0] == "update":
            c.update(ev[1], queue_depth=ev[2], capacity=4)
        elif ev[0] == "remesh":
            c.notify_remeshed()
    return c


def test_recorded_trace_replays_bit_identical(monkeypatch):
    events = _recorded_trace()

    def boom(*a, **k):
        raise AssertionError("controller read the wall clock")

    monkeypatch.setattr(time, "perf_counter", boom)
    monkeypatch.setattr(time, "time", boom)
    monkeypatch.setattr(time, "monotonic", boom)
    a, b = _replay_trace(events), _replay_trace(events)

    # bit-identical trajectory: same floats, same shed counts, same edges
    assert a.trajectory == b.trajectory
    assert a.events == b.events
    assert a.shed_total == b.shed_total
    # the trace actually crossed every edge worth reproducing
    kinds = {k for _t, k, _v in a.events}
    assert {"degrade_admission", "degrade_depth", "degrade_inflight",
            "shed", "escalate", "restore_inflight", "restore_depth",
            "restore_admission"} <= kinds
    # saturation -> remesh fired exactly once, then recovery rearmed it
    assert sum(1 for _t, k, _v in a.events if k == "escalate") == 1
    assert not a.should_escalate
    assert (a.admission_budget, a.depth_budget,
            a.inflight_budget) == (1.0, 1.0, 1.0)


# --------------------------- engine integration -------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


DENSE_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                mha_head_topk=2, lora_rank=1)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    cfg = f32(get_config("toy-lm", "smoke"))
    ecfg = ElasticConfig(**DENSE_KW)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    return cfg, ecfg, params, rp


def test_engine_walks_degradation_stages_with_flat_compiles(setup):
    cfg, ecfg, params, rp = setup
    clock = FakeClock()
    ctrl = SLOController(
        targets={"default": SLOTarget(p95_ttft_ms=1.0)},   # everything over
        floor=0.25, step_down=0.25, window=8, min_samples=1,
        eval_interval_s=0.0, queue_factor=1.0, escalate_after=2,
        retry_after_s=1.0, sample_ttl_s=1e9)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                        max_seq=24, controller=ctrl, clock=clock)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                       8, seed=i) for i in range(10)]
    handles = [eng.submit(r) for r in reqs]
    for _ in range(60):
        clock.advance(0.1)
        if eng.step() == 0 and not eng.has_work:
            break
    # every stage ran, in order
    kinds = [k for _t, k, _v in ctrl.events]
    assert kinds.index("degrade_admission") < kinds.index("degrade_inflight")
    assert "shed" in kinds and "escalate" in kinds
    assert ctrl.admission_budget == 0.25 and ctrl.inflight_budget == 0.25
    # shed requests: typed terminal state + Retry-After hint
    shed = [h for h in handles if h.status == "rejected"]
    assert shed and all(h.finish_reason == "rejected" for h in shed)
    assert all(h.retry_after is not None and h.retry_after >= 1.0
               for h in shed)
    assert eng.n_rejected == len(shed)
    # served requests: degraded in-flight budgets show in budget_served
    served = [h for h in handles if h.status == "done"]
    assert served and all(h.budget_served <= 1.0 for h in served)
    assert any(h.budget_served < 1.0 for h in served)
    # the one-compile contract survives every stage (single prompt length)
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}


def test_deadline_expires_queued_request_before_prefill(setup):
    cfg, ecfg, params, rp = setup
    clock = FakeClock()
    ctrl = SLOController(
        targets={"default": SLOTarget(deadline_ms=50.0)},
        eval_interval_s=1e9)                 # control loop quiet: deadline
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                        max_seq=24, controller=ctrl, clock=clock)
    h = eng.submit(GenRequest(np.arange(8, dtype=np.int32), 4))
    assert h.deadline == pytest.approx(0.05)
    clock.advance(0.2)                       # expires while still queued
    n = eng.step()
    assert n >= 1 and h.status == "rejected"
    assert h.finish_reason == "deadline_exceeded"
    assert h.ttft is None                    # never burned a prefill
    assert eng.n_expired == 1
    # an explicit per-request deadline overrides the class default
    h2 = eng.submit(GenRequest(np.arange(8, dtype=np.int32), 4,
                               deadline_ms=10 ** 6))
    clock.advance(0.2)
    while not h2.done:
        eng.step()
    assert h2.status == "done"
