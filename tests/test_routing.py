"""Unit tests for ElastiFormer routing primitives (Alg. 1 & 2, §B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import routing as R


def test_topk_indices_sorted_causal_order(key):
    scores = jax.random.uniform(key, (4, 64))
    idx = R.topk_indices(scores, 16)
    assert (jnp.diff(idx, axis=-1) > 0).all(), "indices must be ascending"


def test_topk_mask_matches_indices(key):
    scores = jax.random.uniform(key, (4, 64))
    k = 10
    mask = R.topk_mask(scores, k)
    assert (mask.sum(-1) == k).all()
    idx = R.topk_indices(scores, k)
    picked = jnp.take_along_axis(mask, idx, axis=-1)
    assert picked.all()


def test_gather_scatter_roundtrip(key):
    x = jax.random.normal(key, (2, 32, 8))
    idx = R.topk_indices(jax.random.uniform(jax.random.fold_in(key, 1),
                                            (2, 32)), 12)
    sel = R.gather_tokens(x, idx)
    back = R.scatter_add_tokens(x, idx, sel)
    mask = jnp.zeros((2, 32), bool).at[jnp.arange(2)[:, None], idx].set(True)
    np.testing.assert_allclose(back, x * mask[..., None], rtol=1e-6)


def test_param_router_identity_when_all_selected(key):
    """Paper §4.1: k=M with uniform router weights reproduces the base
    module exactly (w == 1 after M*softmax normalization)."""
    d, m = 16, 8
    rp = {"w": jnp.zeros((d, m))}   # uniform logits
    x = jax.random.normal(key, (3, 5, d))
    w, mask, aux = R.param_route_weights(rp, x, top_k=m)
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-6)
    assert mask.all()


def test_param_router_weights_sum_to_m(key):
    d, m = 16, 8
    rp = R.param_router_init(key, d, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 5, d))
    w, _, _ = R.param_route_weights(rp, x, top_k=3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), m, rtol=1e-5)


def test_route_tokens_gather_vs_dense_mask_equivalence(key):
    """Gather and dense-mask implementations are the same math for a
    position-independent module."""
    d = 16
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (d, d)) * 0.1
    f = lambda h, pos: h @ w
    y1, a1 = R.route_tokens(rp, x, f, 0.5, "train", impl="gather")
    y2, a2 = R.route_tokens(rp, x, f, 0.5, "train", impl="dense_mask")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1.topk), float(a2.topk), rtol=1e-5)


def test_route_tokens_gradients_flow_to_router(key):
    d = 8
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, d))
    f = lambda h, pos: jnp.tanh(h)

    def loss(rp):
        y, aux = R.route_tokens(rp, x, f, 0.5, "train")
        return jnp.sum(y ** 2) + aux.topk

    g = jax.grad(loss)(rp)
    assert float(jnp.abs(g["w"]).sum()) > 0, "straight-through grad missing"


def test_infer_threshold_routing(key):
    d = 8
    rp = {"w": jnp.zeros((d,)), "b": jnp.asarray(-10.0)}   # always-off router
    x = jax.random.normal(key, (2, 16, d))
    y, _ = R.route_tokens(rp, x, lambda h, p: jnp.ones_like(h), 0.5, "infer")
    np.testing.assert_allclose(np.asarray(y), 0.0)
    rp_on = {"w": jnp.zeros((d,)), "b": jnp.asarray(10.0)}  # always-on
    y, _ = R.route_tokens(rp_on, x, lambda h, p: jnp.ones_like(h), 0.5, "infer")
    assert float(jnp.abs(y).min()) > 0.99


def test_bce_topk_loss_direction(key):
    logits = jnp.asarray([[-5.0, 5.0, -5.0, 5.0]])
    good = jnp.asarray([[False, True, False, True]])
    bad = ~good
    assert float(R.bce_topk_loss(logits, good)) < float(
        R.bce_topk_loss(logits, bad))


def test_load_balance_penalizes_collapse():
    """Switch-style load loss: collapsed routing (all tokens -> expert 0)
    must score higher than a decisively balanced router."""
    m = 4
    x = jnp.eye(m).repeat(16, axis=0) * 10.0          # (64, 4), rotating
    collapsed = {"w": jnp.zeros((m, m)).at[:, 0].set(1.0)}
    balanced = {"w": jnp.eye(m)}                      # token i -> expert i
    _, _, a_col = R.param_route_weights(collapsed, x, top_k=1)
    _, _, a_bal = R.param_route_weights(balanced, x, top_k=1)
    assert float(a_col.load) > float(a_bal.load)
