"""Unit tests for ElastiFormer routing primitives (Alg. 1 & 2, §B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import routing as R


def test_topk_indices_sorted_causal_order(key):
    scores = jax.random.uniform(key, (4, 64))
    idx = R.topk_indices(scores, 16)
    assert (jnp.diff(idx, axis=-1) > 0).all(), "indices must be ascending"


def test_topk_mask_matches_indices(key):
    scores = jax.random.uniform(key, (4, 64))
    k = 10
    mask = R.topk_mask(scores, k)
    assert (mask.sum(-1) == k).all()
    idx = R.topk_indices(scores, k)
    picked = jnp.take_along_axis(mask, idx, axis=-1)
    assert picked.all()


def test_gather_scatter_roundtrip(key):
    x = jax.random.normal(key, (2, 32, 8))
    idx = R.topk_indices(jax.random.uniform(jax.random.fold_in(key, 1),
                                            (2, 32)), 12)
    sel = R.gather_tokens(x, idx)
    back = R.scatter_add_tokens(x, idx, sel)
    mask = jnp.zeros((2, 32), bool).at[jnp.arange(2)[:, None], idx].set(True)
    np.testing.assert_allclose(back, x * mask[..., None], rtol=1e-6)


def test_param_router_identity_when_all_selected(key):
    """Paper §4.1: k=M with uniform router weights reproduces the base
    module exactly (w == 1 after M*softmax normalization)."""
    d, m = 16, 8
    rp = {"w": jnp.zeros((d, m))}   # uniform logits
    x = jax.random.normal(key, (3, 5, d))
    w, mask, aux = R.param_route_weights(rp, x, top_k=m)
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-6)
    assert mask.all()


def test_param_router_weights_sum_to_m(key):
    d, m = 16, 8
    rp = R.param_router_init(key, d, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 5, d))
    w, _, _ = R.param_route_weights(rp, x, top_k=3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), m, rtol=1e-5)


def test_route_tokens_gather_vs_dense_mask_equivalence(key):
    """Gather and dense-mask implementations are the same math for a
    position-independent module."""
    d = 16
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (d, d)) * 0.1
    f = lambda h, pos: h @ w
    y1, a1 = R.route_tokens(rp, x, f, 0.5, "train", impl="gather")
    y2, a2 = R.route_tokens(rp, x, f, 0.5, "train", impl="dense_mask")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1.topk), float(a2.topk), rtol=1e-5)


def test_route_tokens_gradients_flow_to_router(key):
    d = 8
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, d))
    f = lambda h, pos: jnp.tanh(h)

    def loss(rp):
        y, aux = R.route_tokens(rp, x, f, 0.5, "train")
        return jnp.sum(y ** 2) + aux.topk

    g = jax.grad(loss)(rp)
    assert float(jnp.abs(g["w"]).sum()) > 0, "straight-through grad missing"


def test_infer_threshold_routing(key):
    d = 8
    rp = {"w": jnp.zeros((d,)), "b": jnp.asarray(-10.0)}   # always-off router
    x = jax.random.normal(key, (2, 16, d))
    y, _ = R.route_tokens(rp, x, lambda h, p: jnp.ones_like(h), 0.5, "infer")
    np.testing.assert_allclose(np.asarray(y), 0.0)
    rp_on = {"w": jnp.zeros((d,)), "b": jnp.asarray(10.0)}  # always-on
    y, _ = R.route_tokens(rp_on, x, lambda h, p: jnp.ones_like(h), 0.5, "infer")
    assert float(jnp.abs(y).min()) > 0.99


def test_bce_topk_loss_direction(key):
    logits = jnp.asarray([[-5.0, 5.0, -5.0, 5.0]])
    good = jnp.asarray([[False, True, False, True]])
    bad = ~good
    assert float(R.bce_topk_loss(logits, good)) < float(
        R.bce_topk_loss(logits, bad))


def test_capacity_buckets_and_bucket_for():
    """Bucket sizes are distinct, increasing, aligned, and end at S."""
    bks = R.capacity_buckets(4096)
    assert bks == (1024, 2048, 3072, 4096)
    assert all(b % 128 == 0 for b in bks)
    small = R.capacity_buckets(24)
    assert small[-1] == 24 and len(small) <= 4
    assert list(small) == sorted(set(small))
    for s in (24, 256, 1000, 4096):
        for k in (1, s // 3, s - 1, s):
            b = R.bucket_for(k, s)
            assert k <= b <= s
            # smallest covering bucket
            assert all(bb >= b or bb < k for bb in R.capacity_buckets(s))


def test_ragged_select_partition(key):
    """Prefix = the exact top-k token set in ascending position order."""
    scores = jax.random.uniform(key, (3, 40))
    k, bucket = 13, 20
    idx, valid, count = R.ragged_select(scores, k, bucket)
    assert count == k and idx.shape == (3, bucket)
    assert bool(valid[:, :k].all()) and not bool(valid[:, k:].any())
    pref = np.asarray(idx[:, :k])
    assert (np.diff(pref, axis=-1) > 0).all(), "prefix must be causal order"
    topk = np.asarray(R.topk_indices(scores, k))
    np.testing.assert_array_equal(pref, topk)
    # tail holds distinct non-selected tokens (scatter-safe)
    full = np.asarray(idx)
    assert all(len(set(r)) == bucket for r in full)
    # traced per-row k
    kb = jnp.asarray([5.0, 13.0, 20.0])
    idx2, valid2, count2 = R.ragged_select(scores, kb, bucket)
    np.testing.assert_array_equal(np.asarray(count2), [5, 13, 20])
    np.testing.assert_array_equal(
        np.asarray(valid2.sum(-1)), [5, 13, 20])
    np.testing.assert_array_equal(
        np.asarray(idx2[1, :13]), topk[1])


def _route_setup(key, d=16, s=24, b=2):
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (d, d)) * 0.1
    return rp, x, (lambda h, pos: h @ w)


def test_route_tokens_ragged_matches_gather_and_dense(key):
    """All three execution paths select the same tokens and weights."""
    rp, x, f = _route_setup(key)
    # 0.4 sits off the bucket grid (k=10 < bucket=12): non-empty tail
    for cap in (0.25, 0.4, 0.5, 0.75):
        y_g, a_g = R.route_tokens(rp, x, f, cap, "train", impl="gather")
        y_d, a_d = R.route_tokens(rp, x, f, cap, "train", impl="dense_mask")
        y_r, a_r = R.route_tokens(rp, x, f, cap, "train", impl="ragged")
        np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_g),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_d),
                                   atol=1e-5)
        np.testing.assert_allclose(float(a_r.topk), float(a_g.topk),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(a_r.sel_rate), float(a_d.sel_rate),
                                   rtol=1e-5)


def test_route_tokens_ragged_traced_capacity_and_bucket(key):
    """A traced capacity + static bucket reproduces the static compile,
    including per-request (B,) mixed budgets in one batch."""
    rp, x, f = _route_setup(key)
    y_s, _ = R.route_tokens(rp, x, f, 0.5, "train", impl="ragged")
    y_t, _ = R.route_tokens(rp, x, f, jnp.asarray(0.5), "train",
                            impl="ragged", bucket=12)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_t), atol=1e-5)
    # traced capacity with NO bucket falls back to the dense path (same math)
    y_nb, _ = R.route_tokens(rp, x, f, jnp.asarray(0.5), "train",
                             impl="ragged")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_nb), atol=1e-5)
    # per-request budgets: each row matches its own static run
    caps = jnp.asarray([0.25, 0.75])
    y_b, _ = R.route_tokens(rp, x, f, caps, "train", impl="ragged",
                            bucket=18)
    for i, c in enumerate((0.25, 0.75)):
        y_i, _ = R.route_tokens(rp, x[i:i + 1], f, float(c), "train",
                                impl="ragged")
        np.testing.assert_allclose(np.asarray(y_b[i:i + 1]),
                                   np.asarray(y_i), atol=1e-5)


def test_route_tokens_ragged_gradients_flow(key):
    """Straight-through grads reach the router through the bucket gather."""
    d = 8
    rp = R.token_router_init(key, d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, d))
    f = lambda h, pos: jnp.tanh(h)

    def loss(rp, impl, bucket=None, cap=0.5):
        y, aux = R.route_tokens(rp, x, f, cap, "train", impl=impl,
                                bucket=bucket)
        return jnp.sum(y ** 2) + aux.topk

    g_r = jax.grad(loss)(rp, "ragged")
    g_g = jax.grad(loss)(rp, "gather")
    assert float(jnp.abs(g_r["w"]).sum()) > 0
    np.testing.assert_allclose(np.asarray(g_r["w"]), np.asarray(g_g["w"]),
                               atol=1e-5)
    g_t = jax.grad(loss)(rp, "ragged", 8, jnp.asarray(0.5))
    np.testing.assert_allclose(np.asarray(g_r["w"]), np.asarray(g_t["w"]),
                               atol=1e-5)


def test_param_route_weights_valid_mask_excludes_tail(key):
    """Ragged tail rows must not contribute to the load-balance aux."""
    d, m = 16, 4
    rp = R.param_router_init(key, d, m)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))
    pad = jnp.concatenate([x, 100.0 * jnp.ones((2, 4, d))], axis=1)
    valid = jnp.arange(12)[None, :] < 8
    _, _, a_ref = R.param_route_weights(rp, x, top_k=2)
    _, _, a_msk = R.param_route_weights(rp, pad, top_k=2,
                                        valid=jnp.broadcast_to(valid, (2, 12)))
    np.testing.assert_allclose(float(a_msk.load), float(a_ref.load),
                               rtol=1e-5)
    _, _, a_bad = R.param_route_weights(rp, pad, top_k=2)
    assert abs(float(a_bad.load) - float(a_ref.load)) > 1e-6


def test_load_balance_penalizes_collapse():
    """Switch-style load loss: collapsed routing (all tokens -> expert 0)
    must score higher than a decisively balanced router."""
    m = 4
    x = jnp.eye(m).repeat(16, axis=0) * 10.0          # (64, 4), rotating
    collapsed = {"w": jnp.zeros((m, m)).at[:, 0].set(1.0)}
    balanced = {"w": jnp.eye(m)}                      # token i -> expert i
    _, _, a_col = R.param_route_weights(collapsed, x, top_k=1)
    _, _, a_bal = R.param_route_weights(balanced, x, top_k=1)
    assert float(a_col.load) > float(a_bal.load)
