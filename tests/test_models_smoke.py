"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a reduced same-family config and runs forward + one train
step on CPU, asserting output shapes and no NaNs; plus decode-vs-forward
consistency in base mode (the strongest correctness check for the
cache/ring-buffer machinery)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_elastic
from repro.models import (cache_init, decode_step, forward, model_init,
                          prefill, router_init)
from repro.training import init_train_state, make_train_step
from tests.conftest import f32


def _batch(key, cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.n_image_tokens, cfg.d_frontend))
    if cfg.encoder is not None:
        e = cfg.encoder
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 8), (B, e.encoder_seq,
                                         e.d_frontend or e.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch, key):
    cfg = f32(get_config(arch, "smoke"))
    ecfg = get_elastic(arch, cfg)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    B, S = 2, 32
    batch = _batch(key, cfg, B, S)
    logits_t, _ = forward(params, None, batch, cfg, ecfg, mode="base")
    assert logits_t.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits_t).any())
    logits_s, aux = forward(params, rp, batch, cfg, ecfg, mode="train")
    assert logits_s.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits_s).any())
    step = make_train_step(cfg, ecfg, lr=1e-3, chunked=True)
    state = init_train_state(rp)
    state, m = jax.jit(step)(state, params, batch)
    assert np.isfinite(m["loss"]), (arch, m)
    assert float(m["grad_norm"]) > 0, "router gradients must be nonzero"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward_base_mode(arch, key):
    """Prefill + N decode steps must reproduce the full-sequence forward
    logits position-by-position in teacher mode (exercises KV ring caches,
    SSM/RG-LRU state hand-off, cross-attn caches)."""
    cfg = f32(get_config(arch, "smoke"))
    if cfg.moe is not None:
        # full-capacity dispatch: decode is exact top-k, so the full-seq
        # reference must not drop tokens (capacity drops are a documented
        # training-efficiency tradeoff, not a serving semantic)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = model_init(key, cfg, None)
    B, S, n_dec = 2, 24, 6
    batch = _batch(key, cfg, B, S)
    full_logits, _ = forward(params, None, batch, cfg, None, mode="base")

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - n_dec]
    logits, caches = prefill(params, None, pre, cfg, None, mode="base",
                             max_cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, S - n_dec - 1]),
        atol=2e-3, rtol=1e-3, err_msg=f"{arch}: prefill logits mismatch")
    for i in range(n_dec):
        t = S - n_dec + i
        tok = batch["tokens"][:, t:t + 1]
        logits, caches = decode_step(params, None, tok, caches,
                                     jnp.int32(t), cfg, None, mode="base")
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=1e-3,
            err_msg=f"{arch}: decode step {i} mismatch")


@pytest.mark.parametrize("arch", ["gemma3-27b", "recurrentgemma-2b"])
def test_ring_cache_window_decode(arch, key):
    """Decode far past the window: ring cache must keep producing finite,
    position-consistent outputs (window entries evicted correctly)."""
    cfg = f32(get_config(arch, "smoke"))
    params = model_init(key, cfg, None)
    B, S = 1, 8
    batch = _batch(key, cfg, B, S)
    logits, caches = prefill(params, None, batch, cfg, None, mode="base",
                             max_cache_len=64)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(S, S + 40):   # run past window=16 on the smoke config
        logits, caches = decode_step(params, None, tok, caches,
                                     jnp.int32(t), cfg, None, mode="base")
        assert bool(jnp.isfinite(logits).all()), (arch, t)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_student_infer_mode_runs(arch, key):
    cfg = f32(get_config(arch, "smoke"))
    ecfg = get_elastic(arch, cfg)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    batch = _batch(key, cfg)
    logits, caches = prefill(params, rp, batch, cfg, ecfg, mode="infer",
                             max_cache_len=40)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits, _ = decode_step(params, rp, tok, caches, jnp.int32(32), cfg,
                            ecfg, mode="infer")
    assert bool(jnp.isfinite(logits).all())


def test_even_layer_mode(key):
    """Paper §5.2: ElastiFormer on even layers only."""
    cfg = f32(get_config("qwen2-7b", "smoke"))
    ecfg = dataclasses.replace(get_elastic("qwen2-7b", cfg), layers="even")
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    batch = _batch(key, cfg)
    logits, aux = forward(params, rp, batch, cfg, ecfg, mode="train")
    assert bool(jnp.isfinite(logits).all())
    # fewer layers routed -> smaller aux than all-layers (params re-stacked
    # per mode: pattern period differs, weights identical per layer)
    ecfg_all = dataclasses.replace(ecfg, layers="all")
    params_all = model_init(key, cfg, ecfg_all)
    rp_all = router_init(jax.random.fold_in(key, 1), cfg, ecfg_all)
    _, aux_all = forward(params_all, rp_all, batch, cfg, ecfg_all,
                         mode="train")
    assert float(aux.topk) < float(aux_all.topk)
