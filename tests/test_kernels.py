"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (ref.py),
executed in interpret mode on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_mlp import fused_mlp, fused_mlp_routed
from repro.kernels.moe_gmm import moe_gmm

TOLS = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
        jnp.bfloat16: dict(atol=5e-2, rtol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,K,Dh", [
    (1, 128, 128, 4, 4, 64),     # MHA square
    (2, 256, 256, 8, 2, 64),     # GQA 4:1
    (1, 64, 320, 4, 1, 128),     # MQA, ragged Sk (block padding path)
    (1, 384, 128, 4, 4, 128),    # Sq > Sk
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 96), (False, 0)])
def test_flash_attention_sweep(B, Sq, Sk, H, K, Dh, causal, window, dtype, key):
    if causal and Sq > Sk:
        pytest.skip("causal requires Sq <= Sk alignment in this harness")
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, Dh), dtype)
    valid = jax.random.bernoulli(ks[3], 0.9, (B, Sk))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          kv_valid=valid, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   kv_valid=valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D,F,gated,act", [
    (256, 128, 512, True, "swiglu"),
    (100, 128, 384, True, "geglu"),      # ragged T
    (512, 256, 1024, False, "gelu"),
])
def test_fused_mlp_sweep(T, D, F, gated, act, dtype, key):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, D), dtype)
    wi = (jax.random.normal(ks[1], (D, F)) * 0.05).astype(dtype)
    wo = (jax.random.normal(ks[2], (F, D)) * 0.05).astype(dtype)
    wg = (jax.random.normal(ks[3], (D, F)) * 0.05).astype(dtype) if gated else None
    tw = jax.random.uniform(ks[4], (T,))
    got = fused_mlp(x, wi, wo, wg, tw, act=act, interpret=True)
    want = ref.fused_mlp_ref(x, wi, wo, wg, tw, act=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,Fe,gated", [
    (4, 128, 128, 256, True),
    (8, 96, 64, 128, False),     # ragged C
    (2, 256, 128, 512, True),
])
def test_moe_gmm_sweep(E, C, D, Fe, gated, dtype, key):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    wi = (jax.random.normal(ks[1], (E, D, Fe)) * 0.05).astype(dtype)
    wo = (jax.random.normal(ks[2], (E, Fe, D)) * 0.05).astype(dtype)
    wg = (jax.random.normal(ks[3], (E, D, Fe)) * 0.05).astype(dtype) if gated else None
    w = jax.random.uniform(ks[4], (E, C))
    got = moe_gmm(x, wi, wo, wg, w, act="swiglu", interpret=True)
    want = ref.moe_gmm_ref(x, wi, wo, wg, w, act="swiglu")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("count", [1, 100, 130, 256])
def test_flash_attention_kv_count_ragged(count, key):
    """Traced valid-token count: keys/queries past it are skipped/zeroed."""
    B, S, H, K, Dh = 2, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, K, Dh))
    v = jax.random.normal(ks[2], (B, S, K, Dh))
    got = flash_attention(q, k, v, causal=True, kv_count=jnp.int32(count),
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, kv_count=count)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-5)
    assert not np.asarray(got[:, count:]).any(), "tail rows must be zero"
    # the count is a hard prefix: it must equal full attention on the prefix
    full = ref.flash_attention_ref(q[:, :count], k[:, :count], v[:, :count],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(got[:, :count], np.float32),
                               np.asarray(full, np.float32), atol=2e-5)


def test_flash_attention_per_row_kv_count(key):
    """(B,) counts: every batch row is cut at its own prefix length."""
    B, S, H, K, Dh = 3, 256, 4, 4, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, K, Dh))
    v = jax.random.normal(ks[2], (B, S, K, Dh))
    cnt = jnp.asarray([7, 130, 256], jnp.int32)
    got = flash_attention(q, k, v, causal=True, window=96, kv_count=cnt,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=96,
                                   kv_count=cnt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-5)


@pytest.mark.parametrize("count", [1, 100, 256, 300])
def test_fused_mlp_valid_count_ragged(count, key):
    T, D, F = 300, 64, 256
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, D))
    wi = (jax.random.normal(ks[1], (D, F)) * 0.05)
    wo = (jax.random.normal(ks[2], (F, D)) * 0.05)
    wg = (jax.random.normal(ks[3], (D, F)) * 0.05)
    tw = jax.random.uniform(ks[4], (T,))
    got = fused_mlp(x, wi, wo, wg, tw, act="swiglu",
                    valid_count=jnp.int32(count), interpret=True)
    want = ref.fused_mlp_ref(x, wi, wo, wg, tw, act="swiglu",
                             valid_count=count)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    assert not np.asarray(got[count:]).any()


def test_moe_gmm_group_counts_ragged(key):
    """(E,) per-expert occupancy: capacity slots past it are zeroed."""
    E, C, D, Fe = 4, 128, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (E, C, D))
    wi = (jax.random.normal(ks[1], (E, D, Fe)) * 0.05)
    wo = (jax.random.normal(ks[2], (E, Fe, D)) * 0.05)
    w = jax.random.uniform(ks[4], (E, C))
    cnt = jnp.asarray([0, 5, 100, 128], jnp.int32)
    got = moe_gmm(x, wi, wo, None, w, act="gelu", group_counts=cnt,
                  interpret=True)
    want = ref.moe_gmm_ref(x, wi, wo, None, w, act="gelu", group_counts=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for e in range(E):
        assert not np.asarray(got[e, int(cnt[e]):]).any()


def test_fused_mlp_batched_per_row_counts(key):
    """(B, T, D) input with per-row (B,) valid counts: each batch row is
    cut at its own ragged prefix."""
    B, T, D, F = 3, 128, 64, 192
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, D))
    wi = jax.random.normal(ks[1], (D, F)) * 0.05
    wo = jax.random.normal(ks[2], (F, D)) * 0.05
    wg = jax.random.normal(ks[3], (D, F)) * 0.05
    tw = jax.random.uniform(ks[4], (B, T))
    cnt = jnp.asarray([1, 70, 128], jnp.int32)
    got = fused_mlp(x, wi, wo, wg, tw, act="swiglu", valid_count=cnt,
                    interpret=True)
    want = ref.fused_mlp_ref(x, wi, wo, wg, tw, act="swiglu",
                             valid_count=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        assert not np.asarray(got[b, int(cnt[b]):]).any()


@pytest.mark.parametrize("gated", [True, False])
def test_fused_mlp_routed_gather_scatter_fusion(gated, key):
    """Index-prefetch gather/scatter fusion: x stays full (B,S,D), the
    plan indices ride scalar prefetch, the output is the scattered delta —
    rows the plan dropped stay exactly zero."""
    B, S, Kb, D, F = 2, 96, 24, 64, 128
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, D))
    wi = jax.random.normal(ks[1], (D, F)) * 0.05
    wo = jax.random.normal(ks[2], (F, D)) * 0.05
    wg = (jax.random.normal(ks[3], (D, F)) * 0.05) if gated else None
    idx = jnp.stack([jax.random.permutation(
        jax.random.fold_in(ks[4], b), S)[:Kb] for b in range(B)])
    idx = jnp.sort(idx, axis=-1).astype(jnp.int32)
    cnt = jnp.asarray([Kb, 10], jnp.int32)
    tw = jax.random.uniform(ks[5], (B, Kb)) \
        * (jnp.arange(Kb)[None] < cnt[:, None])
    got = fused_mlp_routed(x, idx, wi, wo, wg, tw, act="swiglu",
                           valid_count=cnt, interpret=True)
    want = ref.fused_mlp_routed_ref(x, idx, wi, wo, wg, tw, act="swiglu",
                                    valid_count=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # untouched rows are exact zeros
    touched = np.zeros((B, S), bool)
    for b in range(B):
        touched[b, np.asarray(idx[b, :int(cnt[b])])] = True
    assert not np.asarray(got)[~touched].any()


def test_moe_gmm_batched_group_counts(key):
    """(B, E, C, D) dispatch buffers with (B, E) per-expert occupancy."""
    B, E, C, D, Fe = 2, 4, 64, 32, 96
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, E, C, D))
    wi = jax.random.normal(ks[1], (E, D, Fe)) * 0.05
    wo = jax.random.normal(ks[2], (E, Fe, D)) * 0.05
    wg = jax.random.normal(ks[3], (E, D, Fe)) * 0.05
    w = jax.random.uniform(ks[4], (B, E, C))
    cnt = jnp.asarray([[0, 5, 33, 64], [64, 1, 0, 17]], jnp.int32)
    got = moe_gmm(x, wi, wo, wg, w, act="swiglu", group_counts=cnt,
                  interpret=True)
    want = ref.moe_gmm_ref(x, wi, wo, wg, w, act="swiglu", group_counts=cnt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    for b in range(B):
        for e in range(E):
            assert not np.asarray(got[b, e, int(cnt[b, e]):]).any()


@pytest.mark.parametrize("window,block_k", [
    (0, 128), (24, 128),
    # block_k < L: exercises the cross-block online-softmax carry,
    # including blocks an aggressive window masks out ENTIRELY (their
    # poisoned p=1 contributions must be annihilated by the alpha rescale)
    (0, 16), (8, 16),
])
def test_decode_attention_ring_cache(window, block_k, key):
    """Ring-cache decode kernel vs the jnp oracle: staggered per-slot
    positions, wrapped ring slots, empty (-1) and invalid entries."""
    B, L, H, K, Dh = 3, 64, 4, 2, 32
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, L, K, Dh))
    v = jax.random.normal(ks[2], (B, L, K, Dh))
    t = jnp.asarray([5, 63, 150], jnp.int32)       # row 2 wrapped the ring
    slots = jnp.arange(L)[None, :]
    pos = jnp.where(slots <= t[:, None] % L, t[:, None] - t[:, None] % L,
                    t[:, None] - t[:, None] % L - L) + slots
    pos = jnp.where(pos >= 0, pos, -1).astype(jnp.int32)
    valid = jax.random.bernoulli(ks[3], 0.85, (B, L))
    got = decode_attention(q, k, v, pos, t, window=window, kv_valid=valid,
                           block_k=block_k, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, t, window=window,
                                    kv_valid=valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_blocked_sdpa(key):
    """The Pallas kernel, the blocked jnp path, and the dense path agree."""
    from repro.models.attention import blocked_sdpa, sdpa, _mask
    B, S, H, K, Dh = 1, 256, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, K, Dh))
    v = jax.random.normal(ks[2], (B, S, K, Dh))
    pos = jnp.arange(S)
    dense = sdpa(q, k, v, _mask(pos, pos, True, 0))
    blocked = blocked_sdpa(q, k, v, pos[None], pos[None], True, 0, block=64)
    kernel = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(blocked),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(kernel),
                               atol=2e-5)
