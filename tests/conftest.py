import dataclasses

import jax
import pytest

# Tests run on the single real CPU device; only launch/dryrun.py (run as its
# own process) uses the 512 fake devices. Keep x64 off (match TPU numerics).
jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def f32(cfg):
    """Smoke configs in float32 for tight numeric comparisons on CPU."""
    new = dataclasses.replace(cfg, dtype="float32")
    if cfg.encoder is not None:
        new = dataclasses.replace(
            new, encoder=dataclasses.replace(cfg.encoder, dtype="float32"))
    return new
