"""Elastic depth: per-token whole-layer skip routing (ISSUE 10).

Covers the acceptance properties:
  * depth budget 1.0 is the bit-exact teacher in train AND decode (the
    IDENTITY fast path holds with the depth router live);
  * composed depth x token budgets lower lowered FLOPs monotonically and
    multiplicatively (hloprof — the cost the CI bench gate asserts on);
  * the ragged depth execution path matches the dense rank-masked
    reference, including mixed per-request (B,) depth budgets;
  * staggered-slot decode == solo decode with per-layer KV-validity masks
    (a slot that skipped a layer wrote NO KV there; the masks keep other
    slots' attention exact) on BOTH cache layouts;
  * compile_counts() stays {prefill: 1, decode: 1} while the SLO
    controller degrades the depth budget live.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ElasticConfig
from repro.configs.elasti_toy import toy_lm
from repro.core.policy import (ElasticPolicy, ElasticSpec, ragged_bucket,
                               spec_from_config)
from repro.core.routing import IDENTITY_BUCKET
from repro.launch.hloprof import lowered_flops
from repro.models import forward, model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32

DEPTH_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                depth_capacity=0.75, lora_rank=1)


def _setup(key, s=24, **ecfg_kw):
    cfg = f32(toy_lm())
    ecfg = ElasticConfig(**ecfg_kw)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, s), dtype=np.int32))}
    return cfg, ecfg, params, rp, batch


# --------------------------- bit-exact teacher -------------------------------

def test_depth_budget_one_is_bit_exact_teacher_train(key):
    cfg, ecfg, params, rp, batch = _setup(key, **DEPTH_KW)
    spec = spec_from_config(ecfg)
    assert spec.depth_routed
    teacher, _ = forward(params, None, batch, cfg, None, mode="base")
    for pol in (ElasticPolicy.uniform(1.0), ElasticPolicy.teacher()):
        out, _ = forward(params, rp, batch, cfg, spec, mode="train",
                         policy=pol)
        np.testing.assert_allclose(np.asarray(out), np.asarray(teacher),
                                   atol=1e-5)
    # full budget still resolves the IDENTITY sentinel with depth routed...
    assert ragged_bucket(ElasticPolicy.uniform(1.0), 24,
                         spec=spec) == IDENTITY_BUCKET
    out, _ = forward(params, rp, batch, cfg, spec, mode="train",
                     policy=jax.tree.map(jnp.asarray,
                                         ElasticPolicy.uniform(1.0)),
                     bucket=IDENTITY_BUCKET)
    np.testing.assert_allclose(np.asarray(out), np.asarray(teacher),
                               atol=1e-5)
    # ...but a partial DEPTH budget at full token budget must NOT: the
    # block plan capacity composes multiplicatively (depth * token), so
    # depth 0.5 lands on a half-size bucket, not the identity graph
    part = ElasticPolicy.uniform(1.0).replace(depth_capacity=0.5)
    assert ragged_bucket(part, 24, spec=spec) not in (IDENTITY_BUCKET, None)


def test_depth_budget_one_is_bit_exact_teacher_decode(key):
    cfg, ecfg, params, rp, _ = _setup(key, **DEPTH_KW)
    rng = np.random.default_rng(2)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                       6, budget=1.0) for _ in range(2)]
    base = ServingEngine(params, rp, cfg, ecfg, mode="base",
                         batch_size=2, max_seq=24)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                        batch_size=2, max_seq=24)
    for got, want in zip(eng.generate(reqs), base.generate(reqs)):
        np.testing.assert_array_equal(got, want)


# ------------------------ FLOP composition (hloprof) -------------------------

def test_depth_composed_flops_monotone(key):
    """Lowered FLOPs must track the depth budget, compose multiplicatively
    with the token budget, and leave the dense reference flat."""
    cfg = f32(toy_lm(vocab=256))
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True,
                       depth_routed=True)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32)}

    def flops_at(sp, depth, token):
        pol = ElasticPolicy.uniform(token, static=True).replace(
            depth_capacity=depth)
        return lowered_flops(
            lambda rp, b: forward(params, rp, b, cfg, sp, mode="train",
                                  policy=pol)[0], rp, batch)

    fl = {d: flops_at(spec, d, 1.0) for d in (1.0, 0.75, 0.5, 0.25)}
    assert fl[1.0] > fl[0.75] > fl[0.5] > fl[0.25], fl
    assert fl[0.5] <= 0.6 * fl[1.0], fl
    # composition: depth x token multiplies into the plan capacity, so the
    # composed cell sits strictly below either single knob once the product
    # crosses a bucket boundary (0.5 x 0.5 = 0.25 -> the quarter bucket)
    both = flops_at(spec, 0.5, 0.5)
    assert both < fl[0.5]
    assert both < flops_at(spec, 1.0, 0.5)
    # the dense reference path stays flat — the gap depth exists to close
    dense = dataclasses.replace(spec, routing_impl="dense_mask")
    fd = {d: flops_at(dense, d, 1.0) for d in (1.0, 0.5)}
    assert fd[0.5] > 0.95 * fd[1.0], fd


# ------------------------- execution-path parity -----------------------------

@pytest.mark.parametrize("depth", [0.4, 0.6, 0.75])
def test_depth_ragged_matches_dense(key, depth):
    cfg, ecfg, params, rp, batch = _setup(key, **DEPTH_KW)
    spec = spec_from_config(ecfg)
    dense = dataclasses.replace(spec, routing_impl="dense_mask")
    pol = jax.tree.map(jnp.asarray,
                       ElasticPolicy.uniform(0.8).replace(
                           depth_capacity=depth))
    s = batch["tokens"].shape[1]
    l_r, _ = forward(params, rp, batch, cfg, spec, mode="train", policy=pol,
                     bucket=ragged_bucket(pol, s, spec=spec))
    l_d, _ = forward(params, rp, batch, cfg, dense, mode="train", policy=pol)
    np.testing.assert_allclose(np.asarray(l_r), np.asarray(l_d), atol=1e-4)


def test_depth_mixed_per_request_budgets_match_solo_rows(key):
    """One (B,)-policy ragged batch with per-row DEPTH budgets reproduces
    each row's own smaller-bucket compile exactly."""
    cfg, ecfg, params, rp, batch = _setup(key, **DEPTH_KW)
    spec = spec_from_config(ecfg)
    s = batch["tokens"].shape[1]
    pols = [ElasticPolicy.uniform(0.75).replace(depth_capacity=d)
            for d in (0.5, 1.0)]
    mixed = ElasticPolicy.stack(pols)
    l_m, _ = forward(params, rp, batch, cfg, spec, mode="train",
                     policy=mixed, bucket=ragged_bucket(mixed, s, spec=spec))
    for i, pol in enumerate(pols):
        row = jax.tree.map(jnp.asarray, pol)
        l_i, _ = forward(params, rp, {"tokens": batch["tokens"][i:i + 1]},
                         cfg, spec, mode="train", policy=row,
                         bucket=ragged_bucket(row, s, spec=spec))
        np.testing.assert_allclose(np.asarray(l_m[i:i + 1]),
                                   np.asarray(l_i), atol=1e-4)


# ------------------------------- serving -------------------------------------

def _staggered_vs_solo(key, kv_layout, plen, **engine_kw):
    cfg, ecfg, params, rp, _ = _setup(key, **DEPTH_KW)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
               for _ in range(4)]
    reqs = [GenRequest(p, 6, budget=b)
            for p, b in zip(prompts, (0.4, 0.7, 1.0, None))]
    solo = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=2,
                         max_seq=24, kv_layout=kv_layout, **engine_kw)
    oracle = [solo.generate([r])[0] for r in reqs]
    # staggered admissions: slots sit at different t AND different
    # per-layer skip histories — each slot's per-layer KV-validity mask
    # must keep its neighbors' attention exact
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                        max_seq=24, kv_layout=kv_layout, **engine_kw)
    h0 = eng.submit(reqs[0])
    eng.step(); eng.step()            # r0 is 2 tokens in when r1 lands
    h1 = eng.submit(reqs[1])
    eng.step()
    h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
    handles = [h0, h1, h2, h3]
    while not all(h.done for h in handles):
        eng.step()
    for h, o in zip(handles, oracle):
        np.testing.assert_array_equal(np.asarray(h.output), o)
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}, \
        eng.compile_counts()


def test_depth_staggered_decode_matches_solo_ring(key):
    _staggered_vs_solo(key, "ring", plen=8)


def test_depth_staggered_decode_matches_solo_paged(key):
    _staggered_vs_solo(key, "paged", plen=12, page_size=8)


def test_depth_controller_degrades_live_with_flat_compiles(key):
    """The degrade ladder's depth stage moves the live depth budget; new
    admissions AND in-flight rows pick it up with zero recompiles, and
    budget_served reflects the composed (budget x depth) cost."""
    from repro.runtime.controller import SLOController, SLOTarget
    cfg, ecfg, params, rp, _ = _setup(key, **DEPTH_KW)
    ctrl = SLOController(targets={"default": SLOTarget(p95_ttft_ms=500.0)},
                         floor=0.25)
    eng = ServingEngine(params, rp, cfg, ecfg, mode="infer", batch_size=4,
                        max_seq=24, controller=ctrl)
    rng = np.random.default_rng(4)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                       8, budget=0.8) for _ in range(4)]
    h0, h1 = eng.submit(reqs[0]), eng.submit(reqs[1])
    eng.step(); eng.step()
    # controller degrades depth mid-flight (what the ladder's depth stage
    # does on a breach): in-flight rows splice, new admissions compose
    ctrl.depth_budget = 0.5
    eng.step()
    h2, h3 = eng.submit(reqs[2]), eng.submit(reqs[3])
    handles = [h0, h1, h2, h3]
    while not all(h.done for h in handles):
        eng.step()
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}, \
        eng.compile_counts()
    assert all(len(h.output) == 8 for h in handles)
    # admissions after the degrade serve the composed cost
    assert h2.budget_served == pytest.approx(0.8 * 0.5)
    # restore: later admissions return to the full-depth cost
    ctrl.depth_budget = 1.0
    h4 = eng.submit(reqs[0])
    while not h4.done:
        eng.step()
    assert h4.budget_served == pytest.approx(0.8)
    assert eng.compile_counts() == {"prefill": 1, "decode": 1}
