"""Integration: distillation training improves the student; fault-tolerant
loop restores deterministically; data pipeline contracts."""
import numpy as np
import pytest

from repro.data import LMDataPipeline
from repro.launch.train import train
from repro.runtime import StragglerWatchdog


def test_pipeline_determinism_and_shard_disjointness():
    a = LMDataPipeline(vocab=128, seq_len=16, global_batch=8, seed=1)
    b = LMDataPipeline(vocab=128, seq_len=16, global_batch=8, seed=1)
    np.testing.assert_array_equal(a.batch_at(5), b.batch_at(5))
    s0 = LMDataPipeline(vocab=128, seq_len=16, global_batch=8,
                        n_shards=2, shard=0, seed=1)
    s1 = LMDataPipeline(vocab=128, seq_len=16, global_batch=8,
                        n_shards=2, shard=1, seed=1)
    assert not np.array_equal(s0.batch_at(0), s1.batch_at(0))
    assert s0.batch_at(0).shape == (4, 16)


def test_pipeline_state_restore():
    p = LMDataPipeline(vocab=64, seq_len=8, global_batch=4, seed=3)
    for _ in range(4):
        next(p)
    st = p.state()
    want = next(p)
    q = LMDataPipeline(vocab=64, seq_len=8, global_batch=4, seed=3)
    q.restore(st)
    np.testing.assert_array_equal(next(q), want)


def test_distillation_reduces_loss(tmp_path):
    _, metrics, restarts, _ = train(
        "toy-lm", variant="smoke", total_steps=30, seq_len=32,
        global_batch=4, lr=3e-3, ckpt_dir=str(tmp_path), save_every=10)
    assert restarts == 0
    assert np.isfinite(metrics["loss"])


def test_fault_tolerant_restart_is_deterministic(tmp_path):
    """Run with injected failures; final metrics must equal a clean run
    (checkpoint + deterministic data replay = bitwise recovery)."""
    _, clean, r0, _ = train(
        "toy-lm", variant="smoke", total_steps=24, seq_len=16,
        global_batch=4, lr=1e-3, ckpt_dir=str(tmp_path / "clean"),
        save_every=8)
    assert r0 == 0
    _, faulty, r1, _ = train(
        "toy-lm", variant="smoke", total_steps=24, seq_len=16,
        global_batch=4, lr=1e-3, ckpt_dir=str(tmp_path / "faulty"),
        save_every=8, inject_failures=(11, 19))
    assert r1 == 2
    assert clean["loss"] == pytest.approx(faulty["loss"], rel=1e-5), \
        "restart must replay to an identical trajectory"


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=2.0)
    for _ in range(5):
        wd.observe(0, 0.10)
    assert wd.observe(5, 0.50)
    assert len(wd.flagged) == 1
