"""Distillation objectives (paper §4.2 / Fig. 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distill import (cosine_distance, kl_divergence, topk_kl,
                                topk_kl_from_gathered)


def test_kl_zero_on_identical(key):
    logits = jax.random.normal(key, (4, 16, 128))
    for d in ("fwd", "rev"):
        assert float(kl_divergence(logits, logits, direction=d)) < 1e-6
    assert float(topk_kl(logits, logits, k=10)) < 1e-6


def test_kl_positive_and_direction_asymmetric(key):
    a = jax.random.normal(key, (4, 16, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, 64))
    f = float(kl_divergence(a, b, direction="fwd"))
    r = float(kl_divergence(a, b, direction="rev"))
    assert f > 0 and r > 0 and abs(f - r) > 1e-6


def test_topk_kl_approaches_full_kl_for_peaked_teacher(key):
    """When the teacher mass is concentrated in the top-k, the residual
    bucket is negligible and top-k KL ~= full KL."""
    v = 256
    t = jax.random.normal(key, (2, 8, v)) * 0.1
    t = t.at[..., :5].add(12.0)             # teacher peaked on 5 tokens
    s = t + 0.3 * jax.random.normal(jax.random.fold_in(key, 1), t.shape)
    full = float(kl_divergence(s, t, direction="fwd"))
    tk = float(topk_kl(s, t, k=50, direction="fwd"))
    assert abs(full - tk) / max(full, 1e-9) < 0.25


def test_temperature_scaling_softens(key):
    a = jax.random.normal(key, (2, 4, 32)) * 4
    b = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 32)) * 4
    hot = float(kl_divergence(a, b, temp=1.0))
    soft = float(kl_divergence(a, b, temp=4.0))
    assert soft != hot  # temperature changes the objective


def test_cosine_distance_bounds(key):
    x = jax.random.normal(key, (4, 8, 32))
    assert float(cosine_distance(x, x)) < 1e-6
    assert float(cosine_distance(x, -x)) == pytest.approx(2.0, abs=1e-5)


def test_gathered_matches_direct_topk_kl(key):
    logits_t = jax.random.normal(key, (2, 8, 64))
    logits_s = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, 64))
    k = 10
    lt = jax.nn.log_softmax(logits_t, -1)
    ls = jax.nn.log_softmax(logits_s, -1)
    t_top, idx = jax.lax.top_k(lt, k)
    s_top = jnp.take_along_axis(ls, idx, -1)
    a = float(topk_kl(logits_s, logits_t, k=k))
    b = float(topk_kl_from_gathered(s_top, t_top))
    assert a == pytest.approx(b, rel=1e-5)
