"""Ragged capacity-bucket execution (ISSUE 3).

Covers the acceptance properties:
  * FLOP-regression gate: on the toy config, ragged budget-0.5 lowers
    <= 0.7x the FLOPs of budget-1.0, FLOPs decrease monotonically across
    budgets {1.0, 0.75, 0.5, 0.25}, and the dense reference path stays flat
    (the gap this PR exists to close);
  * the three execution paths (ragged / gather / dense) agree on outputs
    and router gradients, across static and traced capacities;
  * per-request (B,) mixed budgets in one ragged batch match per-row runs;
  * budget 1.0 under the ragged default remains the bit-exact teacher;
  * ServingEngine keeps {prefill: 1, decode: 1} compile counts per bucket
    set across mixed budgets with the ragged default spec.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ElasticConfig
from repro.configs.elasti_toy import toy_lm
from repro.core.policy import (ElasticPolicy, ElasticSpec, ragged_bucket,
                               spec_from_config, policy_from_config)
from repro.core.routing import RAGGED_N_BUCKETS, capacity_buckets
from repro.launch.hloprof import lowered_flops
from repro.models import forward, model_init, router_init
from repro.training import GenRequest, ServingEngine
from tests.conftest import f32

N_EXPERTS = 4
FULL_KW = dict(mlp_token_capacity=0.5, mha_token_capacity=0.5,
               mha_head_topk=2, mlp_n_experts=N_EXPERTS, mlp_expert_topk=2,
               lora_rank=1)


def _setup(key, s=24, **ecfg_kw):
    cfg = f32(toy_lm())
    ecfg = ElasticConfig(**ecfg_kw)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, s), dtype=np.int32))}
    return cfg, ecfg, params, rp, batch


# --------------------------- FLOP regression gate ----------------------------

def _flops_at(params, rp, batch, cfg, spec, budget):
    pol = ElasticPolicy.uniform(budget, static=True)
    return lowered_flops(
        lambda rp, b: forward(params, rp, b, cfg, spec, mode="train",
                              policy=pol)[0], rp, batch)


def test_flop_gate_ragged_budget_half_saves_30pct(key):
    """The whole point of the PR: lowered FLOPs must track the budget.
    Guards against silent densification of the ragged path."""
    # small vocab so the (fixed) lm-head matmul doesn't drown the layers
    cfg = f32(toy_lm(vocab=256))
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32)}

    fl = {b: _flops_at(params, rp, batch, cfg, spec, b)
          for b in (1.0, 0.75, 0.5, 0.25)}
    assert fl[0.5] <= 0.7 * fl[1.0], fl
    assert fl[1.0] > fl[0.75] > fl[0.5] > fl[0.25], fl
    # the dense reference path is flat — the gap this refactor closes
    dense = dataclasses.replace(spec, routing_impl="dense_mask")
    fd = {b: _flops_at(params, rp, batch, cfg, dense, b) for b in (1.0, 0.5)}
    assert fd[0.5] > 0.95 * fd[1.0], fd


def test_flop_gate_traced_policy_with_bucket(key):
    """Traced policies + static bucket hint: same FLOP savings, and budgets
    sharing a bucket share ONE compile."""
    cfg = f32(toy_lm(vocab=256))
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32)}

    def fwd(rp, batch, policy, bucket=None):
        return forward(params, rp, batch, cfg, spec, mode="train",
                       policy=policy, bucket=bucket)[0]

    def traced_flops(budget):
        pol = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(budget))
        return lowered_flops(fwd, rp, batch, pol,
                             bucket=ragged_bucket(pol, 256),
                             static_argnames=("bucket",))

    f_half, f_full = traced_flops(0.5), traced_flops(1.0)
    assert f_half <= 0.7 * f_full
    # one jit entry per bucket, not per budget
    jitted = jax.jit(fwd, static_argnames=("bucket",))
    for b in (0.30, 0.40, 0.45, 0.5):   # all land in the same bucket
        pol = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(b))
        jitted(rp, batch, pol, bucket=ragged_bucket(pol, 256))
    assert jitted._cache_size() == 1
    assert len(capacity_buckets(256)) <= RAGGED_N_BUCKETS


# ------------------------- execution-path parity ----------------------------

# 0.4 lands OFF a bucket boundary (k=10 < bucket=12 at s=24): the invalid
# tail is non-empty, exercising the masked-slop regime
@pytest.mark.parametrize("budget", [0.25, 0.4, 0.5, 0.75])
def test_ragged_matches_gather_static(key, budget):
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    kw = dict(mlp_token_capacity=budget, mha_token_capacity=budget,
              mha_head_topk=max(1, round(budget * cfg.n_heads)),
              mlp_n_experts=N_EXPERTS,
              mlp_expert_topk=max(1, round(budget * N_EXPERTS)), lora_rank=1)
    e_r = ElasticConfig(**kw)                              # ragged default
    e_g = dataclasses.replace(e_r, routing_impl="gather")
    l_r, a_r = forward(params, rp, batch, cfg, e_r, mode="train")
    l_g, a_g = forward(params, rp, batch, cfg, e_g, mode="train")
    np.testing.assert_allclose(np.asarray(l_r), np.asarray(l_g), atol=1e-4)
    np.testing.assert_allclose(float(a_r.sel_rate), float(a_g.sel_rate),
                               rtol=1e-5)


def test_ragged_traced_bucket_matches_static_and_dense(key):
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    l_s, _ = forward(params, rp, batch, cfg, ecfg, mode="train")
    pol = jax.tree.map(jnp.asarray, policy_from_config(ecfg))
    s = batch["tokens"].shape[1]
    l_t, _ = forward(params, rp, batch, cfg, spec, mode="train", policy=pol,
                     bucket=ragged_bucket(pol, s))
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_t), atol=1e-4)
    # no bucket hint -> dense rank-masked fallback, same math
    l_d, _ = forward(params, rp, batch, cfg, spec, mode="train", policy=pol)
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_d), atol=1e-4)


def test_ragged_router_grads_match_gather(key):
    # capacity 0.4: k=10 < bucket=12, so aux statistics (load/topk) must
    # exclude the invalid tail to match the gather compile
    cfg, ecfg, params, rp, batch = _setup(
        key, **{**FULL_KW, "mlp_token_capacity": 0.4,
                "mha_token_capacity": 0.4})
    e_g = dataclasses.replace(ecfg, routing_impl="gather")

    def loss(rp, e):
        out, aux = forward(params, rp, batch, cfg, e, mode="train")
        return jnp.sum(out ** 2) * 1e-6 + aux.topk + aux.load

    g_r = jax.grad(loss)(rp, ecfg)
    g_g = jax.grad(loss)(rp, e_g)
    for pr, pg in zip(jax.tree.leaves(g_r), jax.tree.leaves(g_g)):
        np.testing.assert_allclose(np.asarray(pr), np.asarray(pg), atol=1e-4)
    assert sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g_r)) > 0


def test_ragged_mixed_per_request_budgets_match_solo_rows(key):
    """One (B,)-policy ragged batch (bucket covering the largest budget)
    reproduces each row's own smaller-bucket compile exactly."""
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    s = batch["tokens"].shape[1]
    budgets = (0.25, 0.75)
    pols = [ElasticPolicy.uniform(b, n_heads=cfg.n_heads,
                                  n_experts=N_EXPERTS) for b in budgets]
    mixed = ElasticPolicy.stack(pols)
    l_m, _ = forward(params, rp, batch, cfg, spec, mode="train",
                     policy=mixed, bucket=ragged_bucket(mixed, s))
    for i, b in enumerate(budgets):
        row = jax.tree.map(jnp.asarray, pols[i])
        l_i, _ = forward(params, rp, {"tokens": batch["tokens"][i:i + 1]},
                         cfg, spec, mode="train", policy=row,
                         bucket=ragged_bucket(row, s))
        np.testing.assert_allclose(np.asarray(l_m[i:i + 1]),
                                   np.asarray(l_i), atol=1e-4)


def test_ragged_budget_one_is_bit_exact_teacher(key):
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    spec = spec_from_config(ecfg)
    assert spec.routing_impl == "ragged"
    teacher, _ = forward(params, None, batch, cfg, None, mode="base")
    for pol in (ElasticPolicy.uniform(1.0, n_heads=cfg.n_heads,
                                      n_experts=N_EXPERTS),
                ElasticPolicy.teacher()):
        out, _ = forward(params, rp, batch, cfg, spec, mode="train",
                         policy=pol)
        np.testing.assert_allclose(np.asarray(out), np.asarray(teacher),
                                   atol=1e-5)
    # full budget resolves the IDENTITY sentinel: the compiled graph
    # skips partition/gather/scatter entirely and stays lossless
    from repro.core.routing import IDENTITY_BUCKET
    assert ragged_bucket(ElasticPolicy.uniform(1.0), 24) == IDENTITY_BUCKET
    out, _ = forward(params, rp, batch, cfg, spec, mode="train",
                     policy=jax.tree.map(jnp.asarray,
                                         ElasticPolicy.uniform(1.0)),
                     bucket=IDENTITY_BUCKET)
    np.testing.assert_allclose(np.asarray(out), np.asarray(teacher),
                               atol=1e-5)
    # mixed full/partial rows cannot take the identity graph
    mixed = ElasticPolicy.stack([ElasticPolicy.uniform(1.0),
                                 ElasticPolicy.uniform(0.5)])
    assert ragged_bucket(mixed, 24) is None


# ------------------------------- serving ------------------------------------

def test_serving_ragged_spec_keeps_compile_counts_flat(key):
    """Acceptance: with routing_impl="ragged", compile_counts() stays
    {prefill: 1, decode: 1} per bucket set across mixed budgets (threshold
    decode/prefill never buckets; only train-mode top-k prefill would add
    <= RAGGED_N_BUCKETS entries)."""
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    assert ecfg.routing_impl == "ragged"
    engine = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=4, max_seq=24)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(4)]
    budgets = [0.4, 0.7, 1.0, None]
    mixed = engine.generate([GenRequest(p, 4, budget=b)
                             for p, b in zip(prompts, budgets)])
    for p, b, got in zip(prompts, budgets, mixed):
        sep = engine.generate([GenRequest(p, 4, budget=b)])[0]
        np.testing.assert_array_equal(got, sep)
    assert engine.compile_counts() == {"prefill": 1, "decode": 1}


def test_serving_train_mode_buckets_prefill(key):
    """Train-mode (top-k) admissions resolve a static capacity bucket per
    request: prefill compiles per bucket (<= RAGGED_N_BUCKETS per prompt
    length, never per budget) and mixed-budget outputs still match solo
    runs."""
    cfg, ecfg, params, rp, batch = _setup(key, **FULL_KW)
    engine = ServingEngine(params, rp, cfg, ecfg, mode="train",
                           batch_size=4, max_seq=24)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]
    budgets = [0.3, 0.35, 0.8]          # first two share a bucket
    mixed = engine.generate([GenRequest(p, 4, budget=b)
                             for p, b in zip(prompts, budgets)])
    counts = engine.compile_counts()
    assert counts["decode"] == 1
    assert counts["prefill"] <= RAGGED_N_BUCKETS
    solo = ServingEngine(params, rp, cfg, ecfg, mode="train",
                         batch_size=4, max_seq=24)
    for p, b, got in zip(prompts, budgets, mixed):
        np.testing.assert_array_equal(
            got, solo.generate([GenRequest(p, 4, budget=b)])[0])
