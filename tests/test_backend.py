"""Kernel-backend dispatch + RoutingPlan reuse (ISSUE 4).

Covers the acceptance properties:
  * parity grid: forward outputs and router gradients agree across
    kernel_backend {ref, interpret} x routing_impl {ragged, gather,
    dense_mask} (the interpret backend runs the REAL Pallas kernel logic
    through the model hot path, with the jnp-reference backward);
  * the model forward under kernel_backend="interpret" actually calls the
    Pallas kernels (call-counter on the kernel modules' entry points);
  * exactly ONE RoutingPlan sort per block trace (no per-component
    re-sort), and ZERO sorts on the identity (full-budget) graph;
  * the ring-cache decode kernel bit-matches the jnp attn_decode twin on
    staggered per-slot positions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.elasti_toy import toy_lm
from repro.core import routing as R
from repro.core.policy import ElasticPolicy, ElasticSpec, ragged_bucket
from repro.models import forward, model_init, router_init
from tests.conftest import f32

N_EXPERTS = 4


def _setup(key, s=24, *, experts=False, impl="ragged", backend="ref"):
    cfg = f32(toy_lm())
    spec = ElasticSpec(
        mha_token_routed=True, mlp_token_routed=True, mha_head_routed=True,
        mlp_n_experts=N_EXPERTS if experts else None, expert_routed=experts,
        lora_rank=1, routing_impl=impl, kernel_backend=backend)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, s), dtype=np.int32))}
    return cfg, spec, params, rp, batch


def _pol(budget, cfg, experts):
    return ElasticPolicy.uniform(
        budget, n_heads=cfg.n_heads,
        n_experts=N_EXPERTS if experts else None, static=True)


# ----------------------------- parity grid -----------------------------------

@pytest.mark.parametrize("experts", [False, True])
@pytest.mark.parametrize("impl", ["ragged", "gather", "dense_mask"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_backend_impl_parity_grid(key, backend, impl, experts):
    """Forward outputs and router grads agree across every execution path
    x backend combination (baseline: ref x gather)."""
    cfg, spec, params, rp, batch = _setup(key, experts=experts, impl=impl,
                                          backend=backend)
    base_spec = dataclasses.replace(spec, routing_impl="gather",
                                    kernel_backend="ref")
    pol = _pol(0.5, cfg, experts)

    def loss(rp, sp):
        out, aux = forward(params, rp, batch, cfg, sp, mode="train",
                           policy=pol)
        return jnp.sum(out ** 2) * 1e-4 + aux.topk + aux.load, out

    (l_b, out_b), g_b = jax.value_and_grad(loss, has_aux=True)(rp, base_spec)
    (l_t, out_t), g_t = jax.value_and_grad(loss, has_aux=True)(rp, spec)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_b),
                               atol=2e-4)
    np.testing.assert_allclose(float(l_t), float(l_b), rtol=1e-4)
    for pt, pb in zip(jax.tree.leaves(g_t), jax.tree.leaves(g_b)):
        np.testing.assert_allclose(np.asarray(pt), np.asarray(pb), atol=2e-4)
    assert sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g_t)) > 0


# ------------------------- kernel call counting ------------------------------

def test_interpret_backend_calls_all_pallas_kernels(key, monkeypatch):
    """Acceptance: the model forward with kernel_backend="interpret"
    dispatches through all three Pallas kernels (plus the routed
    gather/scatter MLP kernel), not the jnp twins."""
    import sys
    # the package __init__ shadows the submodule names with the ops
    # wrappers, so resolve the real modules through sys.modules
    flash_mod = sys.modules["repro.kernels.flash_attention"]
    mlp_mod = sys.modules["repro.kernels.fused_mlp"]
    gmm_mod = sys.modules["repro.kernels.moe_gmm"]

    calls = {"flash": 0, "fused_mlp": 0, "fused_mlp_routed": 0, "moe_gmm": 0}

    def count(name, fn):
        def wrapped(*a, **kw):
            calls[name] += 1
            return fn(*a, **kw)
        return wrapped

    monkeypatch.setattr(flash_mod, "flash_attention",
                        count("flash", flash_mod.flash_attention))
    monkeypatch.setattr(mlp_mod, "fused_mlp",
                        count("fused_mlp", mlp_mod.fused_mlp))
    monkeypatch.setattr(mlp_mod, "fused_mlp_routed",
                        count("fused_mlp_routed", mlp_mod.fused_mlp_routed))
    monkeypatch.setattr(gmm_mod, "moe_gmm",
                        count("moe_gmm", gmm_mod.moe_gmm))
    jax.clear_caches()  # the jitted ops wrappers must re-trace

    # dense-MLP spec: flash attention + the routed fused-MLP kernel
    cfg, spec, params, rp, batch = _setup(key, backend="interpret")
    forward(params, rp, batch, cfg, spec, mode="train",
            policy=_pol(0.5, cfg, False))
    # teacher-mode forward: the unrouted MLP goes through fused_mlp
    forward(params, None, batch, cfg, spec, mode="base")
    # moefied spec: expert dispatch goes through moe_gmm
    cfg, spec, params, rp, batch = _setup(key, experts=True,
                                          backend="interpret")
    forward(params, rp, batch, cfg, spec, mode="train",
            policy=_pol(0.5, cfg, True))
    assert all(c > 0 for c in calls.values()), calls


# --------------------------- one sort per block ------------------------------

def _count_plan_sorts(fn, *args):
    before = R.PLAN_SORT_COUNT
    jax.jit(fn).lower(*args)     # trace only — sorts are counted per trace
    return R.PLAN_SORT_COUNT - before


def test_one_routing_plan_sort_per_block_trace(key):
    """Acceptance: the attention and MLP students share ONE RoutingPlan —
    a single sort per block trace (the toy pattern scan traces its period
    once), where the pre-refactor path issued 3+ per component."""
    cfg = f32(toy_lm(vocab=256))
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    batch = {"tokens": jnp.zeros((2, 256), jnp.int32)}

    def fwd(budget):
        pol = ElasticPolicy.uniform(budget, static=True)
        return lambda rp, b: forward(params, rp, b, cfg, spec, mode="train",
                                     policy=pol)[0]

    # toy-lm: homogeneous pattern -> the block body is traced exactly once
    assert _count_plan_sorts(fwd(0.5), rp, batch) == 1
    # identity (full-budget) graph: no routing work at all
    assert _count_plan_sorts(fwd(1.0), rp, batch) == 0
    # teacher forward: no sorts either
    assert _count_plan_sorts(
        lambda b: forward(params, None, b, cfg, None, mode="base")[0],
        batch) == 0

    # hloprof-verified: the COMPILED forward lowers exactly one sort op
    # (shared across all layers via the pattern scan) at a routed budget,
    # and zero on the identity graph
    from repro.launch.hloprof import profile_text

    def hlo_sorts(budget):
        c = jax.jit(fwd(budget)).lower(rp, batch).compile()
        return profile_text(c.as_text()).get("sort", {"count": 0})["count"]

    assert hlo_sorts(0.5) == 1
    assert hlo_sorts(1.0) == 0


# ------------------------- decode kernel parity ------------------------------

def test_decode_kernel_matches_jnp_twin_on_staggered_slots(key):
    """The ring-cache decode kernel == attn_decode's jnp path, with every
    serving slot at its own position (continuous batching)."""
    from repro.models.attention import attn_cache_init, attn_decode, attn_init
    cfg = f32(toy_lm())
    p = attn_init(key, cfg)
    B, L = 3, 16
    cache = attn_cache_init(cfg, B, L, window=0)
    rng = np.random.default_rng(0)
    # warm the ring cache at staggered offsets with real entries
    t = jnp.asarray([2, 7, 13], jnp.int32)
    ks = jax.random.split(key, 8)
    pos = jnp.where(jnp.arange(L)[None, :] <= t[:, None],
                    jnp.arange(L)[None, :], -1).astype(jnp.int32)
    cache = {
        "k": jax.random.normal(ks[0], cache["k"].shape, cache["k"].dtype),
        "v": jax.random.normal(ks[1], cache["v"].shape, cache["v"].dtype),
        "valid": jnp.asarray(rng.random((B, L)) < 0.9),
        "pos": pos,
    }
    x = jax.random.normal(ks[2], (B, 1, cfg.d_model), jnp.float32)
    write = jnp.asarray([True, False, True])
    for window in (0, 6):
        y_ref, c_ref = attn_decode(p, x, cache, t, cfg=cfg, window=window,
                                   write=write, backend=None)
        y_k, c_k = attn_decode(p, x, cache, t, cfg=cfg, window=window,
                               write=write, backend="interpret")
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                                   atol=2e-5, rtol=2e-5)
        for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_k)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_identity_graph_is_bit_exact_teacher(key):
    """The identity bucket (== S) skips all routing work and reproduces
    the teacher bit-for-bit, for traced full-budget policies."""
    cfg, spec, params, rp, batch = _setup(key)
    teacher, _ = forward(params, None, batch, cfg, None, mode="base")
    pol = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(1.0))
    s = batch["tokens"].shape[1]
    assert ragged_bucket(pol, s) == R.IDENTITY_BUCKET
    out, _ = forward(params, rp, batch, cfg, spec, mode="train", policy=pol,
                     bucket=R.IDENTITY_BUCKET)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(teacher))
    # a real bucket that merely EQUALS a (shorter) batch's length is not
    # an identity assertion: it degrades to the dense fallback, which
    # still applies routing weights — outputs must differ from teacher
    half = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(0.5))
    out_h, _ = forward(params, rp, batch, cfg, spec, mode="train",
                       policy=half, bucket=s)
    assert not np.allclose(np.asarray(out_h), np.asarray(teacher))


# ------------------ backend x layout x dtype parity grid ---------------------
#
# ISSUE 8 (docs/quantization.md): the quantized KV cache + weights must
# serve from both cache layouts on every backend with bounded logit error
# and greedy-token parity vs the fp32 reference, and a staggered slot must
# decode bit-identically to a solo run (per-row compute is row-local, and
# int8 rows are quantized ONCE at the write site).

def _ring_logits(params, cfg, spec, toks, kv_dtype, *, other=None):
    """Prefill ``toks`` into the LAST ring slot, 3 greedy decode steps;
    ``other`` staggers a second live request in slot 0 at its own t."""
    from repro.models.model import cache_init, cache_insert, prefill
    from repro.models.model import decode_step
    S, L = toks.shape[1], 32
    B = 2 if other is not None else 1
    caches = cache_init(cfg, B, L, kv_dtype=kv_dtype)
    logits, row = prefill(params, None, {"tokens": toks}, cfg, spec,
                          mode="base", max_cache_len=L)
    caches = cache_insert(caches, row, B - 1)
    tok = jnp.argmax(logits, -1)[:, None]
    ts = [S]
    if other is not None:
        lo, row2 = prefill(params, None, {"tokens": other}, cfg, spec,
                           mode="base", max_cache_len=L)
        caches = cache_insert(caches, row2, 0)
        ts = [other.shape[1], S]
        tok = jnp.concatenate([jnp.argmax(lo, -1)[:, None], tok], 0)
    t = jnp.asarray(ts, jnp.int32)
    outs = []
    for _ in range(3):
        logits, caches = decode_step(params, None, tok, caches, t, cfg,
                                     spec, mode="base")
        outs.append(logits[B - 1])
        tok = jnp.argmax(logits, -1)[:, None]
        t = t + 1
    return jnp.stack(outs)


def _paged_logits(params, cfg, spec, toks, kv_dtype, *, other=None):
    """Chunked-prefill ``toks`` into pages [3, 5], 3 greedy decode steps;
    ``other`` staggers a second request in pages [7, 9]."""
    from repro.models.model import paged_cache_init, prefill_chunk_step
    from repro.models.model import decode_step
    ps, P = 8, 4
    caches = paged_cache_init(cfg, 16, ps, kv_dtype=kv_dtype)

    def pf(tk, pages):
        nonlocal caches
        S_ = tk.shape[1]
        trow = jnp.full((P,), -1, jnp.int32)
        for i, pg in enumerate(pages):
            trow = trow.at[i].set(pg)
        lg = None
        for c in range(-(-S_ // ps)):
            chunk = jnp.zeros((1, ps), jnp.int32)
            n = min(ps, S_ - c * ps)
            chunk = chunk.at[0, :n].set(tk[0, c * ps:c * ps + n])
            lg, caches = prefill_chunk_step(
                params, None, chunk, caches, jnp.asarray(pages[c]), trow,
                jnp.asarray(c * ps), jnp.asarray(S_), cfg, spec,
                mode="base")
        return lg, trow

    lg, trow = pf(toks, [3, 5])
    rows, ts = [trow], [toks.shape[1]]
    toks_d = [jnp.argmax(lg, -1)[:, None]]
    if other is not None:
        lo, trow2 = pf(other, [7, 9])
        rows, ts = [trow2, trow], [other.shape[1], toks.shape[1]]
        toks_d = [jnp.argmax(lo, -1)[:, None]] + toks_d
    table = jnp.stack(rows)
    t = jnp.asarray(ts, jnp.int32)
    tok = jnp.concatenate(toks_d, 0)
    trash = jnp.full((len(ts),), 15, jnp.int32)
    outs = []
    for _ in range(3):
        lg, caches = decode_step(params, None, tok, caches, t, cfg, spec,
                                 mode="base", table=table, trash=trash)
        outs.append(lg[-1])
        tok = jnp.argmax(lg, -1)[:, None]
        t = t + 1
    return jnp.stack(outs)


@pytest.mark.parametrize("layout", ["ring", "paged"])
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_quantized_kv_layout_dtype_grid(key, backend, kv_dtype, layout):
    """Quantized serving parity: bounded logit error + greedy match vs the
    fp32 reference on the same backend, and staggered == solo bitwise."""
    from repro.models.quant import quantize_params_tree
    cfg = f32(toy_lm())
    spec = ElasticSpec(kernel_backend=backend)
    qspec = dataclasses.replace(spec, kv_dtype=kv_dtype,
                                weight_dtype=kv_dtype)
    params = model_init(key, cfg, spec)
    qparams = quantize_params_tree(params, kv_dtype)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12),
                                    dtype=np.int32))
    other = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9),
                                     dtype=np.int32))
    run = _ring_logits if layout == "ring" else _paged_logits
    ref_out = run(params, cfg, spec, toks, "fp32")
    q_out = run(qparams, cfg, qspec, toks, kv_dtype)
    err = float(jnp.max(jnp.abs(ref_out - q_out)))
    assert err <= 0.25, f"{layout}/{kv_dtype}/{backend}: logit error {err}"
    np.testing.assert_array_equal(np.argmax(np.asarray(ref_out), -1),
                                  np.argmax(np.asarray(q_out), -1),
                                  err_msg="greedy tokens diverged from fp32")
    # a second live request at its own position must not perturb a single
    # bit of this one's logits (quantize-once rows + row-local compute)
    q_stag = run(qparams, cfg, qspec, toks, kv_dtype, other=other)
    np.testing.assert_array_equal(np.asarray(q_out), np.asarray(q_stag))
