"""Hypothesis property tests on system invariants (deliverable c)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[dev])")

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.routing import param_route_weights, topk_mask
from repro.models.rglru import _gates, rglru_init
from repro.models.ssm import ssd_chunked
from repro.optim import dequantize_int8, ef_init, compress_grads, quantize_int8

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

floats = hnp.arrays(np.float32, shape=hnp.array_shapes(min_dims=1, max_dims=3,
                                                       max_side=16),
                    elements=st.floats(-100, 100, width=32))


@given(floats)
def test_int8_quantization_error_bound(x):
    q, s = quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_int8(q, s)) - x)
    assert (err <= float(s) * 0.5 + 1e-6).all()


@given(floats, st.integers(2, 6))
def test_error_feedback_is_lossless_over_time(x, steps):
    """EF compression: sum of compressed outputs converges to the sum of the
    true gradients (residual is bounded, never lost)."""
    g = {"w": jnp.asarray(x)}
    ef = ef_init(g)
    total = np.zeros_like(x)
    for _ in range(steps):
        out, ef = compress_grads(g, ef)
        total += np.asarray(out["w"], np.float32)
    scale = max(1e-6, float(np.abs(x).max()))
    resid = np.abs(np.asarray(ef.residual["w"]))
    # residual stays within one quantization bucket of the *current* grad
    assert (resid <= scale / 127.0 + 1e-5).all()
    np.testing.assert_allclose(total + np.asarray(ef.residual["w"]),
                               x * steps, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 8), st.integers(1, 4))
def test_param_router_norm_invariant(m_pow, seed):
    m = 2 * m_pow
    key = jax.random.PRNGKey(seed)
    rp = {"w": jax.random.normal(key, (8, m))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8))
    w, mask, _ = param_route_weights(rp, x, top_k=max(1, m // 2))
    np.testing.assert_allclose(np.asarray(w.sum(-1)), m, rtol=1e-4)
    assert (mask.sum(-1) == max(1, m // 2)).all()


@given(st.integers(0, 5))
def test_topk_mask_count_invariant(seed):
    key = jax.random.PRNGKey(seed)
    scores = jax.random.uniform(key, (3, 17))
    for k in (1, 5, 17):
        assert (topk_mask(scores, k).sum(-1) == k).all()


@settings(max_examples=10)
@given(st.integers(0, 4), st.sampled_from([2, 4, 8]))
def test_ssd_chunked_matches_sequential_recurrence(seed, chunk):
    """SSD chunked algorithm == naive per-step recurrence oracle."""
    key = jax.random.PRNGKey(seed)
    B, S, H, P, N = 1, 16, 2, 4, 3
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(jax.random.fold_in(key, 5), (B, S, N))
    y, hfin = ssd_chunked(x, dt, a, bm, cm, chunk)
    # oracle
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(a))      # (B,H)
        inp = np.einsum("bh,bhp,bn->bhpn", np.asarray(dt[:, t]),
                        np.asarray(x[:, t]), np.asarray(bm[:, t]))
        h = h * dA[..., None, None] + inp
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(cm[:, t]), h))
    want = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), want, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hfin), h, atol=2e-4, rtol=1e-3)


@settings(max_examples=10)
@given(st.integers(0, 4))
def test_rglru_scan_matches_sequential(seed):
    """Associative-scan RG-LRU == sequential loop."""
    key = jax.random.PRNGKey(seed)
    import dataclasses
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("recurrentgemma-2b", "smoke"),
                              dtype="float32")
    p = rglru_init(key, cfg)
    u = jax.random.normal(jax.random.fold_in(key, 1), (1, 12, cfg.lru_width))
    a, b = _gates(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = np.zeros((1, cfg.lru_width))
    hs = []
    for t in range(12):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        hs.append(h)
    np.testing.assert_allclose(np.asarray(h_scan), np.stack(hs, 1),
                               atol=1e-5, rtol=1e-4)
