"""End-to-end driver: fault-tolerant distributed ElastiFormer distillation.

Uses the production training stack (launch/train.py): sharded frozen base,
distillation train step with chunked top-50 KL, async atomic checkpointing,
straggler watchdog, and *injected failures* to demonstrate restart-from-
checkpoint mid-run. Trains a ~langauge model for a few hundred steps on the
synthetic Zipf-Markov corpus.

Run:   PYTHONPATH=src python examples/train_elastic_lm.py
Flags: --arch phi3-medium-14b --variant smoke --steps 300 --batch 8
       (any registered arch; `smoke` variants fit CPU, `full` needs a pod)
"""
import argparse
import logging
import shutil

from repro.launch.train import train


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-lm")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    ap.add_argument("--fresh", action="store_true",
                    help="clear checkpoint dir first")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the loop at 40%% to demo restart")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt, ignore_errors=True)

    inject = (int(args.steps * 0.4),) if args.inject_failure else ()
    state, metrics, restarts, watchdog = train(
        args.arch, variant=args.variant, total_steps=args.steps,
        seq_len=args.seq_len, global_batch=args.batch,
        ckpt_dir=args.ckpt, save_every=max(10, args.steps // 10),
        inject_failures=inject)
    print(f"\nfinal metrics: {metrics}")
    print(f"restarts survived: {restarts}")
    print(f"straggler watchdog: {len(watchdog.flagged)} slow steps flagged "
          f"{watchdog.flagged[:5]}")


if __name__ == "__main__":
    main()
