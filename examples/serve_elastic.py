"""Serve a small elastic LM with batched requests.

Demonstrates the inference half of ElastiFormer (paper §B.1): prefill uses
capacity-factor top-k routing; decode uses the THRESHOLD path (theta = 0.5
on each router's sigmoid) because top-k over the future is unknowable for a
causal model. Routers are first distilled against the frozen teacher so the
threshold selections are meaningful, then a batch of prompts is served in
both `base` and `infer` modes and the outputs + per-module token-skip rates
are compared.

Run: PYTHONPATH=src python examples/serve_elastic.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import distill_routers, pretrained_teacher
from repro.configs import ElasticConfig
from repro.models import forward
from repro.training import GenRequest, ServingEngine


def main():
    print("== teacher + routers")
    cfg, params = pretrained_teacher(steps=300)
    ecfg = ElasticConfig(mlp_token_capacity=0.8, mha_token_capacity=0.8,
                         lora_rank=1, mha_head_topk=2,
                         mlp_n_experts=4, mlp_expert_topk=2)
    rp, _ = distill_routers(params, cfg, ecfg, steps=60)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (12, 9, 15, 12)]
    reqs = [GenRequest(p, max_new_tokens=16) for p in prompts]

    print("== serving (base mode: frozen teacher)")
    base_eng = ServingEngine(params, None, cfg, None, mode="base",
                             batch_size=4, max_seq=64)
    base_out = base_eng.generate(reqs)

    print("== serving (infer mode: threshold-routed elastic)")
    el_eng = ServingEngine(params, rp, cfg, ecfg, mode="infer",
                           batch_size=4, max_seq=64)
    el_out = el_eng.generate(reqs)

    agree = np.mean([np.mean(a[:8] == b[:8])
                     for a, b in zip(base_out, el_out)])
    print(f"\nper-token agreement (first 8 new tokens): {agree:.0%}")
    for i, (a, b) in enumerate(zip(base_out, el_out)):
        print(f"  req{i}: base={a[:8].tolist()} elastic={b[:8].tolist()}")

    # router selection rates on a held-out batch (the compute actually spent)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32))}
    _, aux = forward(params, rp, batch, cfg, ecfg, mode="infer")
    print(f"\nthreshold-path selection rate (mean fraction of tokens "
          f"processed per routed module): {float(aux.sel_rate):.2f} "
          f"(trained capacity 0.8)")

    # per-request compute budgets: ONE compiled decode step serves a batch
    # mixing budgets 0.5 / 0.8 / 1.0 (budget 1.0 == exact frozen teacher)
    print("\n== serving with mixed per-request budgets")
    mixed = [GenRequest(p, max_new_tokens=16, budget=b)
             for p, b in zip(prompts, (0.5, 0.8, 1.0, 1.0))]
    mx_out = el_eng.generate(mixed)
    for i, (req, o) in enumerate(zip(mixed, mx_out)):
        same = np.array_equal(o[:8], base_out[i][:8])
        print(f"  req{i} budget={req.budget}: {o[:8].tolist()}"
              f"{'  (== teacher)' if same and req.budget == 1.0 else ''}")
    print(f"compiles after the budget mix: {el_eng.compile_counts()} "
          f"(budgets never recompile)")

    # continuous batching: the engine's real surface is a request lifecycle —
    # submit returns a handle, handle.tokens() streams while OTHER requests
    # decode in their own slots of the same compiled step, cancel frees a
    # slot mid-flight (see docs/serving.md).
    print("\n== continuous batching (submit / stream / cancel)")
    h_stream = el_eng.submit(GenRequest(prompts[0], 12, budget=0.8))
    h_bg = el_eng.submit(GenRequest(prompts[1], 12, budget=0.4))
    h_cut = el_eng.submit(GenRequest(prompts[2], 40, budget=0.5))
    first6 = [tok for tok, _ in zip(h_stream.tokens(), range(6))]
    print(f"  streamed 6 tokens from req0 while req1/req2 decode: {first6}")
    el_eng.cancel(h_cut)
    print(f"  cancelled req2 mid-flight after {len(h_cut.output)} tokens "
          f"(status={h_cut.status}, slot freed)")
    h_stream.result(), h_bg.result()
    print(f"  slot occupancy {el_eng.occupancy:.0%}; compiles "
          f"{el_eng.compile_counts()} (admissions never recompile; only new "
          f"prompt lengths add prefill buckets)")


if __name__ == "__main__":
    main()
