"""Quickstart: make a pretrained transformer elastic in ~60 lines.

1. Pretrain a small LM teacher on a synthetic corpus (stands in for a
   downloaded checkpoint; weights are then FROZEN).
2. Attach ElastiFormer routers: token routing around MHA/MLP, head
   selection, moefied-expert selection (+ rank-1 LoRA on q/v).
3. Self-distill ONLY the routers against the frozen teacher.
4. Compare eval LM loss: teacher vs elastic student, and report the
   active-compute fraction and router parameter overhead.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.common import (distill_routers, eval_lm_loss,
                               pretrained_teacher)
from repro.configs import ElasticConfig
from repro.models import router_param_count, router_init


def main():
    print("== 1. pretraining the (stand-in) teacher ...")
    cfg, params = pretrained_teacher(steps=300)
    n_base = sum(x.size for x in jax.tree.leaves(params))

    print("== 2. attaching ElastiFormer routers")
    ecfg = ElasticConfig(
        mlp_token_capacity=0.8,     # 20% of tokens skip the MLP
        mha_token_capacity=0.8,     # 20% of tokens skip attention...
        lora_rank=1,                # ...rescued by rank-1 LoRA (paper Fig. 6)
        mha_head_topk=2,            # 2/4 attention heads per token
        mlp_n_experts=4,            # dense MLP losslessly split into 4 experts
        mlp_expert_topk=2,          # 2/4 experts per token
    )
    rp = router_init(jax.random.PRNGKey(0), cfg, ecfg)
    n_router = router_param_count(rp)
    print(f"   base params (frozen): {n_base:,}")
    print(f"   router(+LoRA) params: {n_router:,} "
          f"({100 * n_router / n_base:.3f}% — paper: 0.00006%–0.3%)")

    print("== 3. self-distilling routers (teacher = frozen base) ...")
    rp, metrics = distill_routers(params, cfg, ecfg, steps=60)
    print(f"   final train metrics: { {k: round(v, 4) for k, v in metrics.items()} }")

    print("== 4. evaluation")
    base = eval_lm_loss(params, None, cfg, None, "base")
    stud = eval_lm_loss(params, rp, cfg, ecfg, "train")
    cap = ecfg.mlp_token_capacity
    print(f"   teacher LM loss : {base:.4f}")
    print(f"   elastic LM loss : {stud:.4f}  (delta {stud - base:+.4f})")
    print(f"   active compute  : ~{cap:.0%} tokens x "
          f"{ecfg.mha_head_topk}/{cfg.n_heads} heads x "
          f"{ecfg.mlp_expert_topk}/{ecfg.mlp_n_experts} experts")


if __name__ == "__main__":
    main()
