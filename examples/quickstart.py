"""Quickstart: make a pretrained transformer elastic in ~60 lines.

The elasticity API is two objects (see docs/elastic_policy.md):

  * ``ElasticSpec``  — static: which routers EXIST (token routing around
    MHA/MLP, head selection, moefied experts, LoRA rank). It shapes the
    router parameter tree and the compiled HLO, like the model config.
  * ``ElasticPolicy`` — runtime: capacities, head/expert top-k, decode
    threshold theta, teacher/student flag. A JAX pytree passed as a traced
    argument, so ONE compiled model serves every compute budget:

        spec = ElasticSpec(mha_token_routed=True, mha_head_routed=True,
                           mlp_n_experts=4, expert_routed=True, lora_rank=1)
        rp   = router_init(key, cfg, spec)
        # sweep budgets with zero recompiles
        for b in (0.25, 0.5, 1.0):
            policy = solve_budget(cfg, spec, b)     # roofline budget solver
            logits, _ = jit_forward(params, rp, batch, policy)

    ``ElasticPolicy.uniform(1.0)`` reproduces the frozen teacher exactly
    (the paper's losslessness property). The legacy ``ElasticConfig`` still
    works everywhere through a shim and maps 1:1 onto (spec, policy).

This script:
1. Pretrains a small LM teacher on a synthetic corpus (stands in for a
   downloaded checkpoint; weights are then FROZEN).
2. Attaches ElastiFormer routers per an ElasticSpec.
3. Self-distills ONLY the routers against the frozen teacher.
4. Evaluates the SAME routers at several budgets through one compiled
   forward, and reports loss vs active-compute fraction.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import BATCH, SEQ, distill_routers, pretrained_teacher
from repro.core.policy import ElasticPolicy, ElasticSpec, solve_budget
from repro.data import LMDataPipeline
from repro.models import forward, router_param_count, router_init
from repro.training import lm_loss


def main():
    print("== 1. pretraining the (stand-in) teacher ...")
    cfg, params = pretrained_teacher(steps=300)
    n_base = sum(x.size for x in jax.tree.leaves(params))

    print("== 2. attaching ElastiFormer routers (ElasticSpec)")
    spec = ElasticSpec(
        mlp_token_routed=True,      # tokens may skip the MLP
        mha_token_routed=True,      # tokens may skip attention...
        lora_rank=1,                # ...rescued by rank-1 LoRA (paper Fig. 6)
        mha_head_routed=True,       # per-token attention-head selection
        mlp_n_experts=4,            # dense MLP losslessly split into 4 experts
        expert_routed=True,         # per-token expert selection
    )
    rp = router_init(jax.random.PRNGKey(0), cfg, spec)
    n_router = router_param_count(rp)
    print(f"   base params (frozen): {n_base:,}")
    print(f"   router(+LoRA) params: {n_router:,} "
          f"({100 * n_router / n_base:.3f}% — paper: 0.00006%–0.3%)")

    print("== 3. self-distilling routers at a 0.8 budget ...")
    train_policy = solve_budget(cfg, spec, 0.8)
    rp, metrics = distill_routers(params, cfg, spec, steps=60,
                                  policy=train_policy)
    print(f"   final train metrics: { {k: round(v, 4) for k, v in metrics.items()} }")

    print("== 4. one compiled model, many budgets")
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                          global_batch=BATCH, seed=123)
    tokens = jnp.asarray(pipe.batch_at(0))
    t_logits, _ = forward(params, None, {"tokens": tokens}, cfg, None,
                          mode="base")
    base = float(lm_loss(t_logits, tokens))
    print(f"   teacher LM loss : {base:.4f}")

    @jax.jit
    def ev(rp, tokens, policy):
        logits, aux = forward(params, rp, {"tokens": tokens}, cfg, spec,
                              mode="train", policy=policy)
        return lm_loss(logits, tokens), aux.sel_rate

    for budget in (0.5, 0.8, 1.0):
        policy = solve_budget(cfg, spec, budget)
        loss, sel = ev(rp, tokens, policy)
        tag = " (== teacher, lossless)" if budget == 1.0 else ""
        print(f"   budget {budget:.1f}: LM loss {float(loss):.4f} "
              f"(delta {float(loss) - base:+.4f}), "
              f"token sel rate {float(sel):.2f}{tag}")
    print(f"   forward compiled {ev._cache_size()}x for "
          f"{3} budgets (policy is a traced argument)")


if __name__ == "__main__":
    main()
