"""jit'd public wrappers for the Pallas kernels + the kernel-backend switch.

The model hot path dispatches through these wrappers under a *backend*
resolved from ``ElasticSpec.kernel_backend``:

  * ``"pallas"``    — real pallas_call (TPU; falls back to the interpreter
                      when the host has no TPU, so the same graph traces
                      everywhere);
  * ``"interpret"`` — pallas_call under interpret=True (CPU verification of
                      the exact kernel logic, incl. the scalar-prefetch
                      ragged skip paths);
  * ``"ref"``       — the pure-jnp oracles in kernels/ref.py (and the jnp
                      twins inside the model, which are the same math) —
                      the fast CPU path;
  * ``"auto"``/None — "pallas" on TPU backends, "ref" elsewhere.

The ragged valid-count arguments (``valid_count`` / ``group_counts`` /
``kv_count``) are traced, so one bucket-sized compile serves every
occupancy. Kernel-backed ops carry a custom VJP that replays the jnp
reference backward (the standard arrangement while the hand-written
backward kernels don't exist): forward runs the kernel, gradients are the
reference's — numerically the kernels and references agree to float
tolerance, so training under ``interpret``/``pallas`` matches ``ref``.

Tests may monkeypatch the kernel modules' entry points; dispatch goes
through the module attributes (``_fused_mlp_mod.fused_mlp`` etc.) so a
patch is observed at trace time.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

import repro.kernels.decode_attention as _decode_mod
import repro.kernels.flash_attention as _flash_mod
import repro.kernels.fused_mlp as _fused_mlp_mod
import repro.kernels.moe_gmm as _moe_gmm_mod
import repro.kernels.paged_decode_attention as _paged_decode_mod
from repro.kernels import ref

BACKENDS = ("pallas", "interpret", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(name=None) -> str:
    """Map an ``ElasticSpec.kernel_backend`` value to a concrete backend."""
    if name in (None, "auto"):
        return "pallas" if _on_tpu() else "ref"
    if name not in BACKENDS:
        raise ValueError(f"kernel_backend must be one of {BACKENDS} or "
                         f"'auto', got {name!r}")
    return name


def _interp(backend: str) -> bool:
    # "pallas" off-TPU still runs the kernel, interpreted: one code path
    return backend == "interpret" or not _on_tpu()


def _f0(x):
    """float0 cotangent for integer/bool primal args in custom VJPs."""
    return np.zeros(jax.numpy.shape(x), jax.dtypes.float0)


# ----------------------------- flash attention -------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash_fwd_op(causal, window, backend, q, k, v, kv_valid, cnt):
    return _flash_mod.flash_attention(
        q, k, v, causal=causal, window=window, kv_valid=kv_valid,
        kv_count=cnt, interpret=_interp(backend))


def _flash_ref(causal, window, q, k, v, kv_valid, cnt):
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   kv_valid=kv_valid, kv_count=cnt)


def _flash_vjp_fwd(causal, window, backend, q, k, v, kv_valid, cnt):
    out = _flash_fwd_op(causal, window, backend, q, k, v, kv_valid, cnt)
    return out, (q, k, v, kv_valid, cnt)


def _flash_vjp_bwd(causal, window, backend, res, g):
    q, k, v, kv_valid, cnt = res
    _, vjp = jax.vjp(lambda q, k, v: _flash_ref(causal, window, q, k, v,
                                                kv_valid, cnt), q, k, v)
    dq, dk, dv = vjp(g)
    return (dq, dk, dv,
            None if kv_valid is None else _f0(kv_valid),
            None if cnt is None else _f0(cnt))


_flash_fwd_op.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "force_pallas",
                                   "backend"))
def flash_attention(q, k, v, kv_valid=None, kv_count=None, *, causal=True,
                    window=0, force_pallas=False, backend=None):
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       kv_valid=kv_valid, kv_count=kv_count)
    return _flash_fwd_op(causal, window, kb, q, k, v, kv_valid, kv_count)


# -------------------------------- fused MLP ----------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_mlp_op(act, backend, x, wi, wo, wg, tw, cnt):
    return _fused_mlp_mod.fused_mlp(x, wi, wo, wg, tw, act=act,
                                    valid_count=cnt,
                                    interpret=_interp(backend))


def _fused_mlp_vjp_fwd(act, backend, x, wi, wo, wg, tw, cnt):
    out = _fused_mlp_op(act, backend, x, wi, wo, wg, tw, cnt)
    return out, (x, wi, wo, wg, tw, cnt)


def _fused_mlp_vjp_bwd(act, backend, res, g):
    x, wi, wo, wg, tw, cnt = res
    diff = tuple(a for a in (x, wi, wo, wg, tw) if a is not None)

    def f(*args):
        it = iter(args)
        a = [next(it) if v is not None else None
             for v in (x, wi, wo, wg, tw)]
        return ref.fused_mlp_ref(a[0], a[1], a[2], a[3], a[4], act=act,
                                 valid_count=cnt)

    _, vjp = jax.vjp(f, *diff)
    grads = iter(vjp(g))
    out = [next(grads) if v is not None else None
           for v in (x, wi, wo, wg, tw)]
    return (*out, None if cnt is None else _f0(cnt))


_fused_mlp_op.defvjp(_fused_mlp_vjp_fwd, _fused_mlp_vjp_bwd)


@partial(jax.jit, static_argnames=("act", "force_pallas", "backend"))
def fused_mlp(x, wi, wo, wg=None, token_weights=None, valid_count=None,
              wi_scale=None, wo_scale=None, wg_scale=None, *,
              act="swiglu", force_pallas=False, backend=None):
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.fused_mlp_ref(x, wi, wo, wg, token_weights, act=act,
                                 valid_count=valid_count, wi_scale=wi_scale,
                                 wo_scale=wo_scale, wg_scale=wg_scale)
    if wi_scale is not None:
        # int8 weights are a serving-only configuration (never
        # differentiated), so the quantized path skips the custom VJP
        return _fused_mlp_mod.fused_mlp(
            x, wi, wo, wg, token_weights, act=act, valid_count=valid_count,
            wi_scale=wi_scale, wo_scale=wo_scale, wg_scale=wg_scale,
            interpret=_interp(kb))
    return _fused_mlp_op(act, kb, x, wi, wo, wg, token_weights, valid_count)


# ---------------------------- routed fused MLP -------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_mlp_routed_op(act, backend, x, idx, wi, wo, wg, tw, cnt):
    return _fused_mlp_mod.fused_mlp_routed(x, idx, wi, wo, wg, tw, act=act,
                                           valid_count=cnt,
                                           interpret=_interp(backend))


def _fused_mlp_routed_vjp_fwd(act, backend, x, idx, wi, wo, wg, tw, cnt):
    out = _fused_mlp_routed_op(act, backend, x, idx, wi, wo, wg, tw, cnt)
    return out, (x, idx, wi, wo, wg, tw, cnt)


def _fused_mlp_routed_vjp_bwd(act, backend, res, g):
    x, idx, wi, wo, wg, tw, cnt = res
    diff = tuple(a for a in (x, wi, wo, wg, tw) if a is not None)

    def f(*args):
        it = iter(args)
        a = [next(it) if v is not None else None
             for v in (x, wi, wo, wg, tw)]
        return ref.fused_mlp_routed_ref(a[0], idx, a[1], a[2], a[3], a[4],
                                        act=act, valid_count=cnt)

    _, vjp = jax.vjp(f, *diff)
    grads = iter(vjp(g))
    out = [next(grads) if v is not None else None
           for v in (x, wi, wo, wg, tw)]
    return (out[0], _f0(idx), *out[1:],
            None if cnt is None else _f0(cnt))


_fused_mlp_routed_op.defvjp(_fused_mlp_routed_vjp_fwd,
                            _fused_mlp_routed_vjp_bwd)


@partial(jax.jit, static_argnames=("act", "force_pallas", "backend"))
def fused_mlp_routed(x, idx, wi, wo, wg=None, token_weights=None,
                     valid_count=None, wi_scale=None, wo_scale=None,
                     wg_scale=None, *, act="swiglu", force_pallas=False,
                     backend=None):
    """Gather/scatter-fused routed MLP: x (B,S,D) full stream, idx (B,Kb)
    RoutingPlan indices; returns the (B,S,D) delta (see fused_mlp.py)."""
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.fused_mlp_routed_ref(x, idx, wi, wo, wg, token_weights,
                                        act=act, valid_count=valid_count,
                                        wi_scale=wi_scale,
                                        wo_scale=wo_scale,
                                        wg_scale=wg_scale)
    if wi_scale is not None:
        # serving-only int8 path: no VJP (see fused_mlp above)
        return _fused_mlp_mod.fused_mlp_routed(
            x, idx, wi, wo, wg, token_weights, act=act,
            valid_count=valid_count, wi_scale=wi_scale, wo_scale=wo_scale,
            wg_scale=wg_scale, interpret=_interp(kb))
    return _fused_mlp_routed_op(act, kb, x, idx, wi, wo, wg, token_weights,
                                valid_count)


# --------------------------------- MoE GMM -----------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _moe_gmm_op(act, backend, x, wi, wo, wg, w, cnt):
    return _moe_gmm_mod.moe_gmm(x, wi, wo, wg, w, act=act,
                                group_counts=cnt, interpret=_interp(backend))


def _moe_gmm_vjp_fwd(act, backend, x, wi, wo, wg, w, cnt):
    out = _moe_gmm_op(act, backend, x, wi, wo, wg, w, cnt)
    return out, (x, wi, wo, wg, w, cnt)


def _moe_gmm_vjp_bwd(act, backend, res, g):
    x, wi, wo, wg, w, cnt = res
    diff = tuple(a for a in (x, wi, wo, wg, w) if a is not None)

    def f(*args):
        it = iter(args)
        a = [next(it) if v is not None else None
             for v in (x, wi, wo, wg, w)]
        return ref.moe_gmm_ref(a[0], a[1], a[2], a[3], a[4], act=act,
                               group_counts=cnt)

    _, vjp = jax.vjp(f, *diff)
    grads = iter(vjp(g))
    out = [next(grads) if v is not None else None
           for v in (x, wi, wo, wg, w)]
    return (*out, None if cnt is None else _f0(cnt))


_moe_gmm_op.defvjp(_moe_gmm_vjp_fwd, _moe_gmm_vjp_bwd)


@partial(jax.jit, static_argnames=("act", "force_pallas", "backend"))
def moe_gmm(x, wi, wo, wg=None, weights=None, group_counts=None,
            wi_scale=None, wo_scale=None, wg_scale=None, *,
            act="swiglu", force_pallas=False, backend=None):
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.moe_gmm_ref(x, wi, wo, wg, weights, act=act,
                               group_counts=group_counts, wi_scale=wi_scale,
                               wo_scale=wo_scale, wg_scale=wg_scale)
    if wi_scale is not None:
        # serving-only int8 path: no VJP (see fused_mlp above)
        return _moe_gmm_mod.moe_gmm(
            x, wi, wo, wg, weights, act=act, group_counts=group_counts,
            wi_scale=wi_scale, wo_scale=wo_scale, wg_scale=wg_scale,
            interpret=_interp(kb))
    return _moe_gmm_op(act, kb, x, wi, wo, wg, weights, group_counts)


# ----------------------------- decode attention ------------------------------

@partial(jax.jit, static_argnames=("window", "force_pallas", "backend"))
def decode_attention(q, k, v, kv_pos, t, kv_valid=None, kscale=None,
                     vscale=None, *, window=0, force_pallas=False,
                     backend=None):
    """Ring-cache decode attention (see kernels/decode_attention.py).
    kscale/vscale: (B, L, K) f32 dequant scales for int8 k/v caches.
    Inference-only: no VJP (decode is never differentiated)."""
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.decode_attention_ref(q, k, v, kv_pos, t, window=window,
                                        kv_valid=kv_valid, kscale=kscale,
                                        vscale=vscale)
    return _decode_mod.decode_attention(q, k, v, kv_pos, t, window=window,
                                        kv_valid=kv_valid, kscale=kscale,
                                        vscale=vscale,
                                        interpret=_interp(kb))


# -------------------------- paged decode attention ---------------------------

@partial(jax.jit, static_argnames=("force_pallas", "backend"))
def paged_decode_attention(q, kp, vp, table, t, pvalid, kscale=None,
                           vscale=None, *, force_pallas=False, backend=None):
    """Paged-pool decode attention (see kernels/paged_decode_attention.py).
    kscale/vscale: (N, ps, K) f32 dequant scale pools for int8 kp/vp.
    Inference-only: no VJP (decode is never differentiated)."""
    kb = "pallas" if force_pallas else resolve_backend(backend)
    if kb == "ref":
        return ref.paged_decode_attention_ref(q, kp, vp, table, t, pvalid,
                                              kscale=kscale, vscale=vscale)
    return _paged_decode_mod.paged_decode_attention(
        q, kp, vp, table, t, pvalid, kscale=kscale, vscale=vscale,
        interpret=_interp(kb))


# --------------------------- SPMD kernel wrappers -----------------------------
#
# A pallas_call is a custom call — OPAQUE to GSPMD, which would replicate
# its operands to every device (an all-gather of the whole KV cache per
# decode step at production scale). Under a mesh the kernel entry points
# below therefore run the kernel INSIDE shard_map: each shard's grid covers
# only its local block (heads/kv-heads or the FFN dim over `model`, batch
# over the data axes), which is exactly how the kernels lower on a real TPU
# slice. The jnp "ref" backend needs none of this — XLA partitions jnp ops
# natively — so these wrappers fall through to the plain call for "ref",
# for trivial meshes, and for shapes that don't divide the axes.

def _mesh_layout(mesh):
    """(mesh, batch_axes, data_size, model_size) for the active/given mesh."""
    from repro.runtime import sharding as SH
    mesh = mesh if mesh is not None else SH.active_mesh()
    if mesh is None:
        return None, (), 1, 1
    return (mesh, SH.batch_axes(mesh), SH.data_axis_size(mesh),
            mesh.shape.get("model", 1))


def decode_attention_sharded(q, k, v, kv_pos, t, kv_valid, *, window=0,
                             backend=None, mesh=None, kscale=None,
                             vscale=None):
    """Ring-cache decode kernel, one grid PER SHARD: q heads and kv heads
    shard over `model`, batch (serving slots) over the data axes. Per-head
    attention has no cross-head contraction, so no collective is needed —
    the output stays head-sharded and the caller's wo projection reduces it
    under GSPMD. Scale leaves (int8 caches) shard like k/v minus the Dh
    axis. Requires Hp % model == 0 and K % model == 0 (each shard's
    local head->kv-group mapping is then exact); anything else, or a
    ref/trivial-mesh call, falls back to the plain entry point."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as SH
    kb = resolve_backend(backend)
    mesh, ba, d, m = _mesh_layout(mesh)
    B, _, Hp, _ = q.shape
    K = k.shape[2]
    if (mesh is None or kb == "ref" or (d <= 1 and m <= 1)
            or Hp % m or K % m or B % d):
        return decode_attention(q, k, v, kv_pos, t, kv_valid, kscale,
                                vscale, window=window, backend=backend)
    bx = ba if d > 1 else None
    # data-only meshes still shard the batch; `model` may be absent/size-1
    md = "model" if "model" in mesh.axis_names else None
    quantized = kscale is not None

    def body(q, k, v, kv_pos, t, kv_valid, *scales):
        ks, vs = scales if quantized else (None, None)
        return _decode_mod.decode_attention(q, k, v, kv_pos, t,
                                            window=window, kv_valid=kv_valid,
                                            kscale=ks, vscale=vs,
                                            interpret=_interp(kb))

    in_specs = (P(bx, None, md, None), P(bx, None, md, None),
                P(bx, None, md, None), P(bx, None), P(bx),
                P(bx, None))
    args = (q, k, v, kv_pos, t, kv_valid)
    if quantized:
        in_specs += (P(bx, None, md), P(bx, None, md))
        args += (kscale, vscale)
    return SH.shard_map_compat(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=P(bx, None, md, None),
    )(*args)


def paged_decode_attention_sharded(q, kp, vp, table, t, pvalid, *,
                                   backend=None, mesh=None, kscale=None,
                                   vscale=None):
    """Paged-pool decode kernel, one grid PER SHARD: kv heads shard over
    `model`, and the POOL's page axis shards over the data axes alongside
    the slot batch — replica locality (the serving engine only hands a
    slot pages from its own replica's contiguous id range, enforced by
    ``PagePool``) is exactly pool-shard locality, so each shard gathers
    only local pages. Page-table entries arrive as GLOBAL ids and are
    rebased in-body by the shard's page offset. Requires Hp % model == 0,
    K % model == 0, and B/N divisible by the data size; anything else, or
    a ref/trivial-mesh call, falls back to the plain entry point."""
    from jax.sharding import PartitionSpec as P
    import jax.numpy as jnp
    from repro.runtime import sharding as SH
    kb = resolve_backend(backend)
    mesh, ba, d, m = _mesh_layout(mesh)
    B, _, Hp, _ = q.shape
    N, K = kp.shape[0], kp.shape[2]
    if (mesh is None or kb == "ref" or (d <= 1 and m <= 1)
            or Hp % m or K % m or B % d or N % d):
        return paged_decode_attention(q, kp, vp, table, t, pvalid, kscale,
                                      vscale, backend=backend)
    bx = ba if d > 1 else None
    md = "model" if "model" in mesh.axis_names else None
    pages_per_shard = N // d
    quantized = kscale is not None

    def body(q, kp, vp, table, t, pvalid, *scales):
        ks, vs = scales if quantized else (None, None)
        if bx is not None:
            ridx = 0
            for ax in bx:
                ridx = ridx * mesh.shape[ax] + jax.lax.axis_index(ax)
            table = jnp.where(table >= 0,
                              table - ridx * pages_per_shard, -1)
        return _paged_decode_mod.paged_decode_attention(
            q, kp, vp, table, t, pvalid, kscale=ks, vscale=vs,
            interpret=_interp(kb))

    in_specs = (P(bx, None, md, None), P(bx, None, md, None),
                P(bx, None, md, None), P(bx, None), P(bx),
                P(bx, None))
    args = (q, kp, vp, table, t, pvalid)
    if quantized:
        # scale pools shard like the KV pool minus the Dh axis: pages over
        # the data axes, kv-heads over `model`
        in_specs += (P(bx, None, md), P(bx, None, md))
        args += (kscale, vscale)
    return SH.shard_map_compat(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=P(bx, None, md, None),
    )(*args)


def fused_mlp_routed_sharded(x, idx, wi, wo, wg=None, token_weights=None,
                             valid_count=None, *, act="swiglu", backend=None,
                             mesh=None, wi_scale=None, wo_scale=None,
                             wg_scale=None):
    """Gather/scatter-fused routed MLP with the FFN dim sharded over
    `model` (the dense-MLP TP rules: wi/wg (D, F/m), wo (F/m, D)): each
    shard runs the index-prefetch kernel on its slice — the RoutingPlan's
    ``idx`` rides in REPLICATED, so one plan drives every TP shard — and
    the partial (B, S, D) deltas are psummed. On a data-only mesh (model
    absent or size 1) the batch still shards and the psum drops out — same
    as the decode wrapper; an unsharded fallback there would replicate the
    (B, S, D) stream to every device. Differentiable (the inner op carries
    the ref-replay VJP; psum transposes to its own gradient). Falls back to
    the plain entry point off-mesh / for "ref" / when the FFN or batch dim
    doesn't divide."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as SH
    kb = resolve_backend(backend)
    mesh, ba, d, m = _mesh_layout(mesh)
    B = x.shape[0]
    F = wi.shape[-1]
    if (mesh is None or kb == "ref" or (d <= 1 and m <= 1)
            or F % m or B % d):
        return fused_mlp_routed(x, idx, wi, wo, wg, token_weights,
                                valid_count, wi_scale, wo_scale, wg_scale,
                                act=act, backend=backend)
    bx = ba if d > 1 else None
    md = ("model" if m > 1 and "model" in mesh.axis_names else None)
    qw = wi_scale is not None
    args = [x, idx, wi, wo]
    specs = [P(bx, None, None), P(bx, None), P(None, md),
             P(md, None)]
    have = [True, True]             # wg / token_weights present?
    if wg is not None:
        args.append(wg)
        specs.append(P(None, md))
    else:
        have[0] = False
    if token_weights is not None:
        args.append(token_weights)
        specs.append(P(bx, None))
    else:
        have[1] = False
    if valid_count is not None:
        args.append(valid_count)
        specs.append(P(bx) if getattr(valid_count, "ndim", 0) else P())
    if qw:
        # per-output-channel scales shard with their weight's output axis:
        # wi/wg scales (F,) over `model`, wo scale (D,) replicated
        args.append(wi_scale)
        specs.append(P(md))
        if have[0]:
            args.append(wg_scale)
            specs.append(P(md))
        args.append(wo_scale)
        specs.append(P(None))

    def body(x, idx, wi, wo, *rest):
        it = iter(rest)
        wg_l = next(it) if have[0] else None
        tw_l = next(it) if have[1] else None
        cnt = next(it) if valid_count is not None else None
        if qw:
            wis = next(it)
            wgs = next(it) if have[0] else None
            wos = next(it)
            # serving-only int8 path: no VJP (see fused_mlp above)
            y = _fused_mlp_mod.fused_mlp_routed(
                x, idx, wi, wo, wg_l, tw_l, act=act, valid_count=cnt,
                wi_scale=wis, wo_scale=wos, wg_scale=wgs,
                interpret=_interp(kb))
        else:
            y = _fused_mlp_routed_op(act, kb, x, idx, wi, wo, wg_l, tw_l,
                                     cnt)
        return jax.lax.psum(y, md) if md else y

    return SH.shard_map_compat(
        body, mesh=mesh, in_specs=tuple(specs),
        out_specs=P(bx, None, None),
    )(*args)
