"""jit'd public wrappers for the Pallas kernels.

On TPU backends the pallas_call path is used; elsewhere (this CPU container)
the kernels run under interpret=True when `force_pallas` (tests) or fall back
to the jnp reference — bit-compatible semantics either way. The ragged
valid-count arguments (`valid_count` / `group_counts` / `kv_count`) are
traced, so one bucket-sized compile serves every occupancy.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_mlp import fused_mlp as _fused_mlp
from repro.kernels.moe_gmm import moe_gmm as _moe_gmm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "force_pallas"))
def flash_attention(q, k, v, kv_valid=None, kv_count=None, *, causal=True,
                    window=0, force_pallas=False):
    if _on_tpu() or force_pallas:
        return _flash(q, k, v, causal=causal, window=window,
                      kv_valid=kv_valid, kv_count=kv_count,
                      interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   kv_valid=kv_valid, kv_count=kv_count)


@partial(jax.jit, static_argnames=("act", "force_pallas"))
def fused_mlp(x, wi, wo, wg=None, token_weights=None, valid_count=None, *,
              act="swiglu", force_pallas=False):
    if _on_tpu() or force_pallas:
        return _fused_mlp(x, wi, wo, wg, token_weights, act=act,
                          valid_count=valid_count, interpret=not _on_tpu())
    return ref.fused_mlp_ref(x, wi, wo, wg, token_weights, act=act,
                             valid_count=valid_count)


@partial(jax.jit, static_argnames=("act", "force_pallas"))
def moe_gmm(x, wi, wo, wg=None, weights=None, group_counts=None, *,
            act="swiglu", force_pallas=False):
    if _on_tpu() or force_pallas:
        return _moe_gmm(x, wi, wo, wg, weights, act=act,
                        group_counts=group_counts, interpret=not _on_tpu())
    return ref.moe_gmm_ref(x, wi, wo, wg, weights, act=act,
                           group_counts=group_counts)
