"""Pallas TPU decode attention over the PAGED KV pool — the one-token
hot path when the serving engine runs the block-paged cache
(``runtime/pagedkv.py``).

Unlike the ring kernel there is no per-slot (B, L) cache: K/V live in a
global page pool of shape (N, page_size, K, Dh) and slot ``b`` owns the
pages named by its page-table row ``table[b]`` (int32, -1 = unused).
Positions are implicit in the table layout — table entry ``p`` of a row
holds absolute positions ``[p * page_size, (p+1) * page_size)`` — so the
kernel needs no position array: a key at page-entry ``p``, lane ``j`` is
attendable iff

    table[b, p] >= 0                      (entry backed by a page)
    p * page_size + j <= t[b]             (causal at this slot's position)
    pvalid[table[b, p], j]                (ElastiFormer token routing:
                                           skipped tokens hold no KV)

The page table and per-slot lengths ride scalar prefetch and the K/V
BlockSpec index_map gathers pages straight from the pool — the same
index-prefetch pattern as ``fused_mlp_routed`` — with ``max(entry, 0)``
keeping unused entries in bounds (their lanes are masked). One
(B, H, table_len) grid with the online-softmax f32 accumulator carried
across the page dimension, GQA via the head-major index map; the jnp
oracle is ``kernels/ref.py::paged_decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def analysis_example():
    """Representative paged-pool decode call for the static kernel
    verifier: a pool with free pages, table rows with -1 holes, per-slot
    offsets riding scalar prefetch, GQA 2:1."""
    import numpy as np
    B, N, ps, H, K, Dh = 2, 8, 16, 4, 2, 128
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, ps, K, Dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, ps, K, Dh)), jnp.float32)
    table = np.full((B, 3), -1, np.int32)
    table[0, :2] = [4, 1]                 # 2 pages, mid-page offset
    table[1, :3] = [0, 6, 2]              # 3 pages, page-boundary offset
    t = jnp.asarray([20, 47], jnp.int32)
    pvalid = jnp.asarray(rng.integers(0, 2, size=(N, ps)), bool)
    return (paged_decode_attention,
            (q, kp, vp, jnp.asarray(table), t, pvalid),
            dict(interpret=True))


def _kernel(tbl_ref, t_ref, q_ref, k_ref, v_ref, pv_ref, ks_ref, vs_ref,
            o_ref, m_sc, l_sc, acc_sc, *, page_size: int, sm_scale: float,
            n_pb: int):
    ib = pl.program_id(0)
    ip = pl.program_id(2)
    t = t_ref[ib]
    entry = tbl_ref[ib, ip]

    @pl.when(ip == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)                   # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                   # (ps, d)
    if ks_ref is not None:
        # int8 pool: widen in-register, per-(lane, kv-head) f32 scale —
        # HBM only ever saw the int8 page (docs/quantization.md)
        k = k * ks_ref[0, 0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                      # (1, ps)
    pos = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                     # (1, ps)
    mask = (entry >= 0) & (pos <= t) & (pv_ref[0][None, :] > 0)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_sc[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_sc[:, 0] = l_sc[:, 0] * alpha + jnp.sum(p, axis=1)
    m_sc[:, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32)
    if vs_ref is not None:
        v = v * vs_ref[0, 0][:, None]
    v = jnp.where(mask[0][:, None], v, 0.0)   # masked rows: 0 * NaN guard
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ip == n_pb - 1)
    def _finish():
        l = jnp.maximum(l_sc[:, 0], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, kp, vp, table, t, pvalid, *, kscale=None,
                           vscale=None, sm_scale: float | None = None,
                           interpret: bool = False):
    """q: (B, 1, H, Dh); kp, vp: (N, page_size, K, Dh) global page pool;
    table: (B, P) i32 page-table rows (-1 = unused entry); t: (B,) i32
    per-slot decode positions; pvalid: (N, page_size) bool per-lane
    routing validity; kscale/vscale: (N, page_size, K) f32 per-(lane,
    kv-head) dequant scale pools when kp/vp are int8 (both or neither).
    Returns (B, 1, H, Dh)."""
    B, Sq, H, Dh = q.shape
    N, ps, K = kp.shape[0], kp.shape[1], kp.shape[2]
    P = table.shape[1]
    G = H // K
    quantized = kscale is not None
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    table = jnp.asarray(table, jnp.int32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))

    kt = kp.transpose(2, 0, 1, 3)                         # (K, N, ps, Dh)
    vt = vp.transpose(2, 0, 1, 3)
    qt = q.transpose(0, 2, 1, 3)                          # (B, H, 1, Dh)

    kernel = functools.partial(_kernel, page_size=ps, sm_scale=sm_scale,
                               n_pb=P)
    # unused entries (-1) clamp to page 0 for the DMA; their lanes are
    # masked in-kernel by the entry >= 0 test
    page_im = lambda b, h, p, tbl, tt: \
        (h // G, jnp.maximum(tbl[b, p], 0), 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, 1, Dh),
                     lambda b, h, p, tbl, tt: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, ps, Dh), page_im),
        pl.BlockSpec((1, 1, ps, Dh), page_im),
        pl.BlockSpec((1, ps),
                     lambda b, h, p, tbl, tt:
                     (jnp.maximum(tbl[b, p], 0), 0)),
    ]
    args = [qt, kt, vt, pvalid.astype(jnp.int32)]
    if quantized:
        # scale pool rides head-major like the KV pool, gathered by the
        # same page-table index map
        sspec = pl.BlockSpec((1, 1, ps), lambda b, h, p, tbl, tt:
                             (h // G, jnp.maximum(tbl[b, p], 0), 0))
        in_specs += [sspec, sspec]
        args += [kscale.astype(jnp.float32).transpose(2, 0, 1),
                 vscale.astype(jnp.float32).transpose(2, 0, 1)]
        kfn = kernel
    else:
        kfn = lambda tbl_ref, t_ref, q_ref, k_ref, v_ref, pv_ref, *rest: \
            kernel(tbl_ref, t_ref, q_ref, k_ref, v_ref, pv_ref, None, None,
                   *rest)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, Dh),
                               lambda b, h, p, tbl, tt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, LANES), jnp.float32),
            pltpu.VMEM((1, LANES), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, t, *args)
    return out.transpose(0, 2, 1, 3)
