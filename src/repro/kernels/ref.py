"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
that tests/test_kernels.py sweeps shapes/dtypes against). The ragged
valid-count arguments mirror the kernels' scalar-prefetch contract: rows
past the count produce exact zeros."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _dq_kv(x, scale):
    """int8 KV + per-(token, head) scale -> f32 (identity when no scale)."""
    if scale is None:
        return x
    return x.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def _dq_w(w, scale):
    """int8 weight + per-output-channel scale -> f32: the scale spans the
    LAST axis block — (F,) for (D, F), (D,) for (F, D), (E, F)/(E, D) for
    expert stacks — broadcasting over the reduced axis at -2."""
    if scale is None:
        return w
    return w.astype(jnp.float32) * scale.astype(jnp.float32)[..., None, :]


def flash_attention_ref(q, k, v, *, causal=True, window=0, kv_valid=None,
                        sm_scale=None, kv_count=None):
    """q: (B,Sq,H,Dh); k,v: (B,Sk,K,Dh) -> (B,Sq,H,Dh). Dense softmax.
    kv_count: scalar or (B,) ragged prefix count over the q/kv buffers."""
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    qg = q.reshape(B, Sq, K, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * sm_scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= (qpos - kpos) < window
    mask = jnp.broadcast_to(mask, (B, 1, 1, Sq, Sk))
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    if kv_count is not None:
        cnt = jnp.broadcast_to(jnp.asarray(kv_count, jnp.int32).reshape(-1),
                               (B,))
        mask = mask & (kpos < cnt[:, None, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", a, v.astype(jnp.float32))
    ctx = ctx.reshape(B, Sq, H, Dh)
    if kv_count is not None:
        ctx = jnp.where(
            jnp.arange(Sq)[None, :, None, None] < cnt[:, None, None, None],
            ctx, 0.0)
    return ctx.astype(q.dtype)


def decode_attention_ref(q, k, v, kv_pos, t, *, window=0, kv_valid=None,
                         kscale=None, vscale=None, sm_scale=None):
    """Ring-cache decode attention oracle. q: (B,1,H,Dh); k,v: (B,L,K,Dh);
    kv_pos: (B,L) absolute positions (-1 = empty); t: (B,) per-slot decode
    positions; kscale/vscale: (B,L,K) f32 dequant scales for int8 k/v.
    Masks by the cache's position array, not by slot index."""
    k, v = _dq_kv(k, kscale), _dq_kv(v, vscale)
    B, Sq, H, Dh = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))
    qg = q.reshape(B, Sq, K, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * sm_scale
    pos = kv_pos.astype(jnp.int32)
    mask = (pos >= 0) & (pos <= t[:, None])
    if window and window > 0:
        mask &= (t[:, None] - pos) < window
    if kv_valid is not None:
        mask &= kv_valid
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", a, v.astype(jnp.float32))
    ctx = ctx.reshape(B, Sq, H, Dh)
    # rows with NO attendable key (fresh slot, everything routed out):
    # softmax of an all -NEG_INF row is uniform garbage — the kernel
    # returns exact zeros there, and this oracle must match it
    ctx = jnp.where(mask.any(-1)[:, None, None, None], ctx, 0.0)
    return ctx.astype(q.dtype)


def paged_decode_attention_ref(q, kp, vp, table, t, pvalid, *, kscale=None,
                               vscale=None, sm_scale=None):
    """Paged-pool decode attention oracle. q: (B,1,H,Dh); kp, vp:
    (N, page_size, K, Dh) global page pool; table: (B,P) i32 page-table
    rows (-1 = unused); t: (B,) per-slot decode positions; pvalid:
    (N, page_size) routing validity; kscale/vscale: (N, page_size, K) f32
    dequant scale pools for int8 kp/vp. Gathers each slot's pages and
    masks by the implicit position ``p * page_size + lane``."""
    kp, vp = _dq_kv(kp, kscale), _dq_kv(vp, vscale)
    B, Sq, H, Dh = q.shape
    N, ps, K = kp.shape[0], kp.shape[1], kp.shape[2]
    P = table.shape[1]
    G = H // K
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    table = jnp.asarray(table, jnp.int32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))
    pid = jnp.maximum(table, 0)                       # (B, P)
    k = kp[pid].reshape(B, P * ps, K, Dh)             # gather pages
    v = vp[pid].reshape(B, P * ps, K, Dh)
    pos = (jnp.arange(P)[:, None] * ps
           + jnp.arange(ps)[None, :]).reshape(-1)     # (P*ps,) implicit
    mask = ((table[:, :, None] >= 0)
            & pvalid[pid]).reshape(B, P * ps)
    mask &= pos[None, :] <= t[:, None]
    qg = q.reshape(B, Sq, K, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * sm_scale
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", a, v.astype(jnp.float32))
    ctx = ctx.reshape(B, Sq, H, Dh)
    # rows with no attendable key match the kernel's exact zeros
    ctx = jnp.where(mask.any(-1)[:, None, None, None], ctx, 0.0)
    return ctx.astype(q.dtype)


def _act(name):
    return jax.nn.silu if name == "swiglu" else jax.nn.gelu


def fused_mlp_ref(x, wi, wo, wg=None, token_weights=None, *, act="swiglu",
                  valid_count=None, wi_scale=None, wo_scale=None,
                  wg_scale=None):
    """x: (T, D) or (B, T, D); valid_count: None | scalar | (B,);
    wi_scale/wg_scale (F,) and wo_scale (D,): int8 weight dequant."""
    wi, wo, wg = _dq_w(wi, wi_scale), _dq_w(wo, wo_scale), \
        (_dq_w(wg, wg_scale) if wg is not None else None)
    xf = x.astype(jnp.float32)
    h = xf @ wi.astype(jnp.float32)
    if wg is not None:
        g = _act(act)(xf @ wg.astype(jnp.float32))
        h = g * h
    else:
        h = jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)
    y = h @ wo.astype(jnp.float32)
    if token_weights is not None:
        y = y * token_weights.astype(jnp.float32)[..., None]
    if valid_count is not None:
        cnt = jnp.asarray(valid_count, jnp.int32)
        rows = jnp.arange(x.shape[-2])
        if x.ndim == 3:
            cnt = jnp.broadcast_to(cnt.reshape(-1), (x.shape[0],))
            y = jnp.where(rows[None, :, None] < cnt[:, None, None], y, 0.0)
        else:
            y = jnp.where(rows[:, None] < cnt, y, 0.0)
    return y.astype(x.dtype)


def fused_mlp_routed_ref(x, idx, wi, wo, wg=None, token_weights=None, *,
                         act="swiglu", valid_count=None, wi_scale=None,
                         wo_scale=None, wg_scale=None):
    """Gather/compute/scatter oracle for the index-prefetch routed MLP.
    x: (B, S, D); idx: (B, Kb); returns the (B, S, D) delta."""
    B, S, D = x.shape
    Kb = idx.shape[-1]
    expand = (slice(None), slice(None), None)
    x_sel = jnp.take_along_axis(x, idx[expand], axis=1)
    tw = (jnp.ones((B, Kb), x.dtype) if token_weights is None
          else token_weights)
    y = fused_mlp_ref(x_sel, wi, wo, wg, tw, act=act,
                      valid_count=valid_count, wi_scale=wi_scale,
                      wo_scale=wo_scale, wg_scale=wg_scale)
    out = jnp.zeros_like(x)
    b = jnp.arange(B)[:, None]
    return out.at[b, idx].add(y.astype(x.dtype))


def moe_gmm_ref(x, wi, wo, wg=None, weights=None, *, act="swiglu",
                group_counts=None, wi_scale=None, wo_scale=None,
                wg_scale=None):
    """x: (E, C, D) or batched (B, E, C, D); group_counts: (E,) / (B, E);
    wi_scale/wg_scale (E, Fe) and wo_scale (E, D): int8 weight dequant."""
    wi, wo, wg = _dq_w(wi, wi_scale), _dq_w(wo, wo_scale), \
        (_dq_w(wg, wg_scale) if wg is not None else None)
    xf = x.astype(jnp.float32)
    h = jnp.einsum("...ecd,edf->...ecf", xf, wi.astype(jnp.float32))
    if wg is not None:
        g = _act(act)(jnp.einsum("...ecd,edf->...ecf", xf,
                                 wg.astype(jnp.float32)))
        h = g * h
    else:
        h = _act(act)(h)
    y = jnp.einsum("...ecf,efd->...ecd", h, wo.astype(jnp.float32))
    if weights is not None:
        y = y * weights.astype(jnp.float32)[..., None]
    if group_counts is not None:
        cnt = jnp.asarray(group_counts, jnp.int32)
        y = jnp.where(
            jnp.arange(x.shape[-2])[:, None] < cnt[..., None, None], y, 0.0)
    return y.astype(x.dtype)
