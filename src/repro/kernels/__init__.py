from repro.kernels.ops import flash_attention, fused_mlp, moe_gmm

__all__ = ["flash_attention", "fused_mlp", "moe_gmm"]
