from repro.kernels.ops import (decode_attention, flash_attention, fused_mlp,
                               fused_mlp_routed, moe_gmm,
                               paged_decode_attention, resolve_backend)

__all__ = ["decode_attention", "flash_attention", "fused_mlp",
           "fused_mlp_routed", "moe_gmm", "paged_decode_attention",
           "resolve_backend", "analyzable_kernels"]


def analyzable_kernels() -> dict:
    """name -> zero-arg builder returning ``(fn, args, kwargs)`` for one
    representative call of each Pallas kernel — the enumeration the static
    kernel verifier (``repro.analysis.pallas_lint``) walks. A new kernel
    is added here once and inherits the in-bounds / MXU-alignment /
    scalar-prefetch gates for free."""
    # importlib: the function re-exports above shadow the submodule names
    import importlib
    _da = importlib.import_module("repro.kernels.decode_attention")
    _fa = importlib.import_module("repro.kernels.flash_attention")
    _fm = importlib.import_module("repro.kernels.fused_mlp")
    _mg = importlib.import_module("repro.kernels.moe_gmm")
    _pd = importlib.import_module("repro.kernels.paged_decode_attention")
    return {
        "flash_attention": _fa.analysis_example,
        "fused_mlp": _fm.analysis_example,
        "fused_mlp_routed": _fm.analysis_example_routed,
        "moe_gmm": _mg.analysis_example,
        "decode_attention": _da.analysis_example,
        "paged_decode_attention": _pd.analysis_example,
    }
