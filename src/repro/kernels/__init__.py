from repro.kernels.ops import (decode_attention, flash_attention, fused_mlp,
                               fused_mlp_routed, moe_gmm, resolve_backend)

__all__ = ["decode_attention", "flash_attention", "fused_mlp",
           "fused_mlp_routed", "moe_gmm", "resolve_backend"]
