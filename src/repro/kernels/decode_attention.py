"""Pallas TPU decode attention over the serving engine's RING KV cache —
the one-token-per-slot hot path of the continuous-batching decode step.

Unlike prefill flash attention, the ring cache is NOT position-ordered:
entry for absolute position p lives at slot p % L, empty slots carry
pos == -1, and every serving slot decodes at its own offset t[b]. So the
kernel masks by the cache's absolute-position array instead of by array
index: a key at slot j is attendable iff

    kv_pos[b, j] >= 0            (slot ever written)
    kv_pos[b, j] <= t[b]         (causal at this slot's position)
    t[b] - kv_pos[b, j] < window (sliding window, if any)
    kv_valid[b, j]               (ElastiFormer token routing: skipped
                                  tokens never entered the cache)

Per-slot positions ride scalar prefetch; one (B, H, L/block) grid with an
online-softmax f32 accumulator carried across the kv-block dimension, GQA
via the head-major block index map — the decode twin of
kernels/flash_attention.py, shaped for Sq == 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def analysis_example():
    """Representative ring-cache decode call for the static kernel
    verifier: partially-filled ring (pos == -1 holes), per-slot offsets
    riding scalar prefetch, GQA 2:1."""
    import numpy as np
    B, L, H, K, Dh = 2, 256, 4, 2, 128
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, K, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, K, Dh)), jnp.float32)
    pos = np.full((B, L), -1, np.int32)
    pos[0, :40] = np.arange(40)
    pos[1, :200] = np.arange(200)
    t = jnp.asarray([39, 199], jnp.int32)
    valid = jnp.asarray(rng.integers(0, 2, size=(B, L)), bool)
    return (decode_attention, (q, k, v, jnp.asarray(pos), t),
            dict(kv_valid=valid, interpret=True))


def _kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, valid_ref, ks_ref, vs_ref,
            o_ref, m_sc, l_sc, acc_sc, *, window: int, sm_scale: float,
            n_kb: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    t = t_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)                  # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    if ks_ref is not None:
        # int8 cache: widen in-register, per-(slot, kv-head) f32 scale —
        # HBM only ever saw the int8 tile (docs/quantization.md)
        k = k * ks_ref[0, 0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                      # (1, bk)
    pos = pos_ref[0][None, :]                             # (1, bk) i32
    mask = (pos >= 0) & (pos <= t)
    if window and window > 0:
        mask &= (t - pos) < window
    if valid_ref is not None:
        mask &= valid_ref[0][None, :] > 0
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_sc[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_sc[:, 0] = l_sc[:, 0] * alpha + jnp.sum(p, axis=1)
    m_sc[:, 0] = m_new
    v = v_ref[0, 0].astype(jnp.float32)
    if vs_ref is not None:
        v = v * vs_ref[0, 0][:, None]
    v = jnp.where(mask[0][:, None], v, 0.0)   # masked rows: 0 * NaN guard
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_sc[:, 0], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_pos, t, *, window: int = 0, kv_valid=None,
                     kscale=None, vscale=None, block_k: int = 128,
                     sm_scale: float | None = None,
                     interpret: bool = False):
    """q: (B, 1, H, Dh); k, v: (B, L, K, Dh) ring caches; kv_pos: (B, L)
    i32 absolute positions (-1 = empty slot); t: (B,) i32 per-slot decode
    positions; kv_valid: (B, L) bool (routing validity); kscale/vscale:
    (B, L, K) f32 per-(slot, kv-head) dequant scales when k/v are int8
    (both or neither). Returns (B, 1, H, Dh)."""
    B, Sq, H, Dh = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    quantized = kscale is not None
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    bk = min(block_k, L)
    nkb = pl.cdiv(L, bk)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32).reshape(-1), (B,))
    # pad slots carry pos == -1 -> masked, so block padding is inert
    pos = kv_pos.astype(jnp.int32)
    if nkb * bk != L:
        pad = nkb * bk - L
        pos = jnp.pad(pos, [(0, 0), (0, pad)], constant_values=-1)
        padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, [(0, 0), (0, pad)])
        if quantized:
            kscale = jnp.pad(kscale, [(0, 0), (0, pad), (0, 0)])
            vscale = jnp.pad(vscale, [(0, 0), (0, pad), (0, 0)])

    qt = q.transpose(0, 2, 1, 3)                          # (B,H,1,Dh)
    kt = k.transpose(0, 2, 1, 3)                          # (B,K,L,Dh)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, window=window, sm_scale=sm_scale,
                               n_kb=nkb)
    in_specs = [
        pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j, *_: (b, h // G, j, 0)),
        pl.BlockSpec((1, 1, bk, Dh), lambda b, h, j, *_: (b, h // G, j, 0)),
        pl.BlockSpec((1, bk), lambda b, h, j, *_: (b, j)),
    ]
    args = [qt, kt, vt, pos]
    have_valid = kv_valid is not None
    if have_valid:
        in_specs.append(pl.BlockSpec((1, bk), lambda b, h, j, *_: (b, j)))
        args.append(kv_valid.astype(jnp.int32))
    if quantized:
        # scales ride as regular VMEM blocks, head-major like k/v
        sspec = pl.BlockSpec((1, 1, bk), lambda b, h, j, *_: (b, h // G, j))
        in_specs += [sspec, sspec]
        args += [kscale.astype(jnp.float32).transpose(0, 2, 1),
                 vscale.astype(jnp.float32).transpose(0, 2, 1)]

    def kfn(t_ref, q_ref, k_ref, v_ref, pos_ref, *rest):
        rs = list(rest)
        valid_ref = rs.pop(0) if have_valid else None
        ks_ref = rs.pop(0) if quantized else None
        vs_ref = rs.pop(0) if quantized else None
        return kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, valid_ref,
                      ks_ref, vs_ref, *rs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nkb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, LANES), jnp.float32),
            pltpu.VMEM((1, LANES), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(t, *args)
    return out.transpose(0, 2, 1, 3)
