"""Pallas TPU flash attention (forward) with causal + sliding-window masks,
GQA, and a per-key validity mask (ElastiFormer token routing: unselected
tokens are invalid keys).

Layout: q (B, H, Sq, Dh), k/v (B, K, Sk, Dh) — heads-major so each grid cell
streams contiguous (block, Dh) tiles HBM->VMEM. Online softmax with f32
scratch accumulators carried across the innermost (sequential) kv-block grid
dimension; causal/window-dead blocks are skipped via pl.when so the lowered
kernel does ~half the work of the dense score matrix.

Ragged capacity-bucket execution: ``kv_count`` (scalar or per-row (B,),
scalar-prefetched) marks the first N tokens of the q/kv buffers as real —
kv blocks entirely past the count are skipped, q blocks past it write zeros
without computing, and the straddling block masks per-position. A
bucket-sized compile therefore does work quadratic in the *count*, not the
buffer. The ragged token-routing gather (core/routing.make_plan — the
block-shared RoutingPlan whose traced count IS this kernel's ``kv_count``)
keeps selected tokens position-ascending in the prefix, so array-index
causal masking inside the kernel IS causal masking over the selected
tokens. The model hot path reaches this kernel through kernels/ops.py
under ``ElasticSpec.kernel_backend`` ("pallas" on TPU, "interpret" for CPU
verification); sliding-window masking is index-based, so windowed GATHERED
attention stays on the jnp twin (models/attention._kernel_ok).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def analysis_example():
    """Representative call for the static kernel verifier
    (``repro.analysis.pallas_lint``): production-shaped tiles (Dh = 128,
    MXU-aligned 128-blocks), a ragged per-row count, GQA 2:1, both masks.
    Returns ``(fn, args, kwargs)``; the verifier intercepts the inner
    ``pallas_call`` and statically evaluates its grid x BlockSpec
    index_maps — the call itself never executes."""
    import numpy as np
    B, Sq, H, K, Dh = 2, 256, 4, 2, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, K, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, K, Dh)), jnp.float32)
    valid = jnp.asarray(rng.integers(0, 2, size=(B, Sq)), bool)
    cnt = jnp.asarray([Sq, 160], jnp.int32)
    return (flash_attention, (q, k, v),
            dict(causal=True, kv_valid=valid, kv_count=cnt, interpret=True))


def _kernel(cnt_ref, valid_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc,
            acc_sc, *, causal: bool, window: int, block_q: int, block_k: int,
            sm_scale: float, n_kb: int, sk: int):
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    cnt = cnt_ref[ib]

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * block_q
    k_start = ik * block_k
    run = q_start < cnt                # q block fully past the valid prefix
    run &= k_start < cnt               # kv block fully past the valid prefix
    if causal:  # skip blocks entirely above the diagonal
        run &= k_start <= q_start + block_q - 1
    if window and window > 0:  # skip blocks entirely outside the window
        run &= q_start - (k_start + block_k - 1) < window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < sk) & (kpos < cnt)
        if causal:
            mask &= kpos <= qpos
        if window and window > 0:
            mask &= (qpos - kpos) < window
        if valid_ref is not None:
            mask &= valid_ref[0][None, :] > 0
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[:, 0] = l_sc[:, 0] * alpha + jnp.sum(p, axis=1)
        m_sc[:, 0] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        # Rows past Sk / the valid count are block padding (NaN in interpret
        # mode); p is 0 there but 0*NaN = NaN in the dot, so zero them.
        vpos = k_start + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where((vpos < sk) & (vpos < cnt), v, 0.0)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_sc[:, 0], 1e-30)
        y = acc_sc[...] / l[:, None]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
        y = jnp.where(rows < cnt, y, 0.0)
        o_ref[0, 0] = y.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_valid=None, block_q: int = 128, block_k: int = 128,
                    sm_scale: float | None = None, kv_count=None,
                    interpret: bool = False):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, K, Dh); kv_valid: (B, Sk) bool;
    kv_count: scalar or (B,) count of real leading tokens (None = Sk) —
    keys/queries past the count are skipped/zeroed (ragged bucket buffers).
    Returns (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    sm_scale = Dh ** -0.5 if sm_scale is None else sm_scale
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nkb = pl.cdiv(Sq, bq), pl.cdiv(Sk, bk)
    # default count caps nothing (kv padding is already masked via `sk`,
    # and q rows past Sk are legal when Sq > Sk)
    full = max(Sq, Sk)
    cnt = jnp.clip(jnp.asarray(
        full if kv_count is None else kv_count, jnp.int32), 0, full)
    cnt = jnp.broadcast_to(cnt.reshape(-1), (B,))

    qt = q.transpose(0, 2, 1, 3)                          # (B,H,Sq,Dh)
    kt = k.transpose(0, 2, 1, 3)                          # (B,K,Sk,Dh)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=bq, block_k=bk,
        sm_scale=sm_scale, n_kb=nkb, sk=Sk)
    in_specs = [
        pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j, *_: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, *_: (b, h // G, j, 0)),
        pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, *_: (b, h // G, j, 0)),
    ]
    args = [qt, kt, vt]
    if kv_valid is not None:
        in_specs.insert(0, pl.BlockSpec((1, bk), lambda b, h, i, j, *_: (b, j)))
        args.insert(0, kv_valid.astype(jnp.int32))
        kfn = kernel
    else:
        kfn = lambda cnt_ref, *rest: kernel(cnt_ref, None, *rest)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nq, nkb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, Dh),
                               lambda b, h, i, j, *_: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(cnt, *args)
    return out.transpose(0, 2, 1, 3)
