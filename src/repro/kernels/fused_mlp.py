"""Pallas TPU fused (gated) MLP with per-token output weighting — the compute
hot-spot of ElastiFormer's *input subset selection* (routed MLP).

y[t] = w[t] * ( act(x[t] @ Wg) * (x[t] @ Wi) ) @ Wo

Fusing both matmuls + activation means the (T, F) hidden activation never
round-trips to HBM (F is 3-4x D on the assigned archs); the kernel tiles
F into VMEM-sized blocks and accumulates the down-projection into an f32
scratch across the sequential F-grid dimension.

Two entry points:

* ``fused_mlp`` — x is a (T, D) or batched (B, T, D) buffer (the routed
  capacity-bucket buffer a RoutingPlan gathered in XLA). ``valid_count``
  (scalar or per-row (B,), scalar-prefetched) marks the first N rows as
  real tokens — token tiles entirely past the count are skipped (zero
  write, no matmuls), the straddling tile zeroes its trailing rows. A
  bucket-sized compile therefore does work proportional to the *count*,
  not the buffer.

* ``fused_mlp_routed`` — index-prefetch gather/scatter fusion: x stays the
  FULL (B, S, D) residual stream and the RoutingPlan's gather indices ride
  scalar prefetch; each grid step pulls its selected row straight from x
  via the BlockSpec index_map and writes the weighted output back to the
  row's original position, so the bucket-sized student buffer never
  materializes in HBM at all. (Row-granular tiles trade MXU utilisation
  for zero gather/scatter traffic — the right trade when the bucket is
  bandwidth- rather than FLOP-bound.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def analysis_example():
    """Representative ``fused_mlp`` call for the static kernel verifier
    (see flash_attention.analysis_example): bucket-buffer layout, ragged
    per-row counts, gated act."""
    import numpy as np
    B, T, D, F = 2, 256, 128, 512
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wo = jnp.asarray(rng.normal(size=(F, D)), jnp.float32)
    tw = jnp.asarray(rng.normal(size=(B, T)), jnp.float32)
    cnt = jnp.asarray([T, 100], jnp.int32)
    return (fused_mlp, (x, wi, wo, wg, tw),
            dict(valid_count=cnt, interpret=True))


def analysis_example_routed():
    """Representative ``fused_mlp_routed`` call: full-stream x, plan
    indices riding scalar prefetch (the index-prefetch gather the verifier
    proves in-bounds by evaluating the BlockSpec index_map over the real
    prefetch operand)."""
    import numpy as np
    B, S, Kb, D, F = 2, 128, 32, 128, 512
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.permutation(S)[:Kb] for _ in range(B)]), jnp.int32)
    wi = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)), jnp.float32)
    wo = jnp.asarray(rng.normal(size=(F, D)), jnp.float32)
    tw = jnp.asarray(rng.normal(size=(B, Kb)), jnp.float32)
    cnt = jnp.asarray([Kb, 20], jnp.int32)
    return (fused_mlp_routed, (x, idx, wi, wo, wg, tw),
            dict(valid_count=cnt, interpret=True))


def _ffn_block(x, wi_ref, wg_ref, wis_ref=None, wgs_ref=None, *, act: str):
    wi = wi_ref[...].astype(jnp.float32)
    if wis_ref is not None:
        # int8 weights: widen in-register, per-output-channel f32 scale —
        # HBM only ever saw the int8 tile (docs/quantization.md)
        wi = wi * wis_ref[0][None, :]
    hi = jax.lax.dot(x, wi, preferred_element_type=jnp.float32)
    if wg_ref is not None:
        wg = wg_ref[...].astype(jnp.float32)
        if wgs_ref is not None:
            wg = wg * wgs_ref[0][None, :]
        hg = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
        a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg)
        return a * hi
    return jax.nn.gelu(hi) if act == "gelu" else jax.nn.silu(hi)


def _dq_wo(wo_ref, wos_ref):
    wo = wo_ref[...].astype(jnp.float32)
    if wos_ref is not None:
        wo = wo * wos_ref[0][None, :]
    return wo


def _kernel(cnt_ref, x_ref, wi_ref, wg_ref, wo_ref, tw_ref, wis_ref,
            wgs_ref, wos_ref, o_ref, acc_sc, *,
            act: str, n_fb: int, weighted: bool, block_t: int):
    ib = pl.program_id(0)
    it = pl.program_id(1)
    jf = pl.program_id(2)
    cnt = cnt_ref[ib]
    live = it * block_t < cnt

    @pl.when(jnp.logical_not(live) & (jf == n_fb - 1))
    def _dead():  # tile fully past the valid count: zero write, no compute
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(live)
    def _run():
        @pl.when(jf == 0)
        def _init():
            acc_sc[...] = jnp.zeros_like(acc_sc)

        x = x_ref[0].astype(jnp.float32)                       # (bt, D)
        acc_sc[...] += jax.lax.dot(
            _ffn_block(x, wi_ref, wg_ref, wis_ref, wgs_ref, act=act),
            _dq_wo(wo_ref, wos_ref),
            preferred_element_type=jnp.float32)

        @pl.when(jf == n_fb - 1)
        def _finish():
            y = acc_sc[...]
            if weighted:
                y = y * tw_ref[0].astype(jnp.float32)[:, :1]
            rows = it * block_t + jax.lax.broadcasted_iota(
                jnp.int32, y.shape, 0)
            y = jnp.where(rows < cnt, y, 0.0)
            o_ref[0] = y.astype(o_ref.dtype)


def fused_mlp(x, wi, wo, wg=None, token_weights=None, *, act: str = "swiglu",
              block_t: int = 256, block_f: int = 512, valid_count=None,
              wi_scale=None, wo_scale=None, wg_scale=None,
              interpret: bool = False):
    """x: (T, D) or (B, T, D); wi/wg: (D, F); wo: (F, D); token_weights:
    (T,) / (B, T) or None; valid_count: traced/static count of real leading
    rows — scalar or per-row (B,); None = T. Rows >= the count produce
    zeros and their tiles are skipped. wi_scale/wg_scale: (F,) and
    wo_scale: (D,) f32 per-output-channel dequant scales when the weights
    are int8. Returns x-shaped output."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
        if token_weights is not None:
            token_weights = jnp.asarray(token_weights).reshape(1, -1)
    B, T, D = x.shape
    F = wi.shape[1]
    bt, bf = min(block_t, T), min(block_f, F)
    nt, nf = pl.cdiv(T, bt), pl.cdiv(F, bf)
    if token_weights is None:
        tw = jnp.ones((B, T, 1), jnp.float32)
    else:  # (T,) broadcasts across the batch; (B, T) is per-row
        tw = jnp.broadcast_to(
            jnp.asarray(token_weights, jnp.float32).reshape(-1, T), (B, T)
        ).reshape(B, T, 1)
    tw = jnp.broadcast_to(tw, (B, T, 128))  # lane-replicated for TPU layout
    cnt = jnp.clip(jnp.asarray(
        T if valid_count is None else valid_count, jnp.int32), 0, T)
    cnt = jnp.broadcast_to(cnt.reshape(-1), (B,))
    have_g = wg is not None
    qw = wi_scale is not None

    kernel = functools.partial(_kernel, act=act, n_fb=nf,
                               weighted=token_weights is not None,
                               block_t=bt)
    in_specs = [
        pl.BlockSpec((1, bt, D), lambda b, i, j, *_: (b, i, 0)),
        pl.BlockSpec((D, bf), lambda b, i, j, *_: (0, j)),
    ]
    args = [x, wi]
    if have_g:
        in_specs.append(pl.BlockSpec((D, bf), lambda b, i, j, *_: (0, j)))
        args.append(wg)
    in_specs += [
        pl.BlockSpec((bf, D), lambda b, i, j, *_: (j, 0)),
        pl.BlockSpec((1, bt, 128), lambda b, i, j, *_: (b, i, 0)),
    ]
    args += [wo, tw]
    if qw:
        # per-output-channel scale rows as (1, F)/(1, D) VMEM blocks
        fspec = pl.BlockSpec((1, bf), lambda b, i, j, *_: (0, j))
        dspec = pl.BlockSpec((1, D), lambda b, i, j, *_: (0, 0))
        in_specs.append(fspec)
        args.append(wi_scale.astype(jnp.float32).reshape(1, F))
        if have_g:
            in_specs.append(fspec)
            args.append(wg_scale.astype(jnp.float32).reshape(1, F))
        in_specs.append(dspec)
        args.append(wo_scale.astype(jnp.float32).reshape(1, D))

    def kfn(cnt_ref, x_ref, *rest):
        rs = list(rest)
        wi_ref = rs.pop(0)
        wg_ref = rs.pop(0) if have_g else None
        wo_ref, tw_ref = rs.pop(0), rs.pop(0)
        wis_ref = rs.pop(0) if qw else None
        wgs_ref = rs.pop(0) if (qw and have_g) else None
        wos_ref = rs.pop(0) if qw else None
        return kernel(cnt_ref, x_ref, wi_ref, wg_ref, wo_ref, tw_ref,
                      wis_ref, wgs_ref, wos_ref, *rs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nt, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bt, D), lambda b, i, j, *_: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
    )
    out = pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cnt, *args)
    return out[0] if squeeze else out


def _routed_kernel(cnt_ref, idx_ref, x_ref, wi_ref, wg_ref, wo_ref, tw_ref,
                   wis_ref, wgs_ref, wos_ref, o_ref, acc_sc, *,
                   act: str, n_fb: int):
    ib = pl.program_id(0)
    it = pl.program_id(1)
    jf = pl.program_id(2)
    cnt = cnt_ref[ib]
    live = it < cnt

    @pl.when((it == 0) & (jf == 0))
    def _zero():  # first visit of this batch row's output slab
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(live)
    def _run():
        @pl.when(jf == 0)
        def _init():
            acc_sc[...] = jnp.zeros_like(acc_sc)

        x = x_ref[0].astype(jnp.float32)                        # (1, D)
        acc_sc[...] += jax.lax.dot(
            _ffn_block(x, wi_ref, wg_ref, wis_ref, wgs_ref, act=act),
            _dq_wo(wo_ref, wos_ref),
            preferred_element_type=jnp.float32)

        @pl.when(jf == n_fb - 1)
        def _finish():  # scatter: write the row back at its token position
            y = acc_sc[...] * tw_ref[0, 0, 0]
            row = idx_ref[ib, it]
            o_ref[0, pl.ds(row, 1), :] = y.astype(o_ref.dtype)


def fused_mlp_routed(x, idx, wi, wo, wg=None, token_weights=None, *,
                     act: str = "swiglu", block_f: int = 512,
                     valid_count=None, wi_scale=None, wo_scale=None,
                     wg_scale=None, interpret: bool = False):
    """Index-prefetch gather/scatter-fused routed MLP.

    x: (B, S, D) FULL residual-stream input; idx: (B, Kb) i32 RoutingPlan
    gather indices (each row a subset of 0..S-1, no duplicates);
    token_weights: (B, Kb) router weights (already zeroed on the invalid
    tail); valid_count: scalar or (B,) true selected count (None = Kb).
    Returns the (B, S, D) DELTA: weighted MLP outputs scattered back to
    their token positions, zeros everywhere else. The (B, Kb, D) student
    buffer of the gather-in-XLA path never exists in HBM: the plan indices
    ride scalar prefetch, each grid step's BlockSpec index_map gathers the
    selected row directly from x, and the output store is the inverse
    scatter. Grid steps past the valid count skip compute entirely.

    VMEM contract: one batch row's FULL (S, D) output slab stays resident
    across its grid steps, so this kernel only compiles/profits while
    S * D * itemsize fits the VMEM budget alongside the weight tiles —
    callers gate on blocks.ROUTED_MLP_SLAB_BYTES and fall back to
    gather-in-XLA + the batched ``fused_mlp`` above."""
    B, S, D = x.shape
    Kb = idx.shape[-1]
    F = wi.shape[1]
    bf = min(block_f, F)
    nf = pl.cdiv(F, bf)
    tw = (jnp.ones((B, Kb), jnp.float32) if token_weights is None
          else token_weights.astype(jnp.float32))
    tw = tw.reshape(B, Kb, 1, 1)  # SMEM-friendly per-row scalar
    cnt = jnp.clip(jnp.asarray(
        Kb if valid_count is None else valid_count, jnp.int32), 0, Kb)
    cnt = jnp.broadcast_to(cnt.reshape(-1), (B,))
    idx = jnp.clip(idx.astype(jnp.int32), 0, S - 1)
    have_g = wg is not None
    qw = wi_scale is not None

    kernel = functools.partial(_routed_kernel, act=act, n_fb=nf)
    # x gather happens IN THE INDEX MAP: block (1,1,D) at row idx[b, t]
    in_specs = [
        pl.BlockSpec((1, 1, D), lambda b, t, j, cnt_ref, idx_ref:
                     (b, idx_ref[b, t], 0)),
        pl.BlockSpec((D, bf), lambda b, t, j, *_: (0, j)),
    ]
    args = [x, wi]
    if have_g:
        in_specs.append(pl.BlockSpec((D, bf), lambda b, t, j, *_: (0, j)))
        args.append(wg)
    in_specs += [
        pl.BlockSpec((bf, D), lambda b, t, j, *_: (j, 0)),
        pl.BlockSpec((1, 1, 1, 1), lambda b, t, j, *_: (b, t, 0, 0)),
    ]
    args += [wo, tw]
    if qw:
        # per-output-channel scale rows as (1, F)/(1, D) VMEM blocks
        fspec = pl.BlockSpec((1, bf), lambda b, t, j, *_: (0, j))
        dspec = pl.BlockSpec((1, D), lambda b, t, j, *_: (0, 0))
        in_specs.append(fspec)
        args.append(wi_scale.astype(jnp.float32).reshape(1, F))
        if have_g:
            in_specs.append(fspec)
            args.append(wg_scale.astype(jnp.float32).reshape(1, F))
        in_specs.append(dspec)
        args.append(wo_scale.astype(jnp.float32).reshape(1, D))

    def kfn(cnt_ref, idx_ref, x_ref, *rest):
        rs = list(rest)
        wi_ref = rs.pop(0)
        wg_ref = rs.pop(0) if have_g else None
        wo_ref, tw_ref = rs.pop(0), rs.pop(0)
        wis_ref = rs.pop(0) if qw else None
        wgs_ref = rs.pop(0) if (qw and have_g) else None
        wos_ref = rs.pop(0) if qw else None
        return kernel(cnt_ref, idx_ref, x_ref, wi_ref, wg_ref, wo_ref,
                      tw_ref, wis_ref, wgs_ref, wos_ref, *rs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Kb, nf),
        in_specs=in_specs,
        # whole per-batch-row output slab stays resident; rows are stored
        # at their scattered positions as their F-accumulation completes
        out_specs=pl.BlockSpec((1, S, D), lambda b, t, j, *_: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(cnt, idx, *args)
