"""Pallas TPU fused (gated) MLP with per-token output weighting — the compute
hot-spot of ElastiFormer's *input subset selection* (routed MLP).

y[t] = w[t] * ( act(x[t] @ Wg) * (x[t] @ Wi) ) @ Wo

Fusing both matmuls + activation means the (T, F) hidden activation never
round-trips to HBM (F is 3-4x D on the assigned archs); the kernel tiles
F into VMEM-sized blocks and accumulates the down-projection into an f32
scratch across the sequential F-grid dimension. Token gather/scatter (the
top-k routing) stays in XLA — it is bandwidth-trivial next to the matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wi_ref, wg_ref, wo_ref, tw_ref, o_ref, acc_sc, *,
            act: str, n_fb: int, weighted: bool):
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    x = x_ref[...].astype(jnp.float32)                     # (bt, D)
    hi = jax.lax.dot(x, wi_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)   # (bt, bf)
    if wg_ref is not None:
        hg = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg)
        h = a * hi
    else:
        h = jax.nn.gelu(hi) if act == "gelu" else jax.nn.silu(hi)
    acc_sc[...] += jax.lax.dot(h, wo_ref[...].astype(jnp.float32),
                               preferred_element_type=jnp.float32)

    @pl.when(jf == n_fb - 1)
    def _finish():
        y = acc_sc[...]
        if weighted:
            y = y * tw_ref[...].astype(jnp.float32)[:, :1]
        o_ref[...] = y.astype(o_ref.dtype)


def fused_mlp(x, wi, wo, wg=None, token_weights=None, *, act: str = "swiglu",
              block_t: int = 256, block_f: int = 512,
              interpret: bool = False):
    """x: (T, D); wi/wg: (D, F); wo: (F, D); token_weights: (T,) or None.
    Returns (T, D)."""
    T, D = x.shape
    F = wi.shape[1]
    bt, bf = min(block_t, T), min(block_f, F)
    nt, nf = pl.cdiv(T, bt), pl.cdiv(F, bf)
    tw = (jnp.ones((T, 1), jnp.float32) if token_weights is None
          else token_weights.reshape(T, 1).astype(jnp.float32))
    tw = jnp.broadcast_to(tw, (T, 128))  # lane-replicated for TPU layout

    kernel = functools.partial(_kernel, act=act, n_fb=nf,
                               weighted=token_weights is not None)
    in_specs = [
        pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
        pl.BlockSpec((D, bf), lambda i, j: (0, j)),
    ]
    args = [x, wi]
    if wg is not None:
        in_specs.append(pl.BlockSpec((D, bf), lambda i, j: (0, j)))
        args.append(wg)
        kfn = kernel
    else:
        kfn = lambda x_ref, wi_ref, wo_ref, tw_ref, o_ref, acc: kernel(
            x_ref, wi_ref, None, wo_ref, tw_ref, o_ref, acc)
    in_specs += [
        pl.BlockSpec((bf, D), lambda i, j: (j, 0)),
        pl.BlockSpec((bt, 128), lambda i, j: (i, 0)),
    ]
    args += [wo, tw]

    return pl.pallas_call(
        kfn,
        grid=(nt, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
