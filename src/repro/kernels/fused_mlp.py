"""Pallas TPU fused (gated) MLP with per-token output weighting — the compute
hot-spot of ElastiFormer's *input subset selection* (routed MLP).

y[t] = w[t] * ( act(x[t] @ Wg) * (x[t] @ Wi) ) @ Wo

Fusing both matmuls + activation means the (T, F) hidden activation never
round-trips to HBM (F is 3-4x D on the assigned archs); the kernel tiles
F into VMEM-sized blocks and accumulates the down-projection into an f32
scratch across the sequential F-grid dimension. Token gather/scatter (the
top-k routing) stays in XLA — it is bandwidth-trivial next to the matmuls.

Ragged capacity-bucket execution: ``valid_count`` (a scalar-prefetched
traced count) marks the first N rows as real tokens — token tiles entirely
past the count are skipped (zero write, no matmuls), the straddling tile
zeroes its trailing rows. A bucket-sized compile therefore does work
proportional to the *count*, not the buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(cnt_ref, x_ref, wi_ref, wg_ref, wo_ref, tw_ref, o_ref, acc_sc, *,
            act: str, n_fb: int, weighted: bool, block_t: int):
    it = pl.program_id(0)
    jf = pl.program_id(1)
    cnt = cnt_ref[0]
    live = it * block_t < cnt

    @pl.when(jnp.logical_not(live) & (jf == n_fb - 1))
    def _dead():  # tile fully past the valid count: zero write, no compute
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(live)
    def _run():
        @pl.when(jf == 0)
        def _init():
            acc_sc[...] = jnp.zeros_like(acc_sc)

        x = x_ref[...].astype(jnp.float32)                     # (bt, D)
        hi = jax.lax.dot(x, wi_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)   # (bt, bf)
        if wg_ref is not None:
            hg = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
            a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg)
            h = a * hi
        else:
            h = jax.nn.gelu(hi) if act == "gelu" else jax.nn.silu(hi)
        acc_sc[...] += jax.lax.dot(h, wo_ref[...].astype(jnp.float32),
                                   preferred_element_type=jnp.float32)

        @pl.when(jf == n_fb - 1)
        def _finish():
            y = acc_sc[...]
            if weighted:
                y = y * tw_ref[...].astype(jnp.float32)[:, :1]
            rows = it * block_t + jax.lax.broadcasted_iota(
                jnp.int32, y.shape, 0)
            y = jnp.where(rows < cnt, y, 0.0)
            o_ref[...] = y.astype(o_ref.dtype)


def fused_mlp(x, wi, wo, wg=None, token_weights=None, *, act: str = "swiglu",
              block_t: int = 256, block_f: int = 512, valid_count=None,
              interpret: bool = False):
    """x: (T, D); wi/wg: (D, F); wo: (F, D); token_weights: (T,) or None;
    valid_count: traced/static count of real leading rows (None = T) —
    rows >= valid_count produce zeros and their tiles are skipped.
    Returns (T, D)."""
    T, D = x.shape
    F = wi.shape[1]
    bt, bf = min(block_t, T), min(block_f, F)
    nt, nf = pl.cdiv(T, bt), pl.cdiv(F, bf)
    tw = (jnp.ones((T, 1), jnp.float32) if token_weights is None
          else token_weights.reshape(T, 1).astype(jnp.float32))
    tw = jnp.broadcast_to(tw, (T, 128))  # lane-replicated for TPU layout
    cnt = jnp.clip(jnp.asarray(
        T if valid_count is None else valid_count, jnp.int32), 0, T)
    cnt = cnt.reshape(1)

    kernel = functools.partial(_kernel, act=act, n_fb=nf,
                               weighted=token_weights is not None,
                               block_t=bt)
    in_specs = [
        pl.BlockSpec((bt, D), lambda i, j, *_: (i, 0)),
        pl.BlockSpec((D, bf), lambda i, j, *_: (0, j)),
    ]
    args = [x, wi]
    if wg is not None:
        in_specs.append(pl.BlockSpec((D, bf), lambda i, j, *_: (0, j)))
        args.append(wg)
        kfn = kernel
    else:
        kfn = lambda cnt_ref, x_ref, wi_ref, wo_ref, tw_ref, o_ref, acc: \
            kernel(cnt_ref, x_ref, wi_ref, None, wo_ref, tw_ref, o_ref, acc)
    in_specs += [
        pl.BlockSpec((bf, D), lambda i, j, *_: (j, 0)),
        pl.BlockSpec((bt, 128), lambda i, j, *_: (i, 0)),
    ]
    args += [wo, tw]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, D), lambda i, j, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
    )
    return pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cnt, *args)
