"""Pallas TPU grouped expert matmul — the compute hot-spot of ElastiFormer's
*parameter subset selection* (expert routing over moefied dense MLPs and
native MoE layers).

Inputs are the capacity-dispatched per-expert token buffers produced by the
router (see models/moe.py):

    y[e, c] = w[e, c] * ( act(x[e,c] @ Wg[e]) * (x[e,c] @ Wi[e]) ) @ Wo[e]

Grid (E, C/bc, Fe/bf): expert-major so each expert's weight tiles are
streamed once per token-block column; the hidden activation is fused in VMEM
exactly like fused_mlp. Routing weights multiply the output (straight-through
gradient path of Alg. 1).

Ragged capacity-bucket execution: ``group_counts`` (an (E,) scalar-prefetched
vector of per-expert valid-slot counts) lets a single bucket-sized compile
skip every token tile past an expert's true occupancy (`pl.when` on tile
index vs count) — work proportional to dispatched tokens, not to capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def analysis_example():
    """Representative ``moe_gmm`` call for the static kernel verifier:
    batched dispatch buffers, per-(row, expert) ragged occupancy."""
    import numpy as np
    B, E, C, D, Fe = 2, 2, 128, 128, 256
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(B, E, C, D)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(E, D, Fe)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, D, Fe)), jnp.float32)
    wo = jnp.asarray(rng.normal(size=(E, Fe, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(B, E, C)), jnp.float32)
    cnt = jnp.asarray([[C, 40], [96, 0]], jnp.int32)
    return (moe_gmm, (x, wi, wo, wg, w),
            dict(group_counts=cnt, interpret=True))


def _kernel(cnt_ref, x_ref, wi_ref, wg_ref, wo_ref, w_ref, wis_ref, wgs_ref,
            wos_ref, o_ref, acc_sc, *, act: str, n_fb: int, block_c: int):
    ib = pl.program_id(0)
    ie = pl.program_id(1)
    ic = pl.program_id(2)
    jf = pl.program_id(3)
    cnt = cnt_ref[ib, ie]
    live = ic * block_c < cnt

    @pl.when(jnp.logical_not(live) & (jf == n_fb - 1))
    def _dead():  # capacity tile past this expert's occupancy: zeros only
        o_ref[0, 0] = jnp.zeros_like(o_ref[0, 0])

    @pl.when(live)
    def _run():
        @pl.when(jf == 0)
        def _init():
            acc_sc[...] = jnp.zeros_like(acc_sc)

        x = x_ref[0, 0].astype(jnp.float32)                    # (bc, D)
        wi = wi_ref[0].astype(jnp.float32)
        if wis_ref is not None:
            # int8 expert weights: widen in-register, per-(expert, output
            # channel) f32 scale — HBM only ever saw the int8 tile
            wi = wi * wis_ref[0, 0][None, :]
        hi = jax.lax.dot(x, wi, preferred_element_type=jnp.float32)
        if wg_ref is not None:
            wg = wg_ref[0].astype(jnp.float32)
            if wgs_ref is not None:
                wg = wg * wgs_ref[0, 0][None, :]
            hg = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
            a = jax.nn.silu(hg) if act == "swiglu" else jax.nn.gelu(hg)
            h = a * hi
        else:
            h = jax.nn.gelu(hi) if act == "gelu" else jax.nn.silu(hi)
        wo = wo_ref[0].astype(jnp.float32)
        if wos_ref is not None:
            wo = wo * wos_ref[0, 0][None, :]
        acc_sc[...] += jax.lax.dot(h, wo,
                                   preferred_element_type=jnp.float32)

        @pl.when(jf == n_fb - 1)
        def _finish():
            y = acc_sc[...] * w_ref[0, 0].astype(jnp.float32)[:, :1]
            rows = ic * block_c + jax.lax.broadcasted_iota(
                jnp.int32, y.shape, 0)
            y = jnp.where(rows < cnt, y, 0.0)
            o_ref[0, 0] = y.astype(o_ref.dtype)


def moe_gmm(x, wi, wo, wg=None, weights=None, *, act: str = "swiglu",
            block_c: int = 128, block_f: int = 512, group_counts=None,
            wi_scale=None, wo_scale=None, wg_scale=None,
            interpret: bool = False):
    """x: (E, C, D) or batched (B, E, C, D) dispatched tokens; wi/wg:
    (E, D, Fe); wo: (E, Fe, D) — expert weights are shared across the batch
    dim; weights: (E, C) / (B, E, C) routing weights (0 for empty capacity
    slots); group_counts: (E,) / (B, E) per-expert count of real leading
    slots (None = C) — slots >= the count produce zeros and their tiles are
    skipped. wi_scale/wg_scale: (E, Fe) and wo_scale: (E, D) f32
    per-(expert, output-channel) dequant scales when the weights are int8.
    Returns x-shaped output."""
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
        if weights is not None:
            weights = jnp.asarray(weights)[None]
        if group_counts is not None:
            group_counts = jnp.asarray(group_counts).reshape(1, -1)
    B, E, C, D = x.shape
    Fe = wi.shape[2]
    bc, bf = min(block_c, C), min(block_f, Fe)
    nc, nf = pl.cdiv(C, bc), pl.cdiv(Fe, bf)
    w = jnp.ones((B, E, C), jnp.float32) if weights is None else weights
    w = jnp.broadcast_to(w.astype(jnp.float32)[..., None], (B, E, C, 128))
    cnt = (jnp.full((B, E), C, jnp.int32) if group_counts is None
           else jnp.clip(jnp.asarray(group_counts, jnp.int32), 0, C))
    cnt = jnp.broadcast_to(cnt, (B, E))
    have_g = wg is not None
    qw = wi_scale is not None

    kernel = functools.partial(_kernel, act=act, n_fb=nf, block_c=bc)
    in_specs = [
        pl.BlockSpec((1, 1, bc, D), lambda b, e, i, j, *_: (b, e, i, 0)),
        pl.BlockSpec((1, D, bf), lambda b, e, i, j, *_: (e, 0, j)),
    ]
    args = [x, wi]
    if have_g:
        in_specs.append(
            pl.BlockSpec((1, D, bf), lambda b, e, i, j, *_: (e, 0, j)))
        args.append(wg)
    in_specs += [
        pl.BlockSpec((1, bf, D), lambda b, e, i, j, *_: (e, j, 0)),
        pl.BlockSpec((1, 1, bc, 128), lambda b, e, i, j, *_: (b, e, i, 0)),
    ]
    args += [wo, w]
    if qw:
        # per-(expert, output-channel) scale rows as (E,1,Fe)/(E,1,D) blocks
        fspec = pl.BlockSpec((1, 1, bf), lambda b, e, i, j, *_: (e, 0, j))
        dspec = pl.BlockSpec((1, 1, D), lambda b, e, i, j, *_: (e, 0, 0))
        in_specs.append(fspec)
        args.append(wi_scale.astype(jnp.float32).reshape(E, 1, Fe))
        if have_g:
            in_specs.append(fspec)
            args.append(wg_scale.astype(jnp.float32).reshape(E, 1, Fe))
        in_specs.append(dspec)
        args.append(wo_scale.astype(jnp.float32).reshape(E, 1, D))

    def kfn(cnt_ref, x_ref, *rest):
        rs = list(rest)
        wi_ref = rs.pop(0)
        wg_ref = rs.pop(0) if have_g else None
        wo_ref, w_ref = rs.pop(0), rs.pop(0)
        wis_ref = rs.pop(0) if qw else None
        wgs_ref = rs.pop(0) if (qw and have_g) else None
        wos_ref = rs.pop(0) if qw else None
        return kernel(cnt_ref, x_ref, wi_ref, wg_ref, wo_ref, w_ref,
                      wis_ref, wgs_ref, wos_ref, *rs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, E, nc, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bc, D),
                               lambda b, e, i, j, *_: (b, e, i, 0)),
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32)],
    )
    out = pl.pallas_call(
        kfn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, E, C, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(cnt, *args)
    return out[0] if squeeze else out
