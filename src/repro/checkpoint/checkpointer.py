"""Fault-tolerant checkpointing: async, atomic, content-verified, keep-N.

Layout:  <dir>/step_<n>/  shard_<host>.npz  + manifest.json
 - writes go to step_<n>.tmp then os.replace (atomic on POSIX) — a crash
   mid-save never corrupts the latest checkpoint;
 - manifest carries a per-array checksum so restore detects torn writes;
 - saves run on a background thread (training never blocks on disk);
 - `latest_step`/`restore` implement restart-from-failure, and restore
   accepts a target jax.sharding so a checkpoint written on one mesh can be
   loaded onto another (elastic re-scale path in runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): np.asarray(v) for k, v in flat}


def _unflatten_into(tree_like, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for k, v in flat:
        key = jax.tree_util.keystr(k)
        a = arrays[key]
        assert a.shape == v.shape, f"{key}: {a.shape} != {v.shape}"
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, host: int = 0):
        self.dir = directory
        self.keep = keep
        self.host = host
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------ save --------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False):
        """Snapshot `tree` (device arrays are fetched now, written async)."""
        arrays = _flatten(jax.device_get(tree))
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, arrays, extra or {}), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrays: dict, extra: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + f".tmp{self.host}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{self.host}.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra,
            "checksums": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                          for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ----------------------------- restore ------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(tuple(
                    f".tmp{i}" for i in range(1024))):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, tree_like: Any, shardings=None):
        """Load checkpoint `step` shaped like `tree_like`; verify checksums;
        optionally device_put onto `shardings` (tree of jax.sharding)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, f"shard_{self.host}.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        for k, v in arrays.items():
            crc = zlib.crc32(np.ascontiguousarray(v).tobytes())
            if crc != manifest["checksums"][k]:
                raise IOError(f"checkpoint corruption at {k} (crc mismatch)")
        tree = _unflatten_into(tree_like, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["extra"]
