"""Mixture-of-experts MLP: native (qwen2-moe, grok-1) and ElastiFormer's
moefied dense MLP share this machinery.

Dispatch is per-expert capacity gather (exact top-k semantics, FLOPs
proportional to selected experts only, no (B,S,E,C) one-hot): for each expert
take its top-C tokens by routing weight, gather, batched expert matmul,
weighted scatter-add. Sequence-chunked via lax.scan to bound the gather
buffer and keep the HLO small at 512-way SPMD.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.routing import RouteAux, bcast_to, is_full, topk_mask, \
    topk_mask_dyn
from repro.kernels import ops as OPS
from repro.models.layers import act_fn, dense_init, dtype_of, is_gated
from repro.models import flags, quant


def moe_init(key, cfg):
    """Native MoE params (router + stacked experts + optional shared)."""
    m = cfg.moe
    D, dt = cfg.d_model, dtype_of(cfg)
    ks = jax.random.split(key, 8)
    E, Fe = m.n_experts, m.d_expert
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "wi": dense_init(ks[1], D, E * Fe, dt).reshape(D, E, Fe).transpose(1, 0, 2),
        "wo": dense_init(ks[2], Fe, E * D, dt).reshape(Fe, E, D).transpose(1, 0, 2),
    }
    if is_gated(cfg.act):
        p["wg"] = dense_init(ks[3], D, E * Fe, dt).reshape(D, E, Fe).transpose(1, 0, 2)
    if m.n_shared_experts:
        Fs = m.d_shared
        p["shared"] = {"wi": dense_init(ks[4], D, Fs, dt),
                       "wo": dense_init(ks[5], Fs, D, dt)}
        if is_gated(cfg.act):
            p["shared"]["wg"] = dense_init(ks[6], D, Fs, dt)
    return p


def _expert_ffn(p, x_sel, act, backend=None, counts=None):
    """x_sel: (B,E,C,D), expert weights (E,D,Fe)/(E,Fe,D) -> (B,E,C,D).

    ``backend`` "pallas"/"interpret" routes through the grouped-matmul
    kernel (``kernels.ops.moe_gmm``); ``counts`` (B,E) per-expert occupancy
    then skips every capacity tile past an expert's dispatched tokens —
    the dispatch gather keeps the valid slots a per-(b,e) prefix, so the
    counts are exact, not a bound."""
    if backend in ("pallas", "interpret"):
        return OPS.moe_gmm(x_sel, p["wi"], p["wo"], p.get("wg"),
                           group_counts=counts,
                           wi_scale=p.get("wi_scale"),
                           wo_scale=p.get("wo_scale"),
                           wg_scale=p.get("wg_scale"),
                           act=act, backend=backend)
    h = jnp.einsum("becd,edf->becf", x_sel,
                   quant.maybe_dequant(p, "wi", x_sel.dtype))
    if "wg" in p:
        h = act_fn(act)(jnp.einsum("becd,edf->becf", x_sel,
                                   quant.maybe_dequant(p, "wg", x_sel.dtype))) * h
    else:
        h = act_fn(act)(h)
    return jnp.einsum("becf,efd->becd", h,
                      quant.maybe_dequant(p, "wo", x_sel.dtype)).astype(x_sel.dtype)


def moe_apply(
    p, x, *, act: str, top_k: int, router_w=None, normalize_to_m: bool = False,
    capacity_factor: float = 1.25, seq_chunk: int = 2048, top_k_traced=None,
    token_valid=None, dispatch_frac=None, token_count=None, backend=None,
):
    """x: (B,S,D) -> (B,S,D), aux. router_w overrides p['router'] (elastic).

    ``top_k_traced``: optional traced expert count ((), or (B,)). Dispatch
    buffers are then sized for ``top_k`` (the static maximum — pass E for
    the any-budget graph) and experts beyond the traced count are masked
    out, so one compilation serves every expert budget. A traced count
    >= E forces uniform weight 1 — the exact (lossless) dense module.

    ``token_valid`` (B,S) bars tokens from dispatch (token-routed callers:
    skipped tokens must not evict kept ones from expert capacity), and
    ``dispatch_frac`` (traced token capacity) shrinks the per-expert
    capacity to what the static *gather* path would have used for the same
    budget — together they make the one-graph masked composition match the
    gathered per-budget compile exactly in the single-chunk regime.

    ``token_count`` is the ragged capacity-bucket contract: x is a bucket
    buffer whose first N rows (per batch row, () or (B,)) are real tokens.
    It derives the dispatch shrink (``dispatch_frac = count / S``) so a
    bucket-sized compile dispatches exactly what the per-budget gather
    compile would have."""
    B, S, D = x.shape
    if token_count is not None and dispatch_frac is None:
        if isinstance(token_count, (int, float)):
            dispatch_frac = float(token_count) / S
        else:
            dispatch_frac = jnp.asarray(token_count, jnp.float32) / S
    rw = router_w if router_w is not None else p["router"]
    E = rw.shape[-1]
    k = min(top_k, E)
    chunk = min(seq_chunk, S)
    n_chunks = -(-S // chunk)
    # Elastic token routing hands us ragged S (e.g. ceil(0.8*4096)=3277):
    # pad to a chunk multiple; padded tokens are barred from dispatch.
    s_pad = n_chunks * chunk
    x_orig = x
    if s_pad != S:
        x = jnp.pad(x, [(0, 0), (0, s_pad - S), (0, 0)])
    valid = (jnp.arange(s_pad) < S)
    tv = None
    if token_valid is not None:
        tv = token_valid if s_pad == S else jnp.pad(
            token_valid, [(0, 0), (0, s_pad - S)])
    cap = int(math.ceil(k * chunk / E * capacity_factor))
    cap = min(chunk, max(4, -(-cap // 4) * 4))

    def one_chunk(xc, vc, tvc):
        s = xc.shape[1]
        logits = xc.astype(jnp.float32) @ rw                  # (B,s,E)
        probs = jax.nn.softmax(logits, axis=-1)
        w = probs * E if normalize_to_m else probs
        cap_eff = None
        kept = chunk if dispatch_frac is None else jnp.clip(
            jnp.ceil(dispatch_frac * chunk - 1e-9), 1, chunk)
        if top_k_traced is None:
            mask = topk_mask(w, k) & vc[None, :, None]
            k_for_cap = k
        else:
            kt = jnp.clip(top_k_traced, 1, E)
            full = bcast_to(is_full(top_k_traced, E), w.ndim)
            w = jnp.where(full, 1.0, w)
            mask = topk_mask_dyn(w, kt) & vc[None, :, None]
            k_for_cap = kt
        if tvc is not None:
            mask = mask & tvc[:, :, None]
        if top_k_traced is not None or dispatch_frac is not None:
            # per-expert capacity the static path would have compiled for
            # this budget (buffers stay sized for the static maximum `cap`)
            ce = jnp.ceil(k_for_cap * kept / E * capacity_factor)
            cap_eff = jnp.minimum(kept,
                                  jnp.maximum(4, jnp.ceil(ce / 4) * 4))
        # load-balance stats over REAL tokens only: chunk padding and the
        # ragged bucket's invalid tail must not dilute the denominator
        # (else budgets sharing a bucket train against a weaker signal
        # than the per-budget gather compile would have)
        stat_w = jnp.broadcast_to(vc[None, :, None].astype(jnp.float32),
                                  mask.shape[:2] + (1,))
        if tvc is not None:
            stat_w = stat_w * tvc[:, :, None].astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(stat_w), 1.0)
        red_frac = jnp.sum(mask * stat_w, axis=(0, 1)) / denom
        load = E * jnp.sum(
            red_frac * jnp.sum(probs * stat_w, axis=(0, 1)) / denom)
        sc = jnp.where(mask, w, -jnp.inf)                     # (B,s,E)
        vals, idx = jax.lax.top_k(sc.transpose(0, 2, 1), cap)  # (B,E,C)
        keep = jnp.isfinite(vals)
        if cap_eff is not None:
            keep &= jnp.arange(cap)[None, None, :] < bcast_to(cap_eff, 3)
        # dispatch: token gather into (B,E,C,D) buffers (UNweighted)
        x_sel = jnp.take_along_axis(xc[:, None], idx[..., None], axis=2)
        # per-(b,e) occupancy: top_k returns descending, so the kept slots
        # are a prefix — the exact group_counts the GMM kernel skips by
        y_buf = _expert_ffn(p, x_sel, act, backend=backend,
                            counts=jnp.sum(keep, axis=-1))    # (B,E,C,D)
        # combine by GATHER, not scatter (§Perf H3): XLA upcasts bf16
        # scatter-add to f32 and surrounds it with full-buffer copies
        # (~25 GB/layer of traffic). Instead invert the dispatch index
        # with a tiny int32 scatter, then each token reads back its k
        # expert outputs — bf16 loads proportional to top-k only.
        b3 = jnp.arange(B)[:, None, None]
        e3 = jnp.arange(E)[None, :, None]
        slot_of = jnp.full((B, E, s), -1, jnp.int32)
        slot_of = slot_of.at[b3, e3, idx].set(
            jnp.where(keep, jnp.broadcast_to(jnp.arange(cap), (B, E, cap)),
                      -1))
        wtok, eids = jax.lax.top_k(sc, k)                     # (B,s,k)
        slots = jnp.take_along_axis(slot_of.transpose(0, 2, 1), eids, -1)
        ok = jnp.isfinite(wtok) & (slots >= 0)
        lin = eids * cap + jnp.maximum(slots, 0)              # (B,s,k)
        y_tok = jnp.take_along_axis(
            y_buf.reshape(B, E * cap, D),
            lin.reshape(B, s * k)[..., None], axis=1).reshape(B, s, k, D)
        wt = jnp.where(ok, wtok, 0.0)
        out = jnp.sum(y_tok * wt[..., None].astype(xc.dtype), axis=2)
        return out.astype(xc.dtype), load

    xs = x.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    vs = valid.reshape(n_chunks, chunk)
    if tv is not None:
        tvs = tv.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
        ys, loads = jax.lax.scan(
            lambda c, xv: (c, one_chunk(*xv)), None, (xs, vs, tvs),
            unroll=flags.unroll())[1]
    else:
        ys, loads = jax.lax.scan(
            lambda c, xv: (c, one_chunk(xv[0], xv[1], None)), None, (xs, vs),
            unroll=flags.unroll())[1]
    y = ys.transpose(1, 0, 2, 3).reshape(B, s_pad, D)[:, :S]
    if "shared" in p:
        y = y + _dense_ffn(p["shared"], x_orig, act)
    aux = RouteAux.of(load=jnp.mean(loads))
    return y, aux


def _dense_ffn(p, x, act):
    h = x @ quant.maybe_dequant(p, "wi", x.dtype)
    if "wg" in p:
        h = act_fn(act)(x @ quant.maybe_dequant(p, "wg", x.dtype)) * h
    else:
        h = act_fn(act)(h)
    return (h @ quant.maybe_dequant(p, "wo", x.dtype)).astype(x.dtype)


def moe_decode(p, x, *, act: str, top_k: int, router_w=None,
               normalize_to_m: bool = False, top_k_traced=None):
    """Decode path (S==1): gather only the selected experts' weights so HBM
    traffic ∝ top-k experts (memory-roofline critical at 314B scale).

    With ``top_k_traced`` the gather covers the static ``top_k`` maximum and
    experts ranked beyond the traced count get weight 0 (>= E: all weight 1,
    the exact dense module) — variable expert budgets on one graph."""
    B, S, D = x.shape
    rw = router_w if router_w is not None else p["router"]
    E = rw.shape[-1]
    k = min(top_k, E)
    logits = x.astype(jnp.float32) @ rw                       # (B,1,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * E if normalize_to_m else probs
    vals, idx = jax.lax.top_k(w[:, 0], k)                     # (B,k)
    if top_k_traced is not None:
        kt = jnp.clip(top_k_traced, 1, E)
        sel = jnp.arange(k)[None, :] < bcast_to(kt, 2)        # (B,k)
        full = bcast_to(is_full(top_k_traced, E), 2)
        vals = jnp.where(full, 1.0, jnp.where(sel, vals, 0.0))
    def take_w(name):
        # gather selected experts' weights, then dequant the gathered
        # slice only — HBM traffic stays ∝ top-k int8 expert rows
        w_sel = jnp.take(p[name], idx, axis=0)                # (B,k,D,Fe)
        sc = p.get(name + "_scale")
        if sc is None:
            return w_sel
        return (w_sel.astype(jnp.float32)
                * jnp.take(sc, idx, axis=0)[:, :, None, :]).astype(x.dtype)
    wi_sel, wo_sel = take_w("wi"), take_w("wo")
    h = jnp.einsum("bsd,bkdf->bkf", x, wi_sel)
    if "wg" in p:
        h = act_fn(act)(jnp.einsum("bsd,bkdf->bkf", x, take_w("wg"))) * h
    else:
        h = act_fn(act)(h)
    y = jnp.einsum("bkf,bkfd,bk->bd", h, wo_sel, vals.astype(h.dtype))
    y = y[:, None].astype(x.dtype)
    if "shared" in p:
        y = y + _dense_ffn(p["shared"], x, act)
    return y, RouteAux.zero()
