"""Transformer blocks with ElastiFormer routing woven in.

Block kinds (cfg.mixer_pattern):
  attn  : [token-route] GQA self-attention [head-route] [LoRA]  + MLP block
  xattn : same + cross-attention to encoder/image context       + MLP block
  ssm   : [token-route] Mamba2 SSD mixer (no MLP)
  rglru : [token-route] RG-LRU recurrent mixer                  + MLP block

Elasticity is split into a static ``ElasticSpec`` (which routers exist —
shapes params and HLO) and a runtime ``ElasticPolicy`` (capacities, head/
expert top-k, decode threshold theta, teacher/student flag) — see
core/policy.py. Policy leaves that are python numbers are trace-time
constants (ragged capacity-bucket or legacy gather routing, real FLOP
savings); traced leaves serve every budget — including per-request (B,)
budgets — from ONE compiled block per ragged bucket (with a static
``bucket`` hint; see core/routing), or from a single full-shape rank-masked
graph without one.

Modes:
  base  : frozen pretrained model (the distillation teacher) — routers off.
  train : student; input-subset selection = top-k (capacity c), Alg. 2.
  infer : student; input-subset selection = threshold theta (§B.1).

Token routing semantics per mixer family:
  attention : top-k tokens attend among themselves (MoD semantics) — the
              ragged/gather paths deliver real FLOP savings in the lowered
              HLO; the masked path computes the same math at full shapes.
  ssm/rglru : skipped tokens leave the recurrent state untouched (dt=0 /
              a=1 exact pass-through); dense-masked in both train and infer
              so train/infer semantics coincide.

Routed execution (this PR's hot path): train-mode top-k selection is
planned ONCE per block (core/routing.RoutingPlan — one sort, shared by the
attention and MLP/MoE students; each weights the shared token set with its
own router), full-budget policies compile the identity graph (no routing
work, bit-exact teacher), and ``spec.kernel_backend`` dispatches the block
math through the Pallas kernels (flash attention with scalar-prefetched
kv_count, fused/routed MLP, grouped expert matmul, ring-cache decode
attention) or their jnp twins.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import routing as R
from repro.kernels import ops as OPS
from repro.runtime import sharding as SH
from repro.core.moefy import moefy_mlp
from repro.core.lora import lora_init
from repro.models import attention as A
from repro.models import quant
from repro.models import rglru as G
from repro.models import ssm as S
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.models.moe import moe_apply, moe_decode, moe_init


# VMEM budget for fused_mlp_routed's resident per-row output slab (it
# holds one (S, D) block for a whole batch row): ~4 MiB leaves room for
# the weight/f-tiles on a 16 MiB-VMEM core. Beyond it the plan path falls
# back to gather-in-XLA + the batched fused_mlp kernel.
ROUTED_MLP_SLAB_BYTES = 4 * 1024 * 1024


def has_mlp(kind: str) -> bool:
    return kind != "ssm"


def is_attn(kind: str) -> bool:
    return kind in ("attn", "xattn")


# ------------------------------ init ---------------------------------------

def block_init(key, kind: str, cfg):
    ks = jax.random.split(key, 6)
    p = {"norm1": norm_init(cfg.d_model, cfg.norm)}
    if is_attn(kind):
        p["attn"] = A.attn_init(ks[0], cfg)
    elif kind == "ssm":
        p["mixer"] = S.ssm_init(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = G.rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == "xattn":
        p["xnorm"] = norm_init(cfg.d_model, cfg.norm)
        p["xattn"] = A.attn_init(ks[1], cfg, cross=True)
    if has_mlp(kind):
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["mlp"] = moe_init(ks[2], cfg) if cfg.moe is not None else mlp_init(ks[2], cfg)
    return p


def block_router_init(key, kind: str, cfg, spec):
    """Trainable ElastiFormer params for one layer (tiny; see Table 1).
    ``spec`` is the static ElasticSpec: it alone decides which routers exist."""
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    rp = {}
    if spec.depth_routed:
        # per-token whole-layer skip: same scalar-logit router as the
        # token routers, gating the ENTIRE block (mixer + MLP + KV write).
        # fold_in (not a wider split): the 6-way split above must stay
        # byte-identical for specs without depth, or enabling the feature
        # flag would shift EVERY router's init
        rp["depth"] = R.token_router_init(jax.random.fold_in(key, 6), D)
    if spec.mha_token_routed:
        rp["tok_mixer"] = R.token_router_init(ks[0], D)
    if is_attn(kind):
        if spec.mha_head_routed:
            rp["head"] = R.param_router_init(ks[1], D, cfg.n_heads)
        if spec.lora_rank:
            rp["lora"] = {
                "q": lora_init(ks[2], D, cfg.n_heads * cfg.d_head, spec.lora_rank),
                "v": lora_init(ks[3], D, cfg.n_kv_heads * cfg.d_head, spec.lora_rank),
            }
    if has_mlp(kind):
        if spec.mlp_token_routed:
            rp["tok_mlp"] = R.token_router_init(ks[4], D)
        n_exp = cfg.moe.n_experts if cfg.moe is not None else spec.mlp_n_experts
        if n_exp and spec.expert_routed:
            rp["expert"] = R.param_router_init(ks[5], D, n_exp)
    return rp


# ------------------------- helpers ------------------------------------------

def _expert_args(pol, n_experts: int) -> dict:
    """moe_apply/moe_decode kwargs for the elastic expert budget: a static
    int keeps the small-k graph; a traced count sizes buffers for all E and
    masks (one graph, any budget)."""
    k = R.gate_topk(pol.mlp_expert_topk, pol.student, n_experts)
    if R.is_static(k):
        return {"top_k": min(int(k), n_experts)}
    return {"top_k": n_experts, "top_k_traced": k}


def _lora_gate(lora, cap, student):
    """Disable the LoRA rescue adapters exactly when there is nothing to
    rescue: mha token budget full, or the policy is in teacher mode — this
    keeps budget-1.0 rows bit-lossless even with trained adapters.
    ``cap`` is the (already student-gated) mha token capacity or None."""
    if lora is None:
        return None
    if cap is not None:
        full = R.is_full(cap)
    elif student is None or R.is_static(student):
        full = student is not None and student <= 0
    else:
        full = jnp.asarray(student) <= 0
    if R.is_static(full):
        return None if full else lora
    return {**lora, "scale": 1.0 - jnp.asarray(full, jnp.float32)}


def _head_weights(rp, h, spec, pol, cfg, auxes, valid=None):
    if rp is None or spec is None or "head" not in rp \
            or not spec.mha_head_routed:
        return None
    k = R.gate_topk(pol.mha_head_topk, pol.student, cfg.n_heads)
    w, m, a = R.param_route_weights(rp["head"], h, k, valid=valid)
    auxes.append(a)
    hw = w * m
    full = R.is_full(k, cfg.n_heads)
    if R.is_static(full):
        return jnp.ones_like(hw) if full else hw
    return jnp.where(R.bcast_to(full, hw.ndim), 1.0, hw)


def _mlp_fn(p, rp, cfg, spec, pol, elastic_on, mode, auxes, backend=None):
    """Returns f(h_sub, pos_sub[, token_valid, dispatch_frac, token_count])
    for the MLP/MoE sub-block. The masked (traced-capacity) token-routing
    path hands in ``token_valid``/``dispatch_frac`` so skipped tokens cannot
    evict kept ones from expert capacity; the ragged bucket path hands in
    ``token_valid``/``token_count`` (prefix buffers) — either way the
    dispatch buffers match what the static gather path would have compiled
    for the same budget. ``backend`` "pallas"/"interpret" executes the
    dense MLP through ``kernels.ops.fused_mlp`` (``token_count`` becomes
    the kernel's scalar-prefetched ``valid_count``) and expert dispatch
    through ``kernels.ops.moe_gmm``."""
    def f(h, _pos, token_valid=None, dispatch_frac=None, token_count=None):
        if cfg.moe is not None:
            if elastic_on and rp and "expert" in rp and mode != "base":
                y, a = moe_apply(
                    p["mlp"], h, act=cfg.act,
                    router_w=rp["expert"]["w"], normalize_to_m=True,
                    capacity_factor=cfg.moe.capacity_factor,
                    seq_chunk=cfg.moe.seq_chunk, token_valid=token_valid,
                    dispatch_frac=dispatch_frac, token_count=token_count,
                    backend=backend,
                    **_expert_args(pol, cfg.moe.n_experts))
            else:
                y, a = moe_apply(
                    p["mlp"], h, act=cfg.act, top_k=cfg.moe.top_k,
                    capacity_factor=cfg.moe.capacity_factor,
                    seq_chunk=cfg.moe.seq_chunk, token_valid=token_valid,
                    dispatch_frac=dispatch_frac, token_count=token_count,
                    backend=backend)
            auxes.append(a)
            return y
        if (elastic_on and rp and "expert" in rp and mode != "base"
                and spec.mlp_n_experts):
            ep = moefy_mlp(p["mlp"], spec.mlp_n_experts)
            # seq_chunk bounds the (B,E,C,D) dispatch buffers: 512 keeps
            # the f32 scatter-upcast live set ~1.3 GB/dev (vs 8.5 GB at a
            # full-sequence chunk) — §Perf H4 (HBM fit).
            y, a = moe_apply(
                ep, h, act=cfg.act,
                router_w=rp["expert"]["w"], normalize_to_m=True,
                seq_chunk=512, token_valid=token_valid,
                dispatch_frac=dispatch_frac, token_count=token_count,
                backend=backend,
                **_expert_args(pol, spec.mlp_n_experts))
            auxes.append(a)
            return y
        if backend in ("pallas", "interpret"):
            mp = p["mlp"]
            return OPS.fused_mlp(h, mp["wi"], mp["wo"], mp.get("wg"),
                                 valid_count=token_count,
                                 wi_scale=mp.get("wi_scale"),
                                 wo_scale=mp.get("wo_scale"),
                                 wg_scale=mp.get("wg_scale"), act=cfg.act,
                                 backend=backend)
        return mlp_apply(p["mlp"], h, cfg.act)
    return f


def _is_dense_mlp(p, rp, cfg, spec, elastic_on, mode) -> bool:
    """True when the MLP sub-block is the plain dense MLP (no native MoE,
    no moefied expert routing) — the case the gather/scatter-fused routed
    kernel (``fused_mlp_routed``) can serve directly."""
    if cfg.moe is not None:
        return False
    return not (elastic_on and rp and "expert" in rp and mode != "base"
                and spec is not None and spec.mlp_n_experts)


# --------------------- full-sequence block apply ----------------------------

def _combine_caps(cap_a, cap_b):
    """Block-level plan capacity: the elementwise max of the active
    components' (already student-gated) token capacities. The budget
    solver and every policy constructor set them equal; when a caller
    hands diverging per-component capacities the shared plan covers the
    larger one (and the smaller component rides the same token set)."""
    if cap_a is None:
        return cap_b
    if cap_b is None:
        return cap_a
    if R.is_static(cap_a) and R.is_static(cap_b):
        return max(cap_a, cap_b)
    return jnp.maximum(jnp.asarray(cap_a, jnp.float32),
                       jnp.asarray(cap_b, jnp.float32))


def _mul_caps(cap_a, cap_b):
    """Multiplicative capacity composition (the depth axis): the depth
    router skips the WHOLE layer for unselected tokens, so a component's
    effective token fraction is its own capacity x the depth capacity —
    depth 0.75 x token 0.75 runs ~0.56 of the component's tokens, the
    same product the roofline solver's ``_active_fraction`` models. Each
    factor is clamped at 1 first (capacity >= 1 means "full", not "more")."""
    if cap_a is None:
        return cap_b
    if cap_b is None:
        return cap_a
    if R.is_static(cap_a) and R.is_static(cap_b):
        return min(1.0, cap_a) * min(1.0, cap_b)
    return (jnp.minimum(jnp.asarray(cap_a, jnp.float32), 1.0)
            * jnp.minimum(jnp.asarray(cap_b, jnp.float32), 1.0))


def block_apply(
    kind: str, p, rp, x, *, cfg, spec, pol=None, mode: str, elastic_on: bool,
    window: int = 0, positions=None, causal: bool = True,
    enc_kv=None, enc_valid=None, collect_cache: bool = False,
    max_cache_len: int = 0, bucket=None, spmd_auto: bool = True,
):
    """x: (B,S,D) -> (x', aux[, cache]). Pre-norm residual block.

    Train-mode token routing is planned ONCE per block: a single
    ``RoutingPlan`` (one sort — see core/routing) built from the block's
    primary token router (the mixer router when attention is token-routed,
    else the MLP router) is shared by the attention and MLP/MoE students —
    each component weights the shared token set with its OWN router's
    scores (straight-through gradients to both routers) and BCE-trains its
    router against the shared membership. Per-component capacities are
    unified at the block level (``_combine_caps``); the budget solver
    always sets them equal.

    ``bucket``: static plan-buffer hint for traced-capacity routing under
    ``spec.routing_impl == "ragged"`` (see core/policy.ragged_bucket). It
    must cover the largest per-row top-k this graph will see;
    ``routing.IDENTITY_BUCKET`` asserts every row is at full budget and
    compiles the IDENTITY fast path (no partition/gather/scatter — the
    bit-exact teacher math, with router aux losses still emitted); None
    falls back to the dense rank-masked path. ``spec.kernel_backend``
    selects how the hot math executes (Pallas kernels vs jnp twins — see
    kernels/ops.py).

    ``spmd_auto``: True when this trace runs in a GSPMD-auto region (no
    enclosing manual shard_map), where mesh-wide sharding constraints and
    nested shard_map kernel wrappers are legal — the serving prefill path.
    ``_run_stack`` sets it False inside its manual-over-batch-axes wrap."""
    B, Seq, D = x.shape
    auxes = [R.RouteAux.zero()]
    if positions is None:
        positions = jnp.arange(Seq, dtype=jnp.int32)
    routed = elastic_on and mode != "base"
    backend = OPS.resolve_backend(
        spec.kernel_backend if spec is not None else None)
    cache = {}

    # ---- block-level routing plan resolution ----
    cap_mha = cap_mlp = cap_depth = None
    if routed and spec is not None and rp:
        if spec.depth_routed and "depth" in rp:
            cap_depth = R.gate_capacity(pol.depth_capacity, pol.student)
        if spec.mha_token_routed and "tok_mixer" in rp:
            cap_mha = R.gate_capacity(pol.mha_token_capacity, pol.student)
        if has_mlp(kind) and spec.mlp_token_routed and "tok_mlp" in rp:
            cap_mlp = R.gate_capacity(pol.mlp_token_capacity, pol.student)
    # depth composes multiplicatively (it skips the whole layer), so the
    # block plan's capacity is depth x the max of the per-component caps
    cap_plan = _mul_caps(_combine_caps(cap_mha, cap_mlp), cap_depth)
    impl = spec.routing_impl if spec is not None else "gather"
    kb = None
    if mode == "train" and cap_plan is not None and (
            impl == "ragged" or (impl == "gather" and R.is_static(cap_plan)
                                 and R.is_static(pol.theta))):
        kb = R.resolve_bucket(cap_plan, Seq, bucket, impl=impl)
    identity = kb == Seq            # full budget everywhere: skip routing
    k_plan = None if (kb is None or identity) else \
        R.capacity_k(cap_plan, Seq, mxu=True)
    plan = None                     # built lazily by the first consumer
    # mixer-stage routers, OUTERMOST first: the depth router (whole-layer
    # skip) is the block's primary plan router when present, then the
    # mixer token router. The first entry builds the plan; the rest weight
    # the shared token set and BCE-train toward its membership.
    mixer_routers = []
    if cap_depth is not None:
        mixer_routers.append(("depth", cap_depth))
    if cap_mha is not None:
        mixer_routers.append(("tok_mixer", cap_mha))
    plan_on_mixer = bool(mixer_routers)
    depth_scores = None       # depth sigmoid over the full sequence
    depth_w_sel = None        # depth weight on the plan's selected set
    depth_gate = None         # infer-mode depth threshold gate (keep, w)

    def build_plan(h_src):
        """The block's ONE RoutingPlan sort, from the primary router.
        Under a mesh the plan arrays stay replicated over `model` (batch
        over data), so one plan drives every TP shard of the block."""
        name = mixer_routers[0][0] if mixer_routers else "tok_mlp"
        logits = R.token_logits(rp[name], h_src)
        scores = jax.nn.sigmoid(logits)
        plan = R.make_plan(scores, k_plan, kb)
        if spmd_auto and SH.active_mesh() is not None:
            plan = R.constrain_plan(plan)
        return plan, logits, scores

    def bce_aux(logits, keep, train):
        if train:
            auxes.append(R.RouteAux.of(topk=R.bce_topk_loss(logits, keep),
                                       keep=keep))
        else:
            auxes.append(R.RouteAux.of(keep=keep))

    def plan_weights(plan, logits, scores, h_src):
        """Mixer-stage weight on the plan's selected set: the primary
        router's scores times every secondary mixer router's, each
        BCE-trained toward the shared membership (straight-through)."""
        nonlocal depth_scores, depth_w_sel
        w_sel = jnp.take_along_axis(scores, plan.idx, 1)
        bce_aux(logits, plan.keep, train=True)
        if mixer_routers and mixer_routers[0][0] == "depth":
            depth_scores = scores
            depth_w_sel = w_sel * plan.valid
        for name, _c in mixer_routers[1:]:
            lg = R.token_logits(rp[name], h_src)
            w_sel = w_sel * jnp.take_along_axis(jax.nn.sigmoid(lg),
                                                plan.idx, 1)
            bce_aux(lg, plan.keep, train=True)
        return w_sel * plan.valid

    def mixer_gate(h_src):
        """Dense/threshold gate over every mixer-stage router. Train: the
        PRIMARY router rank-masks at the shared plan capacity (secondary
        routers contribute weight only — the plan path's semantics).
        Infer: each router thresholds at theta independently; keeps AND
        and weights multiply (matching the decode gate)."""
        nonlocal depth_scores, depth_gate
        name0, _c0 = mixer_routers[0]
        logits = R.token_logits(rp[name0], h_src)
        scores = jax.nn.sigmoid(logits)
        if name0 == "depth":
            depth_scores = scores
        if mode == "train":
            keep, wtok = R.token_gate(logits, scores, cap_plan, mode,
                                      theta=pol.theta, mxu=True)
            bce_aux(logits, keep, train=True)
            full = R.is_full(cap_plan)
            for name, _c in mixer_routers[1:]:
                lg = R.token_logits(rp[name], h_src)
                sc = jax.nn.sigmoid(lg)
                if R.is_static(full):
                    wtok = wtok if full else wtok * sc
                else:
                    wtok = wtok * jnp.where(R.bcast_to(full, keep.ndim),
                                            1.0, sc)
                bce_aux(lg, keep, train=True)
            return keep, wtok
        keep, wtok = None, None
        for name, c in mixer_routers:
            lg = logits if name == name0 else R.token_logits(rp[name], h_src)
            sc = scores if name == name0 else jax.nn.sigmoid(lg)
            kp, w = R.token_gate(lg, sc, c, mode, theta=pol.theta, mxu=True)
            bce_aux(lg, kp, train=False)
            if name == "depth":
                depth_gate = (kp, w)
            keep = kp if keep is None else keep & kp
            wtok = w if wtok is None else wtok * w
        return keep, wtok

    # ---- temporal mixer ----
    h = norm_apply(p["norm1"], x, cfg.norm)
    dense_keep = None               # shared keep of the dense fallback

    if is_attn(kind):
        lora = rp.get("lora") if (routed and rp) else None
        lora = _lora_gate(lora, _mul_caps(cap_mha, cap_depth),
                          pol.student if (routed and pol is not None) else None)
        if not mixer_routers:
            hw = _head_weights(rp if routed else None, h, spec, pol, cfg,
                               auxes) if routed else None
            y, k, v = A.attn_apply(p["attn"], h, cfg=cfg, positions=positions,
                                   causal=causal, window=window,
                                   head_weights=hw, lora=lora,
                                   backend=backend)
            delta, keep = y, jnp.ones((B, Seq), bool)
        elif identity:
            # full budget on every row: bit-exact teacher attention, no
            # partition/sort/masking — every mixer-stage router (depth
            # included) still trains (BCE toward keep-everything, exactly
            # what the dense path emits at 1.0)
            keep = jnp.ones((B, Seq), bool)
            for name, _c in mixer_routers:
                bce_aux(R.token_logits(rp[name], h), keep, train=True)
            hw = _head_weights(rp, h, spec, pol, cfg, auxes)
            y, k, v = A.attn_apply(p["attn"], h, cfg=cfg, positions=positions,
                                   causal=causal, window=window,
                                   head_weights=hw, lora=lora,
                                   backend=backend)
            delta = y
        elif kb is not None:
            # shared plan (ragged capacity bucket, or exact static gather):
            # selected tokens gathered valid-first (position-ascending
            # prefix), tail filled + masked. Static caps derive the bucket
            # here (budgets sharing a bucket share the compile); traced
            # caps ride the caller's static bucket hint. With depth routed
            # the plan is the depth router's (outermost) selection —
            # unselected tokens ride the residual through the WHOLE block.
            plan, logits, scores = build_plan(h)
            h_sel = R.plan_gather(h, plan)
            pos_sel = jnp.take_along_axis(
                jnp.broadcast_to(positions, (B, Seq)), plan.idx, 1)
            hw = _head_weights(rp, h_sel, spec, pol, cfg, auxes,
                               valid=plan.valid)
            y_sel, k, v = A.attn_apply(p["attn"], h_sel, cfg=cfg,
                                       positions=pos_sel, causal=causal,
                                       window=window, kv_valid=plan.valid,
                                       kv_count=plan.count, head_weights=hw,
                                       lora=lora, backend=backend,
                                       gathered=True)
            w_sel = plan_weights(plan, logits, scores, h)
            delta = R.plan_scatter(
                plan, x, y_sel * w_sel[..., None].astype(y_sel.dtype))
            keep = plan.keep
            if collect_cache:  # scatter valid k/v back to full positions
                k = _scatter_kv(k, plan.idx, B, Seq)
                v = _scatter_kv(v, plan.idx, B, Seq)
        else:  # threshold (infer/prefill), dense_mask, or traced capacity
            keep, wtok = mixer_gate(h)
            if mode == "train":
                dense_keep = keep
            # head-router stats over the SELECTED tokens only, matching
            # the plan path (whose buffer holds exactly the selected set)
            hw = _head_weights(rp, h, spec, pol, cfg, auxes,
                               valid=keep if mode == "train" else None)
            y, k, v = A.attn_apply(p["attn"], h, cfg=cfg, positions=positions,
                                   causal=causal, window=window,
                                   kv_valid=keep, head_weights=hw, lora=lora,
                                   backend=backend)
            delta = y * wtok[..., None].astype(y.dtype)
        if collect_cache:
            L = max_cache_len or Seq
            cache["attn"] = _pad_cache(
                k, v, keep, L, window,
                kv_dtype=spec.kv_dtype if spec is not None else "fp32")
    else:  # ssm / rglru — dense masked routing (state pass-through semantics)
        keep = None
        if mixer_routers:
            if identity:
                keep, wtok = None, None
                ones = jnp.ones((B, Seq), bool)
                for name, _c in mixer_routers:
                    bce_aux(R.token_logits(rp[name], h), ones, train=True)
            elif kb is not None:
                # recurrent mixers cannot gather (state pass-through): they
                # consume the shared plan's MEMBERSHIP as a dense mask
                plan, logits, scores = build_plan(h)
                keep = plan.keep
                if mixer_routers[0][0] == "depth":
                    depth_scores = scores
                    depth_w_sel = jnp.take_along_axis(
                        scores, plan.idx, 1) * plan.valid
                wtok = keep * scores
                bce_aux(logits, keep, train=True)
                for name, _c in mixer_routers[1:]:
                    lg = R.token_logits(rp[name], h)
                    wtok = wtok * jax.nn.sigmoid(lg)
                    bce_aux(lg, keep, train=True)
            else:
                keep, wtok = mixer_gate(h)
                if mode == "train":
                    dense_keep = keep
        if kind == "ssm":
            y, (st, cv) = S.ssm_apply(p["mixer"], h, cfg, keep_mask=keep)
            if collect_cache:
                cache["ssm"] = {"state": st, "conv": cv}
        else:
            y, (st, cv) = G.rglru_apply(p["mixer"], h, cfg, keep_mask=keep)
            if collect_cache:
                cache["rglru"] = {"state": st, "conv": cv}
        if keep is None:
            delta = y
        else:
            delta = y * wtok[..., None].astype(y.dtype)
    x = x + delta

    # ---- cross attention (xattn) ----
    if kind == "xattn":
        hx = norm_apply(p["xnorm"], x, cfg.norm)
        lora = None
        y, xk, xv = A.attn_apply(
            p["xattn"], hx, cfg=cfg, positions=positions, causal=False,
            kv_x=enc_kv, kv_positions=jnp.arange(enc_kv.shape[1]),
            kv_valid=enc_valid, use_rope=False, backend=backend)
        x = x + y
        if collect_cache:
            ev = (jnp.ones(enc_kv.shape[:2], bool) if enc_valid is None
                  else jnp.broadcast_to(enc_valid, enc_kv.shape[:2]))
            cache["xattn"] = {"k": xk, "v": xv, "valid": ev}

    # ---- MLP ----
    if has_mlp(kind):
        h = norm_apply(p["norm2"], x, cfg.norm)
        f = _mlp_fn(p, rp, cfg, spec, pol, elastic_on, mode, auxes,
                    backend=backend)
        if cap_mlp is None and cap_depth is None:
            delta = f(h, positions)
        elif identity:
            if cap_mlp is not None:
                bce_aux(R.token_logits(rp["tok_mlp"], h),
                        jnp.ones((B, Seq), bool), train=True)
            delta = f(h, positions)
        elif kb is not None:
            # reuse the block plan (built by the mixer when it is routed;
            # otherwise this IS the block's one sort, on the MLP router).
            # The depth weight (outermost selection) multiplies the MLP's
            # own router weight — the whole-block delta is depth-gated.
            if plan is None:
                plan, logits, scores = build_plan(h)
                w_sel = jnp.take_along_axis(scores, plan.idx, 1) * plan.valid
                bce_aux(logits, plan.keep, train=True)
            else:
                if cap_mlp is not None:
                    logits = R.token_logits(rp["tok_mlp"], h)
                    scores = jax.nn.sigmoid(logits)
                    w_sel = jnp.take_along_axis(
                        scores, plan.idx, 1) * plan.valid
                    bce_aux(logits, plan.keep, train=True)
                else:
                    w_sel = plan.valid.astype(jnp.float32)
                if depth_w_sel is not None:
                    w_sel = w_sel * depth_w_sel
            # the gather/scatter-fused kernel keeps one (S, D) output slab
            # resident in VMEM — only profitable (and compilable) while
            # that slab fits; bigger shapes gather in XLA and run the
            # batched fused_mlp kernel on the bucket buffer instead
            slab = Seq * D * jnp.dtype(x.dtype).itemsize
            if (backend in ("pallas", "interpret")
                    and _is_dense_mlp(p, rp, cfg, spec, elastic_on, mode)
                    and slab <= ROUTED_MLP_SLAB_BYTES):
                # plan indices ride scalar prefetch; the bucket buffer
                # never hits HBM. Under a mesh (GSPMD-auto region) the
                # kernel runs per-shard over the FFN dim via shard_map —
                # ops.fused_mlp_routed_sharded falls through to the plain
                # call off-mesh or when shapes don't divide.
                routed_op = (OPS.fused_mlp_routed_sharded if spmd_auto
                             else OPS.fused_mlp_routed)
                delta = routed_op(
                    h, plan.idx, p["mlp"]["wi"], p["mlp"]["wo"],
                    p["mlp"].get("wg"), w_sel, valid_count=plan.count,
                    wi_scale=p["mlp"].get("wi_scale"),
                    wo_scale=p["mlp"].get("wo_scale"),
                    wg_scale=p["mlp"].get("wg_scale"),
                    act=cfg.act, backend=backend).astype(x.dtype)
            else:
                h_sel = R.plan_gather(h, plan)
                pos_sel = jnp.take_along_axis(
                    jnp.broadcast_to(positions, (B, Seq)), plan.idx, 1)
                y_sel = f(h_sel, pos_sel, token_valid=plan.valid,
                          token_count=plan.count)
                delta = R.plan_scatter(
                    plan, x, y_sel * w_sel[..., None].astype(y_sel.dtype))
        elif mode == "train":
            # dense fallback (traced capacity without a covering bucket, or
            # dense_mask impl): selection shared with the mixer stage when
            # it ran; expert dispatch is barred from skipped tokens so the
            # one-graph result matches the per-budget plan compile
            logits = scores = None
            if cap_mlp is not None:
                logits = R.token_logits(rp["tok_mlp"], h)
                scores = jax.nn.sigmoid(logits)
            if dense_keep is not None:
                keep = dense_keep
                w = keep.astype(jnp.float32)
                if scores is not None:
                    w = w * scores
                if depth_scores is not None:
                    w = w * depth_scores
                full = R.is_full(cap_plan)
                if R.is_static(full):
                    wtok = jnp.ones_like(w) if full else w
                else:
                    wtok = jnp.where(R.bcast_to(full, keep.ndim), 1.0, w)
            else:
                keep, wtok = R.token_gate(logits, scores, cap_plan, mode,
                                          theta=pol.theta, mxu=True)
            y = f(h, positions, token_valid=keep, dispatch_frac=cap_plan)
            delta = y * wtok[..., None].astype(y.dtype)
            if logits is not None:
                bce_aux(logits, keep, train=True)
        else:
            # inference thresholding (§B.1): per-token, per-router gate;
            # the depth router's threshold gate (already emitted in the
            # mixer stage) multiplies the whole delta
            if cap_mlp is None:
                delta = f(h, positions)
            else:
                delta, a = R.route_tokens(
                    rp["tok_mlp"], h, f, cap_mlp, mode, positions=positions,
                    impl=impl, theta=pol.theta if pol is not None else 0.5,
                    bucket=bucket)
                auxes.append(a)
            if depth_gate is not None:
                _dk, dw = depth_gate
                delta = delta * dw[..., None].astype(delta.dtype)
        x = x + delta

    aux = auxes[0]
    for a in auxes[1:]:
        aux = aux + a
    return (x, aux, cache) if collect_cache else (x, aux)


def _scatter_kv(t, idx, b, s):
    out = jnp.zeros((b, s) + t.shape[2:], t.dtype)
    bi = jnp.arange(b)[:, None]
    return out.at[bi, idx].set(t)


def _pad_cache(k, v, keep, max_len: int, window: int = 0,
               kv_dtype: str = "fp32"):
    """Lay prefill k/v into the ring-cache format (slot = pos % L).

    ``kv_dtype`` "int8" quantizes here — the ring's one-shot-prefill WRITE
    site (docs/quantization.md): decode steps then dequantize the stored
    rows, so the cache row a later decode reads is identical to what a
    decode-time write of the same token would have stored. (The in-flight
    prefill attention above ran on the f32 k/v — that is the documented
    ring-vs-paged bit-stability caveat.) "bf16" narrowing is handled by
    the `.astype` at the `cache_row_insert` splice."""
    B, S = k.shape[:2]
    L = min(max_len, window) if window and window > 0 else max_len
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    quantized = kv_dtype == "int8"
    if quantized:
        k, ks = quant.quantize_kv(k)                     # (B,S,K,Dh),(B,S,K)
        v, vs = quant.quantize_kv(v)
    if S <= L:
        pad = L - S
        pw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        out = {"k": jnp.pad(k, pw), "v": jnp.pad(v, pw),
               "valid": jnp.pad(keep, [(0, 0), (0, pad)]),
               "pos": jnp.pad(pos, [(0, 0), (0, pad)], constant_values=-1)}
        if quantized:
            sw = [(0, 0), (0, pad), (0, 0)]
            out["kscale"] = jnp.pad(ks, sw, constant_values=1.0)
            out["vscale"] = jnp.pad(vs, sw, constant_values=1.0)
        return out
    # keep the last L positions, scattered to their ring slots
    k, v = k[:, -L:], v[:, -L:]
    keep, pos = keep[:, -L:], pos[:, -L:]
    slots = pos % L
    bi = jnp.arange(B)[:, None]
    out = {
        "k": jnp.zeros_like(k).at[bi, slots].set(k),
        "v": jnp.zeros_like(v).at[bi, slots].set(v),
        "valid": jnp.zeros_like(keep).at[bi, slots].set(keep),
        "pos": jnp.full_like(pos, -1).at[bi, slots].set(pos),
    }
    if quantized:
        ks, vs = ks[:, -L:], vs[:, -L:]
        out["kscale"] = jnp.ones_like(ks).at[bi, slots].set(ks)
        out["vscale"] = jnp.ones_like(vs).at[bi, slots].set(vs)
    return out


# ------------------------------ decode --------------------------------------

def _decode_token_gate(rp, name, h, cap, pol):
    """Threshold gate for one decode token: (keep (B,), weight (B,)).
    capacity >= 1 or student off forces (keep all, weight 1) per row."""
    logits = R.token_logits(rp[name], h)[:, 0]               # (B,)
    keep = logits > R.threshold_logit(pol.theta)
    w = keep * jax.nn.sigmoid(logits)
    full = R.is_full(R.gate_capacity(cap, pol.student))
    if R.is_static(full):
        if full:
            return jnp.ones_like(keep, bool), jnp.ones_like(w)
        return keep, w
    full = jnp.broadcast_to(full, keep.shape)
    return keep | full, jnp.where(full, 1.0, w)


def block_decode(kind: str, p, rp, x, cache, t, *, cfg, spec, pol=None,
                 mode: str, elastic_on: bool, window: int = 0,
                 table=None, trash=None):
    """One token. x: (B,1,D); returns (x', new_cache).

    ``table``/``trash``: paged-KV operands (the per-slot page-table rows
    and per-slot trash-page ids — see attention.attn_decode_paged). When
    given and the cache is a page pool ({'kp','vp','pvalid'}), decode
    attention appends through the page table instead of the ring."""
    B = x.shape[0]
    routed = elastic_on and mode != "base" and rp is not None
    backend = OPS.resolve_backend(
        spec.kernel_backend if spec is not None else None)
    new_cache = dict(cache)

    h = norm_apply(p["norm1"], x, cfg.norm)
    keepd, wd = None, None
    if routed and spec.depth_routed and "depth" in rp:
        # per-(slot, layer) whole-layer skip: the token writes NO KV at
        # this layer (write gate below), the mask leaf records it, and
        # the block delta is depth-weighted — unselected slots ride the
        # residual untouched
        keepd, wd = _decode_token_gate(rp, "depth", h, pol.depth_capacity,
                                       pol)
    keep, w1 = None, None
    if routed and spec.mha_token_routed and "tok_mixer" in rp:
        keep, w1 = _decode_token_gate(rp, "tok_mixer", h,
                                      pol.mha_token_capacity, pol)
    if keepd is not None:
        keep = keepd if keep is None else keep & keepd
        w1 = wd if w1 is None else w1 * wd

    auxes = []
    if is_attn(kind):
        lora = rp.get("lora") if routed else None
        if lora is not None:
            dcap = R.gate_capacity(pol.mha_token_capacity, pol.student) \
                if spec.mha_token_routed else None
            dcap = _mul_caps(
                dcap, R.gate_capacity(pol.depth_capacity, pol.student)
                if spec.depth_routed else None)
            lora = _lora_gate(lora, dcap, pol.student)
        hw = _head_weights(rp if routed else None, h, spec, pol, cfg,
                           auxes) if routed else None
        if table is not None and "kp" in cache["attn"]:
            y, new_cache["attn"] = A.attn_decode_paged(
                p["attn"], h, cache["attn"], t, table, trash, cfg=cfg,
                head_weights=hw, lora=lora, write=keep, backend=backend)
        else:
            y, new_cache["attn"] = A.attn_decode(
                p["attn"], h, cache["attn"], t, cfg=cfg, window=window,
                head_weights=hw, lora=lora, write=keep, backend=backend)
    elif kind == "ssm":
        y, new_cache["ssm"] = S.ssm_decode(p["mixer"], h, cache["ssm"], cfg,
                                           write=keep)
    else:
        y, new_cache["rglru"] = G.rglru_decode(p["mixer"], h, cache["rglru"],
                                               cfg, write=keep)
    if keep is not None:
        y = y * w1[:, None, None].astype(y.dtype)
    x = x + y

    if kind == "xattn":
        hx = norm_apply(p["xnorm"], x, cfg.norm)
        xc = cache["xattn"]
        pos = jnp.zeros((B, 1), jnp.int32)
        kvp = jnp.broadcast_to(jnp.arange(xc["k"].shape[1], dtype=jnp.int32),
                               xc["k"].shape[:2])
        mask = A._mask(pos, kvp, False, 0, xc["valid"])
        q = A._project_q(p["xattn"], hx, pos, cfg, None, False)
        ctx = A.sdpa(q, xc["k"], xc["v"], mask)
        x = x + jnp.einsum("bshk,hkd->bsd", ctx,
                           quant.maybe_dequant(p["xattn"], "wo", ctx.dtype))

    if has_mlp(kind):
        h = norm_apply(p["norm2"], x, cfg.norm)
        keep2, w2 = None, None
        if routed and spec.mlp_token_routed and "tok_mlp" in rp:
            keep2, w2 = _decode_token_gate(rp, "tok_mlp", h,
                                           pol.mlp_token_capacity, pol)
        if keepd is not None:   # depth gates the MLP delta too
            keep2 = keepd if keep2 is None else keep2 & keepd
            w2 = wd if w2 is None else w2 * wd
        if cfg.moe is not None:
            if routed and "expert" in rp:
                y, _ = moe_decode(p["mlp"], h, act=cfg.act,
                                  router_w=rp["expert"]["w"],
                                  normalize_to_m=True,
                                  **_expert_args(pol, cfg.moe.n_experts))
            else:
                y, _ = moe_decode(p["mlp"], h, act=cfg.act,
                                  top_k=cfg.moe.top_k)
        elif routed and "expert" in rp and spec.mlp_n_experts:
            ep = moefy_mlp(p["mlp"], spec.mlp_n_experts)
            y, _ = moe_decode(ep, h, act=cfg.act,
                              router_w=rp["expert"]["w"], normalize_to_m=True,
                              **_expert_args(pol, spec.mlp_n_experts))
        else:
            y = mlp_apply(p["mlp"], h, cfg.act)
        if keep2 is not None:
            y = y * w2[:, None, None].astype(y.dtype)
        x = x + y
    return x, new_cache


def block_chunk(kind: str, p, rp, x, cache, write_page, table_row, pos0,
                plen, *, cfg, spec, pol=None, mode: str, elastic_on: bool):
    """One CHUNK of a paged prefill: x is (1, C, D) with C == page_size,
    covering absolute positions [pos0, pos0 + C) of a plen-token prompt
    (the last chunk arrives zero-padded). Mirrors ``block_apply``'s
    inference-threshold branch EXACTLY — ``token_gate(mode)`` / head
    routing / LoRA gating are all per-token, so streaming a prompt through
    this graph chunk-by-chunk produces the same keep decisions and (up to
    reduction order inside attention) the same activations as the one-shot
    prefill — but writes K/V into ONE pool page (``write_page``) and
    attends through ``table_row`` (see attention.attn_chunk). pos0 / plen /
    write_page / table_row are traced, so ONE compile serves every chunk of
    every prompt length. Paged serving is attention-only with dense MLPs
    (engine-validated): ``moe_apply``'s expert-capacity buffers are sized
    by the sequence chunking, so expert dispatch is the one sub-block
    whose one-shot and chunked results can drop different tokens.
    Returns (x', new_cache)."""
    assert mode != "train", "block_chunk is a serving (infer/base) path"
    if not is_attn(kind):
        raise ValueError(f"paged chunk prefill requires attn blocks, "
                         f"got {kind!r}")
    routed = elastic_on and mode != "base" and rp is not None
    backend = OPS.resolve_backend(
        spec.kernel_backend if spec is not None else None)
    impl = spec.routing_impl if spec is not None else "gather"
    new_cache = dict(cache)
    positions = (jnp.asarray(pos0, jnp.int32)
                 + jnp.arange(x.shape[1], dtype=jnp.int32))   # (C,)
    auxes = []                                   # serving: aux discarded

    cap_mha = cap_mlp = cap_depth = None
    if routed and spec is not None and rp:
        if spec.depth_routed and "depth" in rp:
            cap_depth = R.gate_capacity(pol.depth_capacity, pol.student)
        if spec.mha_token_routed and "tok_mixer" in rp:
            cap_mha = R.gate_capacity(pol.mha_token_capacity, pol.student)
        if spec.mlp_token_routed and "tok_mlp" in rp:
            cap_mlp = R.gate_capacity(pol.mlp_token_capacity, pol.student)

    # ---- attention (paged page write + table attend) ----
    h = norm_apply(p["norm1"], x, cfg.norm)
    lora = rp.get("lora") if routed else None
    lora = _lora_gate(lora, _mul_caps(cap_mha, cap_depth),
                      pol.student if (routed and pol is not None) else None)
    hw = _head_weights(rp if routed else None, h, spec, pol, cfg,
                       auxes) if routed else None
    keep_d, w_d = None, None
    if cap_depth is not None:
        # per-token whole-layer skip, threshold semantics (same decision
        # decode would make): skipped tokens write no KV into the page —
        # the page's occupancy bitmap (pvalid) records the hole
        lg = R.token_logits(rp["depth"], h)
        keep_d, w_d = R.token_gate(lg, jax.nn.sigmoid(lg), cap_depth, mode,
                                   theta=pol.theta, mxu=True)
    keep, wtok = None, None
    if cap_mha is not None:
        logits = R.token_logits(rp["tok_mixer"], h)
        scores = jax.nn.sigmoid(logits)
        keep, wtok = R.token_gate(logits, scores, cap_mha, mode,
                                  theta=pol.theta, mxu=True)
    if keep_d is not None:
        keep = keep_d if keep is None else keep & keep_d
        wtok = w_d if wtok is None else wtok * w_d
    y, new_cache["attn"] = A.attn_chunk(
        p["attn"], h, cache["attn"], write_page, table_row, pos0, plen,
        cfg=cfg, keep=keep, head_weights=hw, lora=lora)
    if wtok is not None:
        y = y * wtok[..., None].astype(y.dtype)
    x = x + y

    # ---- MLP (dense; per-token threshold routing) ----
    if has_mlp(kind):
        h = norm_apply(p["norm2"], x, cfg.norm)
        f = _mlp_fn(p, rp, cfg, spec, pol, elastic_on, mode, auxes,
                    backend=backend)
        if cap_mlp is None:
            delta = f(h, positions)
        else:
            delta, _ = R.route_tokens(
                rp["tok_mlp"], h, f, cap_mlp, mode, positions=positions,
                impl=impl, theta=pol.theta if pol is not None else 0.5)
        if w_d is not None:     # depth gates the MLP delta too
            delta = delta * w_d[..., None].astype(delta.dtype)
        x = x + delta
    return x, new_cache


def block_paged_cache_init(kind: str, cfg, n_pages: int, page_size: int,
                           kv_dtype: str = "fp32"):
    """Paged twin of ``block_cache_init``: one layer's slice of the global
    page pool (attention-only — the pool replaces the ring, recurrent
    state has no paged form)."""
    if not is_attn(kind) or kind == "xattn":
        raise ValueError(f"paged KV cache requires self-attention blocks, "
                         f"got {kind!r}")
    return {"attn": A.attn_paged_cache_init(cfg, n_pages, page_size,
                                            kv_dtype=kv_dtype)}


def cache_row_insert(full, row, slot, batch_axis: int = 0):
    """Splice a freshly prefilled single-request block cache (batch dim 1)
    into row ``slot`` of a live slot-array cache of the same structure.

    ``slot`` may be traced (dynamic_update_slice), so admitting a request
    into any serving slot reuses ONE compiled insert. Works on any cache
    pytree (attn k/v/valid/pos rings, ssm/rglru state+conv, xattn context);
    ``batch_axis`` selects where the batch dim lives (1 for pattern-scan
    stacked caches with a leading period dim, 0 for tail caches)."""
    def ins(f, r):
        return jax.lax.dynamic_update_slice_in_dim(
            f, r.astype(f.dtype), slot, axis=batch_axis)
    return jax.tree.map(ins, full, row)


def block_cache_init(kind: str, cfg, batch: int, max_seq: int, enc_len: int = 0,
                     window: int = 0, kv_dtype: str = "fp32"):
    c = {}
    if is_attn(kind):
        c["attn"] = A.attn_cache_init(cfg, batch, max_seq, window,
                                      kv_dtype=kv_dtype)
    if kind == "xattn":
        c["xattn"] = {
            "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.dtype(cfg.dtype)),
            "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.d_head),
                           jnp.dtype(cfg.dtype)),
            "valid": jnp.zeros((batch, enc_len), bool),
        }
    if kind == "ssm":
        c["ssm"] = S.ssm_cache_init(cfg, batch)
    if kind == "rglru":
        c["rglru"] = G.rglru_cache_init(cfg, batch)
    return c
