from repro.models.model import (batch_specs, cache_init, cache_insert,
                                cache_specs, decode_step, forward, model_init,
                                paged_cache_init, prefill, prefill_chunk_step,
                                prefill_into_slot, router_init,
                                router_param_count, build_pattern)

__all__ = ["batch_specs", "cache_init", "cache_insert", "cache_specs",
           "decode_step", "forward", "model_init", "paged_cache_init",
           "prefill", "prefill_chunk_step", "prefill_into_slot",
           "router_init", "router_param_count", "build_pattern"]
