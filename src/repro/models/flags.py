"""Global tracing flags.

ANALYSIS_UNROLL: when True, every lax.scan in the model (layer stack, blocked
attention KV loop, MoE dispatch chunk loop) is fully unrolled at trace time.
Used ONLY by the roofline analysis path: XLA's HloCostAnalysis counts a while
body once regardless of trip count, so the dry-run lowers small unrolled
clones (1 and 2 periods deep) and extrapolates exactly (see launch/dryrun.py).
The production path always scans (compile time, code size).
"""
from __future__ import annotations

from contextlib import contextmanager

ANALYSIS_UNROLL = False


def unroll() -> bool:
    return ANALYSIS_UNROLL


@contextmanager
def analysis_unroll(enabled: bool = True):
    global ANALYSIS_UNROLL
    prev = ANALYSIS_UNROLL
    ANALYSIS_UNROLL = enabled
    try:
        yield
    finally:
        ANALYSIS_UNROLL = prev
