"""Grouped-query attention with RoPE, sliding windows, cross-attention,
KV caches, and ElastiFormer hooks (head routing weights, LoRA q/v).

TP formulation (§Perf H1): q-heads are zero-padded to cfg.n_heads_p (a
multiple of the `model` mesh axis; wo pad rows are zero so the math is
exact) and GQA is computed in *repeat-kv* form — k/v are expanded from K kv
heads to the padded head count with a static take. Every head-indexed
tensor then shards cleanly on one axis, so XLA partitions attention 16-way
with no partial-sum all-reduces (the grouped (B,K,G,Sq,Sk) reshape used to
shatter the head axis across two dims and force replication or worse).

Two softmax-attention implementations:
  * plain: materializes (B,Hp,Sq,Sk) scores — short sequences.
  * blocked: lax.scan over KV chunks with online softmax (flash-style) —
    long sequences; numerically identical (f32 accumulation) and the
    jnp twin of kernels/flash_attention.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lora import lora_apply
from repro.kernels import ops as OPS
from repro.models import flags, quant
from repro.models.layers import dense_init, dtype_of, rope_apply, rope_tables
from repro.runtime import sharding as SH

NEG_INF = -1e30
BLOCKED_THRESHOLD = 2048   # use blocked attention when Sk exceeds this
KV_BLOCK = 1024


def _kernel_ok(backend, cfg, *, window: int = 0, gathered: bool = False,
               causal: bool = True) -> bool:
    """Whether the Pallas flash/decode kernels may serve this attention
    call. The kernels mask causality/window by ARRAY INDEX (the ragged
    prefix contract: gathered tokens stay position-ascending, so
    index-causal == position-causal), but a sliding WINDOW measures
    position distance — on a gathered subset index distance underestimates
    it regardless of causality, so windowed gathered attention keeps the
    jnp twins. TP head padding (Hp != H) would skew the kernels'
    head->kv-group mapping."""
    del causal  # window masking is position-based whether causal or not
    if backend not in ("pallas", "interpret"):
        return False
    if cfg is not None and cfg.n_heads_p != cfg.n_heads:
        return False
    return not (window and window > 0 and gathered)


def _expand_kv(t, hp: int, h: Optional[int] = None):
    """(B,S,K,Dh) -> (B,S,Hp,Dh) repeat-kv (exact GQA; shards on heads).
    h = logical head count (defaults to hp when there is no padding)."""
    k = t.shape[2]
    g = max(1, (h or hp) // k)
    idx = jnp.minimum(jnp.arange(hp) // g, k - 1)
    return jnp.take(t, idx, axis=2)


def attn_init(key, cfg, cross: bool = False):
    D, K, Dh = cfg.d_model, cfg.n_kv_heads, cfg.d_head
    H, Hp = cfg.n_heads, cfg.n_heads_p
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)

    def pad_h(w, axis):  # zero q-head padding (exact)
        if Hp == H:
            return w
        pw = [(0, 0)] * w.ndim
        pw[axis] = (0, Hp - H)
        return jnp.pad(w, pw)

    p = {
        "wq": pad_h(dense_init(ks[0], D, H * Dh, dt).reshape(D, H, Dh), 1),
        "wk": dense_init(ks[1], D, K * Dh, dt).reshape(D, K, Dh),
        "wv": dense_init(ks[2], D, K * Dh, dt).reshape(D, K, Dh),
        "wo": pad_h(dense_init(ks[3], H * Dh, D, dt).reshape(H, Dh, D), 0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp, Dh), dt)
        p["bk"] = jnp.zeros((K, Dh), dt)
        p["bv"] = jnp.zeros((K, Dh), dt)
    return p


def _pad_heads(t, cfg, axis: int = -1, fill: float = 0.0):
    """Pad a head-indexed tensor on `axis` from H to Hp."""
    H, Hp = cfg.n_heads, cfg.n_heads_p
    if Hp == H:
        return t
    pw = [(0, 0)] * t.ndim
    pw[axis] = (0, Hp - H)
    return jnp.pad(t, pw, constant_values=fill)


def _lora_scale(lora, d):
    """Optional traced on/off multiplier ((), or (B,)) set by the policy:
    0 disables the adapter (full-budget / teacher rows stay lossless)."""
    s = lora.get("scale")
    return None if s is None else jnp.reshape(
        jnp.asarray(s), jnp.shape(s) + (1,) * (d - jnp.ndim(s)))


def _project_q(p, x, positions, cfg, lora, use_rope):
    # maybe_dequant: identity for fp32/bf16 trees, int8 * scale otherwise
    q = jnp.einsum("bsd,dhk->bshk", x,
                   quant.maybe_dequant(p, "wq", x.dtype))
    # (B,S,Hp,Dh)
    if lora is not None and "q" in lora:
        H, Dh = cfg.n_heads, cfg.d_head
        dq = lora_apply(lora["q"], x).reshape(x.shape[0], x.shape[1], H, Dh)
        s = _lora_scale(lora, dq.ndim)
        if s is not None:
            dq = dq * s.astype(dq.dtype)
        q = q + _pad_heads(dq, cfg, axis=2)
    if "bq" in p:
        q = q + p["bq"]
    if use_rope:
        cos, sin = rope_tables(positions, cfg.d_head, cfg.rope_theta)
        if cos.ndim == 2:  # (S, half) -> broadcast over batch
            cos, sin = cos[None], sin[None]
        q = rope_apply(q, cos, sin)
    return q


def _project_kv(p, x, positions, cfg, lora, use_rope):
    k = jnp.einsum("bsd,dhk->bshk", x,
                   quant.maybe_dequant(p, "wk", x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x,
                   quant.maybe_dequant(p, "wv", x.dtype))
    if lora is not None and "v" in lora:
        K, Dh = p["wv"].shape[1], p["wv"].shape[2]
        dv = lora_apply(lora["v"], x).reshape(x.shape[0], x.shape[1], K, Dh)
        s = _lora_scale(lora, dv.ndim)
        if s is not None:
            dv = dv * s.astype(dv.dtype)
        v = v + dv
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if use_rope:
        cos, sin = rope_tables(positions, cfg.d_head, cfg.rope_theta)
        if cos.ndim == 2:
            cos, sin = cos[None], sin[None]
        k = rope_apply(k, cos, sin)
    return k, v


def _mask(q_pos, kv_pos, causal: bool, window: int, kv_valid=None):
    """(B?, Sq, Sk) boolean allow-mask."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window and window > 0:
        m &= (qp - kp) < window
    if kv_valid is not None:
        m &= kv_valid[..., None, :]
    return m


def sdpa(q, k, v, mask, cfg=None):
    """q:(B,Sq,Hp,Dh) k,v:(B,Sk,K,Dh) mask:(B?,Sq,Sk) -> (B,Sq,Hp,Dh).

    Repeat-kv GQA (head axis shards whole); f32 softmax."""
    B, Sq, Hp, Dh = q.shape
    mqa = k.shape[2] == 1  # MQA: broadcast kv in the einsum, never expand
    if k.shape[2] != Hp and not mqa:
        h = cfg.n_heads if cfg is not None else Hp
        k, v = _expand_kv(k, Hp, h), _expand_kv(v, Hp, h)
    scale = Dh ** -0.5
    if mqa:
        s = jnp.einsum("bqhd,bsd->bhqs", q, k[:, :, 0])
    else:
        s = jnp.einsum("bqhd,bshd->bhqs", q, k)
    s = s.astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    if mqa:
        ctx = jnp.einsum("bhqs,bsd->bqhd", a.astype(v.dtype), v[:, :, 0])
    else:
        ctx = jnp.einsum("bhqs,bshd->bqhd", a.astype(v.dtype), v)
    return ctx


def blocked_sdpa(q, k, v, q_pos, kv_pos, causal, window, kv_valid=None,
                 block: int = KV_BLOCK, cfg=None):
    """Flash-style online-softmax attention, lax.scan over KV blocks.

    Identical math to sdpa (f32 accumulators), O(Sq*block) live memory."""
    if flags.unroll():
        # analysis mode: cap trip count at 64 so full unroll stays compilable
        block = max(block, -(-k.shape[1] // 64))
        block = -(-block // 128) * 128
    B, Sq, Hp, Dh = q.shape
    Sk = k.shape[1]
    mqa = k.shape[2] == 1  # MQA: broadcast kv in the einsums, never expand
    if k.shape[2] != Hp and not mqa:
        h = cfg.n_heads if cfg is not None else Hp
        k, v = _expand_kv(k, Hp, h), _expand_kv(v, Hp, h)
    kvh = k.shape[2]
    nb = -(-Sk // block)
    pad = nb * block - Sk
    if pad:
        padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        kv_pos_p = jnp.pad(kv_pos, [(0, 0)] * (kv_pos.ndim - 1) + [(0, pad)])
        valid = jnp.ones((Sk,), bool) if kv_valid is None else kv_valid
        valid = jnp.pad(valid, [(0, 0)] * (valid.ndim - 1) + [(0, pad)])
    else:
        kv_pos_p = kv_pos
        valid = jnp.ones((Sk,), bool) if kv_valid is None else kv_valid

    def bcast_b(a):  # give kv-side tensors a batch dim for scan stacking
        return jnp.broadcast_to(a, (B,) + a.shape[-1:]) if a.ndim == 1 else a

    kv_pos_p, valid = bcast_b(kv_pos_p), bcast_b(valid)
    kb = k.reshape(B, nb, block, kvh, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, kvh, Dh).transpose(1, 0, 2, 3, 4)
    pb = kv_pos_p.reshape(B, nb, block).transpose(1, 0, 2)
    mb = valid.reshape(B, nb, block).transpose(1, 0, 2)

    scale = Dh ** -0.5
    q_posb = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos, (B, Sq))

    def body(carry, xs):
        m_i, l_i, acc = carry
        kc, vc, pc, vm = xs
        if mqa:
            s = jnp.einsum("bqhd,bsd->bhqs", q, kc[:, :, 0])
        else:
            s = jnp.einsum("bqhd,bshd->bhqs", q, kc)
        s = s.astype(jnp.float32) * scale
        allow = _mask(q_posb, pc, causal, window, vm)     # (B,Sq,block)
        s = jnp.where(allow[:, None], s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p_ij = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(p_ij, axis=-1)
        if mqa:
            pv = jnp.einsum("bhqs,bsd->bhqd", p_ij,
                            vc[:, :, 0].astype(jnp.float32))
        else:
            pv = jnp.einsum("bhqs,bshd->bhqd", p_ij, vc.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hp, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hp, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hp, Sq, Dh), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb, mb),
                                      unroll=flags.unroll())
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attn_apply(
    p, x, *, cfg, positions, causal: bool = True, window: int = 0,
    kv_x=None, kv_positions=None, kv_valid=None, kv_count=None,
    head_weights=None, lora=None, use_rope: bool = True,
    backend=None, gathered: bool = False,
):
    """Full-sequence attention (train / prefill). Self-attn if kv_x is None.

    head_weights: (B, Sq, H) f32 ElastiFormer head-routing weights (already
    masked, logical heads); multiplies per-head context before the output
    projection — Alg. 1 output scaling = straight-through router gradient.

    ``backend`` ("pallas"/"interpret") routes the softmax-attention core
    through ``kernels.ops.flash_attention`` — the scalar-prefetched
    ``kv_count`` (a RoutingPlan's true token count, () or (B,)) then skips
    every kv/q block past the ragged prefix. ``gathered`` declares that
    q/kv rows are a RoutingPlan buffer (position-ascending subset): causal
    masking by index is exact there, sliding windows are not (see
    ``_kernel_ok``). The default/"ref" backend keeps the jnp twins.
    Returns (out (B,Sq,D), k, v) — k/v (logical K heads) for caches."""
    cross = kv_x is not None
    q = _project_q(p, x, positions, cfg, lora, use_rope and not cross)
    if cross:
        k, v = _project_kv(p, kv_x, kv_positions, cfg, lora, use_rope=False)
        kvp = kv_positions if kv_positions is not None else jnp.arange(kv_x.shape[1])
    else:
        k, v = _project_kv(p, x, positions, cfg, lora, use_rope)
        kvp = positions
    if _kernel_ok(backend, cfg, window=window, gathered=gathered,
                  causal=causal and not cross):
        if kv_valid is not None and kv_valid.ndim == 1:
            kv_valid = jnp.broadcast_to(kv_valid, k.shape[:2])
        ctx = OPS.flash_attention(q, k, v, kv_valid=kv_valid,
                                  kv_count=kv_count,
                                  causal=causal and not cross,
                                  window=window or 0, backend=backend)
    else:
        eff_window = window if (window and window > 0) else k.shape[1]
        if min(k.shape[1], eff_window) > BLOCKED_THRESHOLD:
            qp = positions if positions.ndim == 2 else jnp.broadcast_to(positions, x.shape[:2])
            ctx = blocked_sdpa(q, k, v, qp, kvp, causal and not cross, window,
                               kv_valid, cfg=cfg)
        else:
            mask = _mask(positions, kvp, causal and not cross, window, kv_valid)
            ctx = sdpa(q, k, v, mask, cfg=cfg)
    if head_weights is not None:
        ctx = ctx * _pad_heads(head_weights, cfg)[..., None].astype(ctx.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx,
                     quant.maybe_dequant(p, "wo", ctx.dtype))
    return out, k, v


def attn_decode(
    p, x, cache, t, *, cfg, window: int = 0, head_weights=None, lora=None,
    use_rope: bool = True, write: Optional[jnp.ndarray] = None,
    backend=None,
):
    """One decode step. x: (B,1,D); cache: {'k','v': (B,L,K,Dh),
    'valid': (B,L), 'pos': (B,L) i32}; t: scalar position, or a (B,) i32
    vector of PER-ROW positions (continuous batching: every serving slot
    decodes at its own offset inside one compiled step).

    The cache is a RING buffer: entry for position p lives at slot p % L.
    Sliding-window layers allocate L = window so a 500k-token decode keeps
    an O(window) cache; full-attention layers use L = max_seq (slot == p).
    `pos` records absolute positions (-1 = empty) for RoPE-free masking.
    write: (B,) bool — ElastiFormer token routing: skipped tokens do not
    enter the cache.  Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    quantized = "kscale" in cache
    t = jnp.asarray(t, jnp.int32)
    per_row = t.ndim == 1
    pos = t[:, None] if per_row else jnp.full((B, 1), t, jnp.int32)
    q = _project_q(p, x, pos, cfg, lora, use_rope)
    k_new, v_new = _project_kv(p, x, pos, cfg, lora, use_rope)
    if quantized:
        # quantize ONCE, at the write site (docs/quantization.md): the
        # stored (int8, scale) bytes are what every later read dequantizes
        k_new, ks_new = quant.quantize_kv(k_new)         # (B,1,K,Dh),(B,1,K)
        v_new, vs_new = quant.quantize_kv(v_new)
    wr = jnp.ones((B,), bool) if write is None else write
    if per_row:
        # per-row ring slots: scatter each row's k/v into its own slot.
        # Under a mesh the scatter result is pinned back to the cache
        # sharding (kv-heads over `model`, slots over data) — GSPMD cannot
        # partition a batch-indexed scatter and would otherwise replicate
        # the updated cache to every device, every decode step.
        slots = jax.lax.rem(t, jnp.int32(L))                 # (B,)
        bi = jnp.arange(B)
        def upd(c, n):
            old = c[bi, slots]                               # (B, K, Dh)
            new = jnp.where(wr[:, None, None], n[:, 0], old).astype(c.dtype)
            return SH.constrain_kv_cache(c.at[bi, slots].set(new), cfg)
        ck = upd(cache["k"], k_new)
        cv = upd(cache["v"], v_new)
        if quantized:
            def upds(c, n):   # scale leaves: same scatter, minus Dh
                old = c[bi, slots]                           # (B, K)
                new = jnp.where(wr[:, None], n[:, 0], old).astype(c.dtype)
                return SH.constrain_kv_scale(c.at[bi, slots].set(new), cfg)
            cks = upds(cache["kscale"], ks_new)
            cvs = upds(cache["vscale"], vs_new)
        # the slot is consumed by position t either way (stale entry
        # evicted). The mask leaves get the same write-site pin as k/v:
        # this scatter is batch-indexed too, and an unpinned mask write
        # replicates (B, L) to every device each step.
        valid = SH.constrain_kv_mask(cache["valid"].at[bi, slots].set(wr),
                                     cfg)
        cpos = SH.constrain_kv_mask(cache["pos"].at[bi, slots].set(t), cfg)
    else:
        slot = jax.lax.rem(t, jnp.int32(L))
        old = lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)
        upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(
            c, jnp.where(wr[:, None, None, None], n, old(c)).astype(c.dtype),
            slot, axis=1)
        ck = upd(cache["k"], k_new)
        cv = upd(cache["v"], v_new)
        if quantized:
            upds = lambda c, n: jax.lax.dynamic_update_slice_in_dim(
                c, jnp.where(wr[:, None, None], n, old(c)).astype(c.dtype),
                slot, axis=1)
            cks = upds(cache["kscale"], ks_new)
            cvs = upds(cache["vscale"], vs_new)
        # the slot is consumed by position t either way (stale entry evicted)
        valid = SH.constrain_kv_mask(jax.lax.dynamic_update_slice_in_dim(
            cache["valid"], wr[:, None], slot, axis=1), cfg)
        cpos = SH.constrain_kv_mask(jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.full((B, 1), t, jnp.int32), slot, axis=1), cfg)
    new_cache = {"k": ck, "v": cv, "valid": valid, "pos": cpos}
    if quantized:
        new_cache["kscale"], new_cache["vscale"] = cks, cvs
    kv_valid = valid & (cpos >= 0)
    if _kernel_ok(backend, cfg):
        # ring-cache decode kernel: per-slot positions ride scalar
        # prefetch, masking is by the cache's absolute-position array.
        # Under a mesh the kernel runs per-shard (heads over `model`,
        # slots over data) via shard_map — see ops.decode_attention_sharded.
        tvec = t if per_row else jnp.broadcast_to(t, (B,))
        ctx = OPS.decode_attention_sharded(
            q, ck, cv, cpos, tvec, valid, window=window or 0,
            backend=backend,
            kscale=cks if quantized else None,
            vscale=cvs if quantized else None)
    else:
        ckf = quant.dequantize_kv(ck, cks, q.dtype) if quantized else ck
        cvf = quant.dequantize_kv(cv, cvs, q.dtype) if quantized else cv
        if L > BLOCKED_THRESHOLD:
            ctx = blocked_sdpa(q, ckf, cvf, pos, cpos, True, window,
                               kv_valid, cfg=cfg)
        else:
            mask = _mask(pos, cpos, True, window, kv_valid)
            ctx = sdpa(q, ckf, cvf, mask, cfg=cfg)
    if head_weights is not None:
        ctx = ctx * _pad_heads(head_weights, cfg)[..., None].astype(ctx.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx,
                     quant.maybe_dequant(p, "wo", ctx.dtype))
    return out, new_cache


def attn_cache_init(cfg, batch: int, max_seq: int, window: int = 0,
                    kv_dtype: str = "fp32"):
    """Ring cache of length window (local layers) or max_seq (global).
    kv_dtype (docs/quantization.md): "fp32" stores the native config dtype,
    "bf16" a plain cast, "int8" adds per-(slot, token, kv-head) f32
    ``kscale``/``vscale`` sibling leaves."""
    L = min(max_seq, window) if window and window > 0 else max_seq
    K, Dh = cfg.n_kv_heads, cfg.d_head
    dt = quant.kv_store_dtype(quant.check_kv_dtype(kv_dtype), dtype_of(cfg))
    cache = {
        "k": jnp.zeros((batch, L, K, Dh), dt),
        "v": jnp.zeros((batch, L, K, Dh), dt),
        "valid": jnp.zeros((batch, L), bool),
        "pos": jnp.full((batch, L), -1, jnp.int32),
    }
    if kv_dtype == "int8":
        cache["kscale"] = jnp.ones((batch, L, K), jnp.float32)
        cache["vscale"] = jnp.ones((batch, L, K), jnp.float32)
    return cache


# ------------------------------ paged KV pool --------------------------------
#
# The block-paged twin of the ring cache (runtime/pagedkv.py): one GLOBAL
# per-layer pool of (n_pages, page_size, K, Dh) pages shared by every
# serving slot, addressed through per-slot int32 page-table rows. Position
# t of slot b lives at (table[b, t // page_size], t % page_size) — the
# position is implicit in the table layout, so there is no `pos` array;
# `pvalid` carries the ElastiFormer token-gate keep decision per lane.


def attn_paged_cache_init(cfg, n_pages: int, page_size: int,
                          kv_dtype: str = "fp32"):
    """One layer's slice of the global page pool. kv_dtype
    (docs/quantization.md): "int8" adds per-(page, lane, kv-head) f32
    ``kscale``/``vscale`` sibling pools."""
    K, Dh = cfg.n_kv_heads, cfg.d_head
    dt = quant.kv_store_dtype(quant.check_kv_dtype(kv_dtype), dtype_of(cfg))
    cache = {
        "kp": jnp.zeros((n_pages, page_size, K, Dh), dt),
        "vp": jnp.zeros((n_pages, page_size, K, Dh), dt),
        "pvalid": jnp.zeros((n_pages, page_size), bool),
    }
    if kv_dtype == "int8":
        cache["kscale"] = jnp.ones((n_pages, page_size, K), jnp.float32)
        cache["vscale"] = jnp.ones((n_pages, page_size, K), jnp.float32)
    return cache


def _paged_gather(cache, table, B: int, dtype=None):
    """Gather a (B, P)-table's pages into position-ordered (B, P*ps, K, Dh)
    K/V plus the (B, P*ps) validity mask and the implicit kv positions.
    int8 pools come back dequantized (``dtype``, default f32) — this is
    the jnp twin, the kernel path dequantizes in-register."""
    ps = cache["kp"].shape[1]
    P = table.shape[-1]
    pid = jnp.maximum(table, 0)
    kg = cache["kp"][pid].reshape(B, P * ps, *cache["kp"].shape[2:])
    vg = cache["vp"][pid].reshape(B, P * ps, *cache["vp"].shape[2:])
    if "kscale" in cache:
        K = kg.shape[-2]
        kg = quant.dequantize_kv(
            kg, cache["kscale"][pid].reshape(B, P * ps, K), dtype)
        vg = quant.dequantize_kv(
            vg, cache["vscale"][pid].reshape(B, P * ps, K), dtype)
    kvv = ((table[..., None] >= 0)
           & cache["pvalid"][pid]).reshape(B, P * ps)
    kvpos = (jnp.arange(P)[:, None] * ps
             + jnp.arange(ps)[None, :]).reshape(-1)
    return kg, vg, kvv, kvpos


def attn_decode_paged(
    p, x, cache, t, table, trash, *, cfg, head_weights=None, lora=None,
    use_rope: bool = True, write: Optional[jnp.ndarray] = None,
    backend=None,
):
    """One decode step over the paged pool. x: (B,1,D); cache:
    {'kp','vp': (N, ps, K, Dh), 'pvalid': (N, ps)}; t: (B,) i32 per-slot
    positions; table: (B, P) i32 page-table rows (GLOBAL page ids, -1 =
    unused entry — the host guarantees entry t // ps is backed for every
    ACTIVE slot); trash: (B,) i32 per-slot trash-page ids — rows whose
    table entry is -1 (inactive slots) are remapped there, so the write is
    branch-free and never lands on a live page. write: (B,) bool token
    gate. Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    ps = cache["kp"].shape[1]
    quantized = "kscale" in cache
    t = jnp.asarray(t, jnp.int32).reshape(-1)
    pos = t[:, None]                                       # (B, 1)
    q = _project_q(p, x, pos, cfg, lora, use_rope)
    k_new, v_new = _project_kv(p, x, pos, cfg, lora, use_rope)
    if quantized:
        # quantize ONCE, at the write site (docs/quantization.md)
        k_new, ks_new = quant.quantize_kv(k_new)         # (B,1,K,Dh),(B,1,K)
        v_new, vs_new = quant.quantize_kv(v_new)
    wr = jnp.ones((B,), bool) if write is None else write
    entries = jnp.take_along_axis(table, (t // ps)[:, None], axis=1)[:, 0]
    pages = jnp.where(entries >= 0, entries, trash)        # (B,)
    offs = jax.lax.rem(t, jnp.int32(ps))
    # per-slot page append: CoW guarantees the append page is exclusively
    # owned, so distinct active rows never scatter to the same (page, lane).
    # Under a mesh the scatter result is pinned back to the pool sharding
    # (pages over data, kv-heads over `model`) — GSPMD cannot partition a
    # page-indexed scatter and would otherwise replicate the whole pool.
    def upd(c, n):
        old = c[pages, offs]                               # (B, K, Dh)
        new = jnp.where(wr[:, None, None], n[:, 0], old).astype(c.dtype)
        return SH.constrain_page_pool(c.at[pages, offs].set(new), cfg)
    kp = upd(cache["kp"], k_new)
    vp = upd(cache["vp"], v_new)
    # the occupancy bitmap is page-indexed like k/v: pin it too, or the
    # depth router's skip writes replicate the (N, ps) mask pool per step
    pvalid = SH.constrain_page_pool(
        cache["pvalid"].at[pages, offs].set(wr), cfg)
    new_cache = {"kp": kp, "vp": vp, "pvalid": pvalid}
    if quantized:
        def upds(c, n):   # scale pools: same scatter, minus Dh
            old = c[pages, offs]                           # (B, K)
            new = jnp.where(wr[:, None], n[:, 0], old).astype(c.dtype)
            return SH.constrain_page_pool(c.at[pages, offs].set(new), cfg)
        new_cache["kscale"] = upds(cache["kscale"], ks_new)
        new_cache["vscale"] = upds(cache["vscale"], vs_new)
    if _kernel_ok(backend, cfg):
        # paged decode kernel: the table and per-slot lengths ride scalar
        # prefetch, the BlockSpec index_map gathers pages from the pool.
        # Under a mesh it runs per-shard (kv-heads over `model`, pages and
        # slots over data) — see ops.paged_decode_attention_sharded.
        ctx = OPS.paged_decode_attention_sharded(
            q, kp, vp, table, t, pvalid, backend=backend,
            kscale=new_cache.get("kscale"),
            vscale=new_cache.get("vscale"))
    else:
        kg, vg, kvv, kvpos = _paged_gather(new_cache, table, B,
                                           dtype=q.dtype)
        mask = _mask(pos, kvpos[None], True, 0, kvv)
        ctx = sdpa(q, kg, vg, mask, cfg=cfg)
        # rows with no attendable key: match the kernel's exact zeros
        ctx = jnp.where(mask.any(-1)[:, :, None, None], ctx, 0.0)
    if head_weights is not None:
        ctx = ctx * _pad_heads(head_weights, cfg)[..., None].astype(ctx.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx,
                     quant.maybe_dequant(p, "wo", ctx.dtype))
    return out, new_cache


def attn_chunk(
    p, x, cache, write_page, table_row, pos0, plen, *, cfg, keep=None,
    head_weights=None, lora=None, use_rope: bool = True,
):
    """One CHUNK of a paged prefill, shaped like a decode: x is (1, C, D)
    with C == page_size, covering absolute positions [pos0, pos0 + C). The
    chunk's K/V fill exactly ONE page (``write_page``, a traced id — the
    replica's trash page when this chunk's prefix page is shared and the
    chunk only recomputes queries), then the queries attend over ALL pages
    of ``table_row`` with causal masking on the implicit positions — so a
    prompt of ANY length streams through this one compiled graph,
    collapsing the per-length prefill buckets to a single compile.
    ``keep``: (1, C) ElastiFormer token gate; lanes at positions >= plen
    (chunk padding) are never marked valid. Returns (out (1,C,D),
    new_cache)."""
    B, C, _ = x.shape
    positions = pos0 + jnp.arange(C, dtype=jnp.int32)[None, :]   # (1, C)
    q = _project_q(p, x, positions, cfg, lora, use_rope)
    k_new, v_new = _project_kv(p, x, positions, cfg, lora, use_rope)
    if "kscale" in cache:
        # quantize ONCE, at the write site; the queries below then attend
        # the QUANTIZED pool via _paged_gather, so a chunked prefill is
        # bitwise identical to the decode path reading the same pages
        # (docs/quantization.md)
        k_new, ks_new = quant.quantize_kv(k_new)         # (1,C,K,Dh),(1,C,K)
        v_new, vs_new = quant.quantize_kv(v_new)
    wr = jnp.ones((B, C), bool) if keep is None else keep
    wr = wr & (positions < plen)

    def upd(c, n):
        out = jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (write_page, 0, 0, 0))
        return SH.constrain_page_pool(out, cfg)
    kp = upd(cache["kp"], k_new)                           # (1,C,K,Dh) page
    vp = upd(cache["vp"], v_new)
    pvalid = SH.constrain_page_pool(
        jax.lax.dynamic_update_slice(cache["pvalid"], wr, (write_page, 0)),
        cfg)
    new_cache = {"kp": kp, "vp": vp, "pvalid": pvalid}
    if "kscale" in cache:
        def upds(c, n):
            out = jax.lax.dynamic_update_slice(
                c, n.astype(c.dtype), (write_page, 0, 0))
            return SH.constrain_page_pool(out, cfg)
        new_cache["kscale"] = upds(cache["kscale"], ks_new)
        new_cache["vscale"] = upds(cache["vscale"], vs_new)
    kg, vg, kvv, kvpos = _paged_gather(new_cache, table_row[None], B,
                                       dtype=q.dtype)
    mask = _mask(positions, kvpos[None], True, 0, kvv)
    ctx = sdpa(q, kg, vg, mask, cfg=cfg)
    if head_weights is not None:
        ctx = ctx * _pad_heads(head_weights, cfg)[..., None].astype(ctx.dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx,
                     quant.maybe_dequant(p, "wo", ctx.dtype))
    return out, new_cache
