"""RecurrentGemma / Griffin recurrent block with RG-LRU. [arXiv:2402.19427]

Block:  x -> (gate branch: W_y x -> GeLU)  *  (W_x x -> causal conv1d ->
RG-LRU) -> W_out.  RG-LRU:
    r_t = sigmoid(W_a u_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The linear recurrence is computed with jax.lax.associative_scan (log-depth on
TPU), decode is the O(1) step form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

_C = 8.0


def rglru_init(key, cfg):
    D, W, dt = cfg.d_model, cfg.lru_width, dtype_of(cfg)
    ck = cfg.conv_kernel
    ks = jax.random.split(key, 6)
    # Lambda init so that a^c = sigmoid(Lambda)^c is in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_y": dense_init(ks[1], D, W, dt),
        "w_x": dense_init(ks[2], D, W, dt),
        "conv_w": (jax.random.normal(ks[3], (ck, W), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((W,), dt),
        "w_a": dense_init(ks[4], W, W, jnp.float32, scale=1.0 / W ** 0.5),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[5], W, W, jnp.float32, scale=1.0 / W ** 0.5),
        "b_i": jnp.zeros((W,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), W, D, dt),
    }


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_i"] + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * uf)
    return a, gated_in


def _causal_conv(x, w, b, state=None):
    ck = w.shape[0]
    if state is not None:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(ck))
    return y + b, xp[:, -(ck - 1):]


def rglru_apply(p, x, cfg, init_state=None, conv_state=None, keep_mask=None):
    """Full sequence. x: (B,S,D) -> (B,S,D). Returns (y, (h_final, conv)).

    keep_mask: (B,S) bool ElastiFormer token routing — skipped tokens use
    a=1, input=0: exact recurrent-state pass-through."""
    gate = jax.nn.gelu((x @ p["w_y"]).astype(jnp.float32), approximate=True)
    u, new_conv = _causal_conv(x @ p["w_x"], p["conv_w"], p["conv_b"],
                               conv_state)
    a, b = _gates(p, u)                                     # (B,S,W) f32
    if keep_mask is not None:
        km = keep_mask[..., None]
        a = jnp.where(km, a, 1.0)
        b = jnp.where(km, b, 0.0)
    if init_state is not None:
        b = b.at[:, 0].add(a[:, 0] * init_state.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * h).astype(x.dtype) @ p["w_out"]
    return y, (h[:, -1], new_conv)


def rglru_decode(p, x, cache, cfg, write=None):
    """One step. cache: {'state': (B,W) f32, 'conv': (B,ck-1,W)}."""
    B = x.shape[0]
    gate = jax.nn.gelu((x @ p["w_y"]).astype(jnp.float32), approximate=True)
    xw = x @ p["w_x"]                                       # (B,1,W)
    conv_in = jnp.concatenate([cache["conv"].astype(xw.dtype), xw], axis=1)
    u = (jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"]) + p["conv_b"])[:, None]
    a, b = _gates(p, u)                                     # (B,1,W)
    h = a[:, 0] * cache["state"] + b[:, 0]
    wr = jnp.ones((B,), bool) if write is None else write
    h = jnp.where(wr[:, None], h, cache["state"])
    new_conv = jnp.where(wr[:, None, None], conv_in[:, 1:], cache["conv"])
    y = (gate[:, 0] * h)[:, None].astype(x.dtype) @ p["w_out"]
    return y, {"state": h, "conv": new_conv}


def rglru_cache_init(cfg, batch: int):
    return {
        "state": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.lru_width),
                          dtype_of(cfg)),
    }
