"""Symmetric int8 quantization for the serving engine's KV caches and base
weights (``ElasticSpec.kv_dtype`` / ``ElasticSpec.weight_dtype``).

Protocol (docs/quantization.md):

* KV rows are quantized ONCE, at the cache write site, per (token, head):
  ``scale = max|x| over Dh / 127`` (f32), ``q = round(x / scale)`` clipped to
  [-127, 127]. The scale rides as a sibling pytree leaf next to the int8
  tensor (ring: ``kscale``/``vscale`` (B, L, K); paged pool: (N, page_size,
  K)), so row splices, page copies, forks, and preemption replays move the
  EXACT stored bytes — re-quantizing a dequantized value drifts, copying
  (int8, scale) pairs cannot.
* Weights are quantized once at engine init, per OUTPUT channel (the axes
  the consuming contraction does NOT reduce), with an f32 ``{name}_scale``
  sibling leaf.
* Dequantization is ``q.astype(f32) * scale`` — inside the Pallas kernels
  it happens in-register after the tile load (never as an HBM-visible op);
  the jnp ref twins apply the same expression on whole (small) tensors.

``"fp32"`` means "native config dtype, no quantization" (the legacy
behavior); ``"bf16"`` is a plain cast (no scales — bf16 keeps f32's
exponent range).
"""
from __future__ import annotations

import jax.numpy as jnp

KV_DTYPES = ("fp32", "bf16", "int8")
WEIGHT_DTYPES = ("fp32", "bf16", "int8")

INT8_MAX = 127.0


def check_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    return kv_dtype


def check_weight_dtype(weight_dtype: str) -> str:
    if weight_dtype not in WEIGHT_DTYPES:
        raise ValueError(f"weight_dtype must be one of {WEIGHT_DTYPES}, "
                         f"got {weight_dtype!r}")
    return weight_dtype


def kv_store_dtype(kv_dtype: str, cfg_dtype) -> jnp.dtype:
    """Storage dtype of the k/v cache leaves for a given ``kv_dtype``."""
    if kv_dtype == "int8":
        return jnp.dtype(jnp.int8)
    if kv_dtype == "bf16":
        return jnp.dtype(jnp.bfloat16)
    return jnp.dtype(cfg_dtype)


def quantize_kv(x):
    """Per-(token, head) symmetric int8: x (..., Dh) -> (q int8 (..., Dh),
    scale f32 (...,)). Deterministic (round-half-away via jnp.round), so
    identical f32 inputs always produce identical stored bytes — the
    bit-stability contract prefix sharing and replay rely on."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax, 1.0) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale[..., None]), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=None):
    """Inverse of quantize_kv (f32 compute, optionally cast to ``dtype`` —
    the activation dtype, so bf16 models keep their legacy compute dtype).
    Only for the jnp ref paths — the Pallas kernels apply the same
    expression in-register per tile."""
    x = q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    return x if dtype is None else x.astype(dtype)


# ------------------------------ weights --------------------------------------
#
# Reduced (input) axes are END-RELATIVE, so the same rule covers per-layer
# params and the pattern scan's stacked (L, ...) leaves:
#   * attention wq/wk/wv (..., D, H, Dh): reduce D        -> scale (..., H, Dh)
#   * attention wo       (..., H, Dh, D): reduce (H, Dh)  -> scale (..., D)
#   * mlp wi/wg          (..., D, F):     reduce D        -> scale (..., F)
#   * mlp wo             (..., F, D):     reduce F        -> scale (..., D)
#   * expert stacks      (..., E, D, F) / (..., E, F, D): reduce the middle
# "wo" is ambiguous between the attention and MLP shapes; quantization and
# dequantization both disambiguate by the SIBLING names in the param dict
# (an attention dict carries "wq", an MLP dict carries "wi").


def _reduce_axes(node: dict, name: str):
    """End-relative reduced axes for weight ``name`` in param dict
    ``node``, or None if the name is not a quantizable base matrix."""
    if name in ("wq", "wk", "wv"):
        return (-3,)
    if name == "wo" and "wq" in node:
        return (-3, -2)                    # attention out-projection
    if name in ("wi", "wg", "wo") and "wi" in node:
        return (-2,)                       # dense MLP / expert stacks
    return None


def quantize_weight(w, reduce_axes):
    """Per-output-channel symmetric int8: scale has w's shape minus the
    reduced axes."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes)
    scale = jnp.where(amax > 0, amax, 1.0) / INT8_MAX
    sb = jnp.expand_dims(scale, reduce_axes)
    q = jnp.clip(jnp.round(wf / sb), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def dequantize_weight(q, scale, reduce_axes):
    return (q.astype(jnp.float32)
            * jnp.expand_dims(scale.astype(jnp.float32), reduce_axes))


def maybe_dequant(p: dict, name: str, dtype=None):
    """Read weight ``name`` from param dict ``p``, dequantizing if a
    ``{name}_scale`` sibling is present (engine-quantized params). The
    single accessor every jnp weight consumer goes through, so fp32-mode
    trees take the exact legacy path. ``dtype`` (the activation dtype)
    casts the dequantized result so downstream einsums keep the legacy
    compute dtype — without it a bf16 model's residual stream would be
    promoted to f32 and break the scan carry."""
    w = p[name]
    scale = p.get(name + "_scale")
    if scale is None:
        return w
    wd = dequantize_weight(w, scale, _reduce_axes(p, name))
    return wd if dtype is None else wd.astype(dtype)


def quantize_params_tree(params, weight_dtype: str):
    """Engine-init transform: quantize/cast the base attention projections
    and MLP/MoE matrices in a model param tree, leaving routers, norms,
    embeddings, LoRA and biases untouched. int8 adds f32 ``{name}_scale``
    sibling leaves; bf16 is a plain cast. Returns a NEW tree (inputs are
    never mutated)."""
    check_weight_dtype(weight_dtype)
    if weight_dtype == "fp32":
        return params

    def walk(node):
        if isinstance(node, (list, tuple)):    # scan/tail stacking lists
            return type(node)(walk(v) for v in node)
        if not isinstance(node, dict):
            return node
        out = {}
        for k, v in node.items():
            if isinstance(v, (dict, list, tuple)):
                out[k] = walk(v)
                continue
            axes = _reduce_axes(node, k) \
                if getattr(v, "ndim", 0) >= 2 else None
            if axes is None:
                out[k] = v
            elif weight_dtype == "bf16":
                out[k] = v.astype(jnp.bfloat16)
            else:
                q, scale = quantize_weight(v, axes)
                out[k] = q
                out[k + "_scale"] = scale
        return out

    return walk(params)
