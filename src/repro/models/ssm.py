"""Mamba2 SSD (state-space duality) block — chunked parallel scan form for
training/prefill, O(1)-state recurrent form for decode. [arXiv:2405.21060]

TPU adaptation: the chunked algorithm is expressed as dense (chunk x chunk)
matmuls (MXU-friendly) + a lax.scan over chunk states (the only sequential
part), instead of the CUDA selective-scan kernel. Projections are kept as
separate parameters (z/x/B/C/dt) rather than one fused in_proj so that
tensor-parallel sharding stays aligned with the head structure (d_inner and
dt shard over the `model` axis; the group-shared B/C are replicated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of, norm_apply


def ssm_init(key, cfg):
    D, dt = cfg.d_model, dtype_of(cfg)
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ck = cfg.conv_kernel
    ks = jax.random.split(key, 8)
    return {
        "in_z": dense_init(ks[0], D, di, dt),
        "in_x": dense_init(ks[1], D, di, dt),
        "in_b": dense_init(ks[2], D, N, dt),
        "in_c": dense_init(ks[3], D, N, dt),
        "in_dt": dense_init(ks[4], D, H, dt),
        "conv_x": (jax.random.normal(ks[5], (ck, di), jnp.float32) * 0.1).astype(dt),
        "conv_b": (jax.random.normal(ks[6], (ck, N), jnp.float32) * 0.1).astype(dt),
        "conv_c": (jax.random.normal(ks[7], (ck, N), jnp.float32) * 0.1).astype(dt),
        "conv_bias_x": jnp.zeros((di,), dt),
        "conv_bias_b": jnp.zeros((N,), dt),
        "conv_bias_c": jnp.zeros((N,), dt),
        "a_log": jnp.zeros((H,), jnp.float32),            # A = -exp(a_log) = -1
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(jax.random.fold_in(key, 9), di, D, dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B,S,C), w: (ck,C) -> (B,S,C)."""
    ck = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(ck))
    return y + b


def _segsum(a):
    """a: (..., q) -> (..., q, q) with out[i,j] = sum_{j<m<=i} a[m], -inf above
    the diagonal (strictly causal cumulative decay)."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int, init_state=None):
    """SSD: y_t = C_t^T h_t,  h_t = exp(a_t dt_t) h_{t-1} + dt_t B_t x_t^T.

    x: (B,S,H,P); dt: (B,S,H); a: (H,) (negative); bmat/cmat: (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = bmat.shape[-1]
    assert S % chunk == 0, f"seq {S} % ssm_chunk {chunk} != 0"
    nc, q = S // chunk, chunk
    dA = (dt * a).astype(jnp.float32)                       # (B,S,H)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    r = lambda t: t.reshape((B, nc, q) + t.shape[2:])
    xc, dAc = r(xdt), r(dA)
    bc, cc = r(bmat.astype(jnp.float32)), r(cmat.astype(jnp.float32))

    # intra-chunk (quadratic within chunk, MXU matmuls)
    L = jnp.exp(_segsum(dAc.transpose(0, 1, 3, 2)))         # (B,nc,H,q,q)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)              # (B,nc,q,q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", cb, L, xc)

    # chunk states
    dA_cum = jnp.cumsum(dAc, axis=2)                        # (B,nc,q,H)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (B,nc,q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_states, xc)

    # inter-chunk recurrence (the only sequential part)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (B,nc,H)
    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(h, xs):
        s, dec = xs                                         # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + s
        return h_new, h                                     # emit state *before* chunk

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    h_final, h_prev = jax.lax.scan(body, h0, xs)
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    state_decay = jnp.exp(dA_cum)                           # (B,nc,q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, h_prev, state_decay)
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, h_final


def _project(p, x):
    z = x @ p["in_z"]
    xs = x @ p["in_x"]
    bmat = x @ p["in_b"]
    cmat = x @ p["in_c"]
    dt = x @ p["in_dt"]
    return z, xs, bmat, cmat, dt


def ssm_apply(p, x, cfg, init_state=None, conv_state=None, keep_mask=None):
    """Full-sequence Mamba2 block. x: (B,S,D) -> (B,S,D).
    Returns (y, (ssm_state, conv_state)) for cache hand-off at prefill.

    keep_mask: (B,S) bool ElastiFormer token routing — dt is zeroed for
    skipped tokens, which makes the recurrence an exact state pass-through
    (decay exp(a*0)=1, input dt*B*x=0)."""
    B, S, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    z, xs, bmat, cmat, dt = _project(p, x)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1)
    conv_bias = jnp.concatenate(
        [p["conv_bias_x"], p["conv_bias_b"], p["conv_bias_c"]], axis=-1)
    if conv_state is not None:
        xbc_in = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        xbc_conv = _causal_conv(xbc_in, conv_w, conv_bias)[:, -(S + cfg.conv_kernel - 1):][:, -S:]
    else:
        xbc_conv = _causal_conv(xbc, conv_w, conv_bias)
        xbc_in = xbc
    new_conv_state = xbc_in[:, -(cfg.conv_kernel - 1):]
    xbc_conv = jax.nn.silu(xbc_conv)
    xs, bmat, cmat = jnp.split(xbc_conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if keep_mask is not None:
        dt = dt * keep_mask[..., None].astype(dt.dtype)
    a = -jnp.exp(p["a_log"])
    chunk = min(cfg.ssm_chunk, S)
    while S % chunk:        # largest divisor of S not exceeding ssm_chunk
        chunk -= 1
    y, state = ssd_chunked(xs.reshape(B, S, H, P), dt, a, bmat, cmat,
                           chunk, init_state)
    y = y + p["d_skip"][:, None] * xs.reshape(B, S, H, P).astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = norm_apply({"scale": p["norm_scale"]},
                   (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                   "rmsnorm")
    return y @ p["out_proj"], (state, new_conv_state)


def ssm_decode(p, x, cache, cfg, write=None):
    """One decode step. x: (B,1,D); cache: {'state': (B,H,P,N) f32,
    'conv': (B,ck-1,di+2N)}. write: (B,) bool token-routing gate — when False
    the state/conv caches pass through unchanged (token skipped)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, xs, bmat, cmat, dt = _project(p, x)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)        # (B,1,C)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1)
    conv_bias = jnp.concatenate(
        [p["conv_bias_x"], p["conv_bias_b"], p["conv_bias_c"]], axis=-1)
    conv_in = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    y_conv = jnp.einsum("bkc,kc->bc", conv_in, conv_w) + conv_bias
    xbc_conv = jax.nn.silu(y_conv)[:, None]
    xs, bmat, cmat = jnp.split(xbc_conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * a)                                    # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    new_state = (cache["state"] * dA[..., None, None]
                 + jnp.einsum("bh,bhp,bn->bhpn", dt, xh,
                              bmat[:, 0].astype(jnp.float32)))
    wr = jnp.ones((B,), bool) if write is None else write
    new_state = jnp.where(wr[:, None, None, None], new_state, cache["state"])
    new_conv = jnp.where(wr[:, None, None], conv_in[:, 1:], cache["conv"])
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), new_state)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(B, 1, di)
    y = norm_apply({"scale": p["norm_scale"]},
                   (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                   "rmsnorm")
    return y @ p["out_proj"], {"state": new_state, "conv": new_conv}


def ssm_cache_init(cfg, batch: int):
    di, N = cfg.d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, N),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di + 2 * N),
                          dtype_of(cfg)),
    }
