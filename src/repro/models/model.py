"""Model assembly: embedding, pattern-scanned block stack, LM head; prefill &
decode; encoder / enc-dec / VLM plumbing; ElastiFormer router attachment.

Layer stacking uses a *pattern scan*: the layer sequence is grouped into
repeating periods (heterogeneous kinds, windows, and elastic on/off flags are
static per pattern position). Parameters are stacked per position and the
period is unrolled inside a single jax.lax.scan body — so compile time and
HLO size stay ~O(one period) even at 88 layers and 512-way SPMD, with exact
per-kind cost attribution (no lax.switch dual-branch waste). Remainder layers
run unrolled ("tail").

Elasticity API: every entry point takes ``elastic`` as either the legacy
``ElasticConfig`` (static; deprecated shim) or the new ``ElasticSpec``, plus
an optional runtime ``policy`` (``ElasticPolicy`` pytree). When ``policy``
is passed into a jitted call it is *traced*: one compilation serves every
compute budget (capacity sweeps, per-request budgets, annealing schedules).
Policy leaves with a leading layer dim (L, ...) are split per layer and fed
through the pattern scan, enabling per-layer-group capacity schedules.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import ElasticPolicy, as_spec_policy
from repro.core.routing import (RouteAux, bcast_to, capacity_k, gate_capacity,
                                is_full, is_static, gather_tokens,
                                token_router_init, topk_indices,
                                topk_mask_dyn)
from repro.models.blocks import (block_apply, block_cache_init, block_chunk,
                                 block_decode, block_paged_cache_init,
                                 block_router_init, block_init,
                                 cache_row_insert)
from repro.models.layers import dense_init, dtype_of, norm_apply, norm_init
from repro.models import flags


class PatternPos(NamedTuple):
    kind: str
    window: int
    elastic: bool


def _total(mesh, axes) -> int:
    n = 1
    for g in axes:
        for a in (g if isinstance(g, tuple) else (g,)):
            n *= mesh.shape.get(a, 1)
    return n


def build_pattern(cfg, elastic=None):
    """Returns (period: tuple[PatternPos], P, R). ``elastic`` is an
    ElasticSpec or a legacy ElasticConfig (only .layers matters here)."""
    n = cfg.n_layers
    base = math.lcm(len(cfg.mixer_pattern), len(cfg.window_pattern))
    if elastic is not None and elastic.layers == "even":
        base = math.lcm(base, 2)
    period_len = base if base <= n else n
    kinds, wins = cfg.layer_kinds, cfg.layer_windows
    applies = (lambda i: True) if elastic is None else elastic.applies_to_layer
    period = tuple(PatternPos(kinds[j], wins[j], applies(j))
                   for j in range(period_len))
    return period, n // period_len, n % period_len


def _split_layers(per_layer: list, period_len: int, P: int):
    """[L trees] -> (scan: [period_len stacked-over-P trees], tail: [R trees])."""
    scan = []
    for j in range(period_len):
        if P > 0:
            scan.append(jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[per_layer[p * period_len + j] for p in range(P)]))
    tail = per_layer[P * period_len:]
    return scan, tail


# --------------------------- policy threading --------------------------------

def _pol_static(pol) -> bool:
    """True when every policy leaf is a python number (or no policy): the
    values are trace-time constants and must NOT be routed through scan /
    shard_map arguments (that would turn them into tracers and lose the
    static gather path)."""
    return pol is None or all(is_static(l) for l in jax.tree.leaves(pol))


def _split_policy(pol, n_layers: int, period_len: int, P: int):
    """Per-layer split of a traced policy with (L, ...) leaves, mirroring
    the parameter stacking. Returns (scan list, tail list)."""
    per = [pol.for_layer(i) for i in range(n_layers)]
    return _split_layers(per, period_len, P)


def _tail_plan(params, rparams, period, pol_tail, *, has_rp: bool,
               static_pol: bool, pol):
    """Hoisted per-tail-layer (params, entry, router-params, policy) tuples.

    The tail loops used to re-derive ``period[i % len(period)]`` and the
    per-layer policy selection inside every iteration of every trace; with
    layered (L, B) policy leaves (per-layer depth schedules) that costs an
    extra ``for_layer`` gather per layer per trace. Resolve once, zip in
    the caller — the same hoist ``_split_policy`` does for the scan body.
    ``pol_tail`` is the layered split (None when the policy has no layer
    dim)."""
    n = len(params["tail"])
    ents = [period[i % len(period)] for i in range(n)]
    rps = rparams["tail"] if has_rp else [None] * n
    pols = list(pol_tail) if pol_tail is not None else \
        [None if static_pol else pol] * n
    return list(zip(params["tail"], ents, rps, pols))


# ------------------------------- init ---------------------------------------

def model_init(key, cfg, elastic=None):
    period, P, _ = build_pattern(cfg, elastic)
    dt = dtype_of(cfg)
    D, V = cfg.d_model, cfg.padded_vocab
    ks = jax.random.split(key, 8)
    params = {"final_norm": norm_init(D, cfg.norm)}
    if V:
        params["embed"] = dense_init(ks[0], V, D, dt, scale=0.02)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], D, V, dt)
    layers = [block_init(jax.random.fold_in(ks[2], i), cfg.layer_kinds[i], cfg)
              for i in range(cfg.n_layers)]
    params["scan"], params["tail"] = _split_layers(layers, len(period), P)
    if cfg.family in ("encoder", "vlm") or cfg.d_frontend:
        params["in_proj"] = dense_init(ks[3], cfg.d_frontend or D, D, dt)
    if cfg.encoder is not None:
        params["encoder"] = model_init(ks[4], cfg.encoder, elastic)
        params["encoder"]["in_proj"] = dense_init(
            ks[5], cfg.encoder.d_frontend or cfg.encoder.d_model,
            cfg.encoder.d_model, dt)
    return params


def router_init(key, cfg, elastic):
    """Trainable ElastiFormer parameter tree (mirrors the layer stacking).
    ``elastic``: ElasticSpec or legacy ElasticConfig."""
    spec, _ = as_spec_policy(elastic)
    period, P, _ = build_pattern(cfg, spec)
    ks = jax.random.split(key, 4)
    layers = [block_router_init(jax.random.fold_in(ks[0], i),
                                cfg.layer_kinds[i], cfg, spec)
              for i in range(cfg.n_layers)]
    rp = {}
    rp["scan"], rp["tail"] = _split_layers(layers, len(period), P)
    if spec.vlm_routed and (
            cfg.family in ("vlm", "encdec") or cfg.n_image_tokens):
        D = cfg.d_model
        if spec.vlm_router == "mlp":
            h = spec.vlm_router_hidden or D
            rp["vlm"] = {
                "w1": dense_init(ks[1], D, h, jnp.float32),
                "b1": jnp.zeros((h,), jnp.float32),
                "w2": dense_init(ks[2], h, 1, jnp.float32),
                "b2": jnp.zeros((), jnp.float32),
            }
        else:
            rp["vlm"] = token_router_init(ks[1], D)
    if cfg.encoder is not None:
        rp["encoder"] = router_init(ks[3], cfg.encoder, spec)
    return rp


def router_param_count(rp) -> int:
    return sum(x.size for x in jax.tree.leaves(rp))


# --------------------------- context selection -------------------------------

def _vlm_logits(rp, emb):
    if "w1" in rp:  # MLP router (paper §5.3)
        h = jax.nn.gelu(emb.astype(jnp.float32) @ rp["w1"] + rp["b1"])
        return (h @ rp["w2"])[..., 0] + rp["b2"]
    return emb.astype(jnp.float32) @ rp["w"] + rp["b"]


def select_context_tokens(rp, emb, spec, pol, mode: str):
    """Paper §5.3: top-k image/context-token selection before the decoder.
    Non-causal, so top-k applies at inference too (no BCE aux needed).

    Static capacity gathers the (B, k, D) subset (smaller decoder xattn);
    traced capacity keeps full shape and returns a validity mask instead,
    so one compiled graph serves every context budget."""
    if mode == "base" or rp is None or "vlm" not in rp \
            or spec is None or not spec.vlm_routed:
        return emb, None
    B, T, D = emb.shape
    cap = pol.vlm_token_capacity if pol is not None else 1.0
    cap = gate_capacity(cap, pol.student if pol is not None else None)
    logits = _vlm_logits(rp["vlm"], emb)
    scores = jax.nn.sigmoid(logits)
    if is_static(cap):
        if cap >= 1.0:
            return emb, None
        k = max(1, int(math.ceil(cap * T)))
        idx = topk_indices(scores, k)
        sel = gather_tokens(emb, idx)
        w = jnp.take_along_axis(scores, idx, 1)
        return sel * w[..., None].astype(sel.dtype), None
    keep = topk_mask_dyn(scores, capacity_k(cap, T))
    full = bcast_to(is_full(cap), keep.ndim)
    keep = keep | full
    w = jnp.where(full, 1.0, keep * scores)
    return emb * w[..., None].astype(emb.dtype), keep


# ------------------------------ stack runner ---------------------------------

def _run_stack(params, rparams, x, *, cfg, spec, pol, mode, period, causal,
               enc_kv=None, enc_valid=None, remat=False, bucket=None):
    aux0 = RouteAux.zero()
    static_pol = _pol_static(pol)
    layered = (not static_pol) and pol.has_layer_dim
    n_period, P_ = len(period), (cfg.n_layers // len(period))
    pol_scan = pol_tail = None
    if layered:
        pol_scan, pol_tail = _split_policy(pol, cfg.n_layers, n_period, P_)

    def apply_block(ent, lp, lrp, lpol, x, enc_kv, enc_valid):
        return block_apply(
            ent.kind, lp, lrp, x, cfg=cfg, spec=spec,
            pol=(pol if static_pol else lpol), mode=mode,
            elastic_on=ent.elastic, window=ent.window, causal=causal,
            enc_kv=enc_kv, enc_valid=enc_valid, bucket=bucket,
            spmd_auto=spmd_auto)

    # §Perf H2: under a mesh, run each block shard_map-MANUAL over the batch
    # axes (model axis stays auto for GSPMD tensor parallelism). This makes
    # every batch-indexed gather/scatter in token routing / MoE dispatch
    # device-local — GSPMD cannot partition batch-indexed scatters and was
    # replicating them to the full global batch (12 GB f32 tensors + 80 GB
    # of all-reduce per layer at qwen2/train_4k scale).
    from repro.runtime import sharding as _SH
    mesh = _SH.active_mesh()
    ba = _SH.batch_axes(mesh) if mesh is not None else ()
    # skip when the batch axes are trivial (size 1: XLA rejects auto
    # collectives nested in a manual-over-one-partition region) or don't
    # divide the batch
    ba = ba if (ba and _total(mesh, ba) > 1
                and x.shape[0] % _total(mesh, ba) == 0) else ()
    # inside the manual-over-batch wrap, mesh-wide sharding constraints and
    # nested shard_map kernel wrappers are illegal — blocks skip them there
    spmd_auto = not ba

    from jax.sharding import PartitionSpec as P
    # per-request (B,) policy leaves shard with the batch; scalars and
    # size-1 per-layer leaves replicate
    B0 = x.shape[0]
    pol_sample = None if static_pol else (pol.for_layer(0) if layered else pol)
    pol_specs = P() if pol_sample is None else jax.tree.map(
        lambda v: P(ba) if (getattr(v, "ndim", 0) >= 1
                            and v.shape[0] == B0) else P(), pol_sample)

    def shard_block(f):
        if not ba:
            return f

        def body(lp, lrp, lpol, xx, ekv, evd):
            y, a = f(lp, lrp, lpol, xx, ekv, evd)
            return y, jax.tree.map(lambda s: jax.lax.pmean(s, ba), a)

        return _SH.shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(), P(), pol_specs, P(ba, None, None),
                      P() if enc_kv is None else P(ba, None, None),
                      P() if enc_valid is None else P(ba, None)),
            out_specs=(P(ba, None, None), P()),
            axis_names=frozenset(a for g in ba for a in
                                 (g if isinstance(g, tuple) else (g,))),
            check_vma=False)

    fns = []
    for ent in period:
        f = shard_block(partial(apply_block, ent))
        if remat:
            f = jax.checkpoint(f)
        fns.append(f)

    has_rp = rparams is not None and mode != "base"

    def body(carry, xs):
        x, aux = carry
        lps = xs["p"]
        lrps = xs["r"] if has_rp else [None] * len(period)
        lpols = xs.get("pol")
        for j in range(len(period)):
            lpol = lpols[j] if lpols is not None else \
                (None if static_pol else pol)
            x, a = fns[j](lps[j], lrps[j], lpol, x, enc_kv, enc_valid)
            aux = aux + a
        return (x, aux), None

    if params["scan"]:
        assert len(params["scan"]) == len(period), (
            f"param stacking period ({len(params['scan'])}) != apply-time "
            f"pattern period ({len(period)}): init and apply must use the "
            f"same elastic layers mode")
        xs = {"p": params["scan"]}
        if has_rp:
            xs["r"] = rparams["scan"]
        if layered:
            xs["pol"] = pol_scan
        (x, aux), _ = jax.lax.scan(body, (x, aux0), xs,
                                    unroll=flags.unroll())
    else:
        aux = aux0
    for i, (lp, _ent, lrp, lpol) in enumerate(_tail_plan(
            params, rparams, period, pol_tail, has_rp=has_rp,
            static_pol=static_pol, pol=pol)):
        x, a = fns[i % len(period)](lp, lrp, lpol, x, enc_kv, enc_valid)
        aux = aux + a
    return x, aux


def _embed(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _logits(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.padded_vocab != cfg.vocab_size:
        v = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(v, logits, -1e30)
    return logits


def _context(params, rparams, batch, cfg, spec, pol, mode, remat=False):
    """Image/encoder context for xattn layers -> (enc_kv, enc_valid, aux)."""
    if cfg.family == "vlm":
        emb = batch["image_embeds"].astype(dtype_of(cfg)) @ params["in_proj"]
        emb, valid = select_context_tokens(rparams, emb, spec, pol, mode) \
            if spec is not None else (emb, None)
        return emb, valid, RouteAux.zero()
    if cfg.encoder is not None:
        enc_p = params["encoder"]
        enc_rp = rparams.get("encoder") if (rparams and mode != "base") else None
        x = batch["frames"].astype(dtype_of(cfg)) @ enc_p["in_proj"]
        period, _, _ = build_pattern(cfg.encoder, spec)
        # NOTE: no `bucket` here — the caller's bucket is solved for the
        # DECODER sequence length; an undersized bucket would silently drop
        # selected encoder tokens. Traced encoder capacities take the dense
        # fallback (static ones still derive their own bucket inline).
        x, aux = _run_stack(enc_p, enc_rp, x, cfg=cfg.encoder, spec=spec,
                            pol=pol, mode=mode, period=period, causal=False,
                            remat=remat)
        x = norm_apply(enc_p["final_norm"], x, cfg.encoder.norm)
        x, valid = select_context_tokens(rparams, x, spec, pol, mode) \
            if spec is not None else (x, None)
        return x, valid, aux
    return None, None, RouteAux.zero()


def forward(params, rparams, batch, cfg, ecfg=None, mode: str = "base",
            return_hidden: bool = False, remat: bool = False, policy=None,
            bucket=None):
    """Full-sequence forward. Returns (logits | hidden | embeddings, aux).

    ``ecfg``: legacy ElasticConfig (static shim) or new ElasticSpec.
    ``policy``: optional ElasticPolicy; pass it as a jitted-function argument
    to serve every compute budget from one compilation.
    ``bucket``: static ragged capacity-bucket size for traced policies under
    ``routing_impl == "ragged"`` (see core/policy.ragged_bucket) — one
    compile per bucket, FLOPs proportional to the bucket; the
    ``routing.IDENTITY_BUCKET`` sentinel (what ragged_bucket returns for
    all-full policies) compiles the IDENTITY graph, which skips routing
    work entirely while staying bit-exact.
    ``spec.kernel_backend`` decides whether each block's hot
    math (attention softmax core, fused MLP, MoE grouped matmul) executes
    through the Pallas kernels or the jnp twins — see kernels/ops.py."""
    spec, pol = as_spec_policy(ecfg, policy)
    period, _, _ = build_pattern(cfg, spec)
    if cfg.family == "encoder":
        x = batch["embeds"].astype(dtype_of(cfg)) @ params["in_proj"]
        rp = rparams if mode != "base" else None
        x, aux = _run_stack(params, rp, x, cfg=cfg, spec=spec, pol=pol,
                            mode=mode, period=period, causal=False,
                            remat=remat, bucket=bucket)
        return norm_apply(params["final_norm"], x, cfg.norm), aux
    enc_kv, enc_valid, aux0 = _context(params, rparams, batch, cfg, spec,
                                       pol, mode, remat)
    x = _embed(params, cfg, batch["tokens"])
    rp = rparams if mode != "base" else None
    x, aux = _run_stack(params, rp, x, cfg=cfg, spec=spec, pol=pol, mode=mode,
                        period=period, causal=True, enc_kv=enc_kv,
                        enc_valid=enc_valid, remat=remat, bucket=bucket)
    aux = aux + aux0
    x = norm_apply(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, aux
    return _logits(params, cfg, x), aux


# ------------------------------ serving --------------------------------------

def cache_init(cfg, batch: int, max_seq: int, kv_dtype: str = "fp32"):
    period, P, _ = build_pattern(cfg, None)
    enc_len = cfg.n_image_tokens or cfg.encoder_seq
    caches = [block_cache_init(k, cfg, batch, max_seq, enc_len,
                               window=cfg.layer_windows[i],
                               kv_dtype=kv_dtype)
              for i, k in enumerate(cfg.layer_kinds)]
    scan, tail = _split_layers(caches, len(period), P)
    return {"scan": scan, "tail": tail}


def prefill(params, rparams, batch, cfg, ecfg=None, mode: str = "infer",
            max_cache_len: int = 0, policy=None, bucket=None):
    """Forward + cache collection. Returns (logits_last (B,V), caches).
    ``bucket``: static ragged capacity-bucket hint (train-mode prefill)."""
    spec, pol = as_spec_policy(ecfg, policy)
    period, P, _ = build_pattern(cfg, spec)
    enc_kv, enc_valid, _ = _context(params, rparams, batch, cfg, spec, pol,
                                    mode)
    x = _embed(params, cfg, batch["tokens"])
    S = x.shape[1]
    L = max_cache_len or S
    has_rp = rparams is not None and mode != "base"
    static_pol = _pol_static(pol)
    layered = (not static_pol) and pol.has_layer_dim
    pol_scan = pol_tail = None
    if layered:
        pol_scan, pol_tail = _split_policy(pol, cfg.n_layers, len(period), P)

    def apply_block(ent, lp, lrp, lpol, x):
        return block_apply(
            ent.kind, lp, lrp, x, cfg=cfg, spec=spec,
            pol=(pol if static_pol else lpol), mode=mode,
            elastic_on=ent.elastic, window=ent.window, causal=True,
            enc_kv=enc_kv, enc_valid=enc_valid, collect_cache=True,
            max_cache_len=L, bucket=bucket)

    def body(x, xs):
        lps = xs["p"]
        lrps = xs["r"] if has_rp else [None] * len(period)
        lpols = xs.get("pol")
        ncs = []
        for j, ent in enumerate(period):
            lpol = lpols[j] if lpols is not None else \
                (None if static_pol else pol)
            x, _, nc = apply_block(ent, lps[j], lrps[j], lpol, x)
            ncs.append(nc)
        return x, ncs

    if params["scan"]:
        xs = {"p": params["scan"]}
        if has_rp:
            xs["r"] = rparams["scan"]
        if layered:
            xs["pol"] = pol_scan
        x, scan_caches = jax.lax.scan(body, x, xs, unroll=flags.unroll())
    else:
        scan_caches = []
    tail_caches = []
    for lp, ent, lrp, lpol in _tail_plan(
            params, rparams, period, pol_tail, has_rp=has_rp,
            static_pol=static_pol, pol=pol):
        x, _, nc = apply_block(ent, lp, lrp, lpol, x)
        tail_caches.append(nc)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = _logits(params, cfg, x[:, -1])
    return logits, {"scan": scan_caches, "tail": tail_caches}


def cache_insert(caches, row_caches, slot, cfg=None):
    """Splice a single-request cache tree (batch dim 1, collected by
    ``prefill`` at the slot array's ``max_cache_len``) into batch row
    ``slot`` of a live slot-array cache. ``slot`` may be traced, so ONE
    compiled insert serves every slot index. When ``cfg`` is given and a
    mesh is active, the spliced tree is pinned back to the serving cache
    shardings (kv-heads over `model`, slots over data) — the row update is
    a batch-dim dynamic_update_slice, which GSPMD would otherwise resolve
    by replicating the whole live cache."""
    out = {
        "scan": [cache_row_insert(f, r, slot, batch_axis=1)
                 for f, r in zip(caches["scan"], row_caches["scan"])],
        "tail": [cache_row_insert(f, r, slot, batch_axis=0)
                 for f, r in zip(caches["tail"], row_caches["tail"])],
    }
    if cfg is not None:
        from repro.runtime import sharding as SH
        out = SH.constrain_cache_tree(out, cfg)
    return out


def prefill_into_slot(params, rparams, batch, caches, slot, cfg, ecfg=None,
                      mode: str = "infer", max_cache_len: int = 0,
                      policy=None, live_policy=None, bucket=None):
    """Admission path for continuous batching: prefill ONE request (batch
    leaves carry a leading dim of 1) and splice its caches — and its solved
    per-request policy row — into row ``slot`` of the live slot arrays.

    Everything downstream of the (static) prompt-length bucket is traced:
    slot index, policy rows, and the live (B,)-leaf ``live_policy`` ride
    through one compiled graph, so admissions never recompile.
    Returns (last-token logits (1, V), caches, live_policy)."""
    logits, row = prefill(params, rparams, batch, cfg, ecfg, mode=mode,
                          max_cache_len=max_cache_len, policy=policy,
                          bucket=bucket)
    caches = cache_insert(caches, row, slot, cfg)
    if live_policy is not None and policy is not None:
        live_policy = live_policy.set_row(slot, policy)
    return logits, caches, live_policy


def decode_step(params, rparams, token, caches, t, cfg, ecfg=None,
                mode: str = "infer", policy=None, table=None, trash=None):
    """One decode step. token: (B,1) i32; t: scalar i32 position, or (B,)
    i32 per-row positions (continuous batching: each serving slot decodes
    at its own offset inside the same compiled step).
    Returns (logits (B,V), new caches). ``policy`` is traced: one compiled
    decode step serves every (mixed-per-request) budget.

    ``table``/``trash``: paged-KV mode — the (B, P) page-table rows and
    (B,) per-slot trash-page ids. One table serves EVERY layer: pages are
    allocated per slot once and each layer's pool slice is indexed with the
    same page ids, so the table rides the scan as a loop-invariant capture
    (never stacked into xs)."""
    spec, pol = as_spec_policy(ecfg, policy)
    period, P, _ = build_pattern(cfg, spec)
    x = _embed(params, cfg, token)
    has_rp = rparams is not None and mode != "base"
    static_pol = _pol_static(pol)
    layered = (not static_pol) and pol.has_layer_dim
    pol_scan = pol_tail = None
    if layered:
        pol_scan, pol_tail = _split_policy(pol, cfg.n_layers, len(period), P)

    def body(x, xs):
        lps, lcs = xs["p"], xs["c"]
        lrps = xs["r"] if has_rp else [None] * len(period)
        lpols = xs.get("pol")
        ncs = []
        for j, ent in enumerate(period):
            lpol = lpols[j] if lpols is not None else \
                (None if static_pol else pol)
            x, nc = block_decode(
                ent.kind, lps[j], lrps[j], x, lcs[j], t, cfg=cfg, spec=spec,
                pol=(pol if static_pol else lpol), mode=mode,
                elastic_on=ent.elastic, window=ent.window,
                table=table, trash=trash)
            ncs.append(nc)
        return x, ncs

    if params["scan"]:
        xs = {"p": params["scan"], "c": caches["scan"]}
        if has_rp:
            xs["r"] = rparams["scan"]
        if layered:
            xs["pol"] = pol_scan
        x, new_scan = jax.lax.scan(body, x, xs, unroll=flags.unroll())
    else:
        new_scan = []
    new_tail = []
    for i, (lp, ent, lrp, lpol) in enumerate(_tail_plan(
            params, rparams, period, pol_tail, has_rp=has_rp,
            static_pol=static_pol, pol=pol)):
        x, nc = block_decode(ent.kind, lp, lrp, x, caches["tail"][i], t,
                             cfg=cfg, spec=spec,
                             pol=(pol if static_pol else lpol), mode=mode,
                             elastic_on=ent.elastic, window=ent.window,
                             table=table, trash=trash)
        new_tail.append(nc)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = _logits(params, cfg, x[:, -1])
    return logits, {"scan": new_scan, "tail": new_tail}


# --------------------------- paged serving -----------------------------------

def paged_cache_init(cfg, n_pages: int, page_size: int,
                     kv_dtype: str = "fp32"):
    """Paged twin of ``cache_init``: per-layer slices of the GLOBAL page
    pool, stacked into the same scan/tail pattern tree (scan leaves gain a
    leading period dim). Attention-only — validated per layer kind."""
    period, P, _ = build_pattern(cfg, None)
    caches = [block_paged_cache_init(k, cfg, n_pages, page_size,
                                     kv_dtype=kv_dtype)
              for k in cfg.layer_kinds]
    scan, tail = _split_layers(caches, len(period), P)
    return {"scan": scan, "tail": tail}


def prefill_chunk_step(params, rparams, tokens, caches, write_page, table_row,
                       pos0, plen, cfg, ecfg=None, mode: str = "infer",
                       policy=None):
    """One CHUNK of a paged prefill through the whole stack (the decode-
    shaped prefill graph): tokens is (1, C) i32 with C == page_size,
    zero-padded past ``plen``; ``write_page`` (scalar i32) is the pool page
    this chunk's K/V land in at EVERY layer (each layer's pool slice shares
    the id — same invariant as ``decode_step``'s table); ``table_row`` (P,)
    i32 is the slot's page-table row (entries <= this chunk present);
    ``pos0``/``plen`` are traced scalars. Chaining ceil(plen / C) calls of
    this ONE compiled graph replaces every per-length prefill bucket.
    Returns (logits (1, V) at the chunk's LAST REAL position — only the
    final chunk's logits feed sampling — and the new caches)."""
    spec, pol = as_spec_policy(ecfg, policy)
    period, P_, _ = build_pattern(cfg, spec)
    x = _embed(params, cfg, tokens)
    has_rp = rparams is not None and mode != "base"
    static_pol = _pol_static(pol)
    layered = (not static_pol) and pol.has_layer_dim
    pol_scan = pol_tail = None
    if layered:
        pol_scan, pol_tail = _split_policy(pol, cfg.n_layers, len(period), P_)

    def body(x, xs):
        lps, lcs = xs["p"], xs["c"]
        lrps = xs["r"] if has_rp else [None] * len(period)
        lpols = xs.get("pol")
        ncs = []
        for j, ent in enumerate(period):
            lpol = lpols[j] if lpols is not None else \
                (None if static_pol else pol)
            x, nc = block_chunk(
                ent.kind, lps[j], lrps[j], x, lcs[j], write_page, table_row,
                pos0, plen, cfg=cfg, spec=spec,
                pol=(pol if static_pol else lpol), mode=mode,
                elastic_on=ent.elastic)
            ncs.append(nc)
        return x, ncs

    if params["scan"]:
        xs = {"p": params["scan"], "c": caches["scan"]}
        if has_rp:
            xs["r"] = rparams["scan"]
        if layered:
            xs["pol"] = pol_scan
        x, new_scan = jax.lax.scan(body, x, xs, unroll=flags.unroll())
    else:
        new_scan = []
    new_tail = []
    for i, (lp, ent, lrp, lpol) in enumerate(_tail_plan(
            params, rparams, period, pol_tail, has_rp=has_rp,
            static_pol=static_pol, pol=pol)):
        x, nc = block_chunk(ent.kind, lp, lrp, x, caches["tail"][i],
                            write_page, table_row, pos0, plen, cfg=cfg,
                            spec=spec, pol=(pol if static_pol else lpol),
                            mode=mode, elastic_on=ent.elastic)
        new_tail.append(nc)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    lidx = jnp.clip(jnp.asarray(plen, jnp.int32) - 1
                    - jnp.asarray(pos0, jnp.int32), 0, x.shape[1] - 1)
    h_last = jax.lax.dynamic_index_in_dim(x, lidx, axis=1, keepdims=False)
    logits = _logits(params, cfg, h_last)
    return logits, {"scan": new_scan, "tail": new_tail}


# ------------------------------ input specs ----------------------------------

def batch_specs(cfg, seq_len: int, global_batch: int, kind: str):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    i32 = jnp.int32
    B, S = global_batch, seq_len
    if kind == "decode":
        specs = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    elif cfg.family == "encoder":
        specs = {"embeds": jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens or S, cfg.d_frontend or cfg.d_model),
            jnp.float32)}
        return specs
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.family == "vlm" and kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_frontend), jnp.float32)
    if cfg.encoder is not None and kind != "decode":
        e = cfg.encoder
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, e.encoder_seq, e.d_frontend or e.d_model), jnp.float32)
    return specs


def cache_specs(cfg, batch: int, max_seq: int, kv_dtype: str = "fp32"):
    return jax.eval_shape(lambda: cache_init(cfg, batch, max_seq,
                                             kv_dtype=kv_dtype))
