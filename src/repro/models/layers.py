"""Shared neural-net primitives: norms, activations, inits, RoPE.

Pure JAX, params as plain dict pytrees. All inits take an explicit PRNG key
and return dicts of jnp arrays in cfg.dtype (norms/routers in f32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import quant


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def norm_init(d: int, kind: str):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def act_fn(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    if name in ("geglu",):
        return lambda x: jax.nn.gelu(x, approximate=True)
    return lambda x: jax.nn.gelu(x, approximate=True)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ----------------------------- RoPE ---------------------------------------

def rope_tables(positions, d_head: int, theta: float, dtype=jnp.float32):
    """cos/sin tables for given integer positions. positions: (...,)"""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def rope_apply(x, cos, sin):
    """x: (..., n_heads, d_head); cos/sin broadcastable to (..., 1, d_head/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------- dense MLP ---------------------------------------

def mlp_init(key, cfg):
    D, F, dt = cfg.d_model, cfg.d_ff, dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], D, F, dt), "wo": dense_init(ks[1], F, D, dt)}
    if is_gated(cfg.act):
        p["wg"] = dense_init(ks[2], D, F, dt)
    return p


def mlp_apply(p, x, act: str):
    h = x @ quant.maybe_dequant(p, "wi", x.dtype)
    if is_gated(act):
        h = act_fn(act)(x @ quant.maybe_dequant(p, "wg", x.dtype)) * h
    else:
        h = act_fn(act)(h)
    return (h @ quant.maybe_dequant(p, "wo", x.dtype)).astype(x.dtype)
