"""ElasticSpec / ElasticPolicy: one compiled model, many compute budgets.

The elasticity API is split into two objects:

* ``ElasticSpec`` — *static* description of what elastic machinery EXISTS:
  which routers are attached, how many moefied experts, LoRA rank, which
  layers participate. Everything here shapes parameter trees and HLO, so it
  is a frozen, hashable dataclass that is baked into the trace (like
  ``ModelConfig``).

* ``ElasticPolicy`` — *runtime* knobs: token capacities, head/expert top-k,
  the decode threshold theta, and a teacher/student flag. It is a JAX pytree
  passed as a (traced) argument to ``forward`` / ``prefill`` / ``decode_step``
  / ``make_train_step``'s step function, so ONE compilation serves every
  budget: the fig5 capacity sweep, per-request budgets in ``ServingEngine``,
  and capacity annealing during distillation all run with zero re-jits.

Policy leaves may be:
  * python floats/ints — trace-time constants (the legacy ``ElasticConfig``
    path; top-k routing executes on a ragged capacity bucket by default, so
    budgets sharing a bucket share a compile — at most
    ``routing.RAGGED_N_BUCKETS`` graphs — with FLOPs proportional to the
    bucket);
  * jnp scalars ``()`` — traced, one compile for all budgets;
  * ``(B,)`` arrays — per-request budgets inside one batched step;
  * ``(L, 1)`` / ``(L, B)`` arrays — per-layer schedules (L = n_layers).

Budget semantics: any capacity ``>= 1`` (or top-k ``>= n``) short-circuits
to the exact frozen-teacher computation (router weights forced to 1), so
``ElasticPolicy.uniform(1.0)`` reproduces the teacher bit-for-bit — the
paper's losslessness property, now available at runtime.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

# a top-k value meaning "all submodules" when the real count is unknown
FULL_TOPK = 1 << 30

Scalar = Union[float, int, jnp.ndarray]


# ------------------------------- spec ----------------------------------------

@dataclass(frozen=True)
class ElasticSpec:
    """What elastic machinery exists (shapes params + HLO; trace-static)."""
    mlp_token_routed: bool = True      # token router around the MLP
    mha_token_routed: bool = False     # token router around MHA/mixer
    mha_head_routed: bool = False      # head router over attention heads
    # Depth router: per-token whole-layer skip (docs/elastic_policy.md).
    # Selected tokens run the block (attention AND MLP/MoE, one shared
    # RoutingPlan); unselected tokens ride the residual untouched and
    # write no KV at that layer. Composes multiplicatively with the
    # token/head/expert knobs in the roofline solver.
    depth_routed: bool = False
    mlp_n_experts: Optional[int] = None  # moefy dense MLP into M experts
    expert_routed: bool = False        # elastic expert router (moefied/native)
    vlm_routed: bool = False           # image/context token selection
    vlm_router: str = "linear"         # linear | mlp
    vlm_router_hidden: int = 0
    lora_rank: int = 0                 # LoRA on q/v projections
    layers: str = "all"                # all | even  (paper §5.2)
    router_dtype: str = "float32"
    distill_loss: str = "topk_kl"      # topk_kl|fwd_kl|rev_kl|cosine
    distill_topk: int = 50
    distill_temp: float = 1.0
    lambda_load: float = 1.0
    lambda_topk: float = 1.0
    routing_impl: str = "ragged"       # ragged | gather | dense_mask
    # How the model hot path EXECUTES: "pallas" = real TPU kernels,
    # "interpret" = pallas interpreter (CPU kernel verification), "ref" =
    # jnp references/twins (fast CPU path), "auto" = pallas on TPU, ref
    # elsewhere. Static: changing it recompiles (it swaps the HLO).
    kernel_backend: str = "auto"       # auto | pallas | interpret | ref
    # Serving storage widths (docs/quantization.md). "fp32" = native config
    # dtype (no quantization); "int8" stores symmetric int8 with f32 scale
    # sibling leaves (KV: per (token, kv-head); weights: per output
    # channel), dequantized in-register inside the Pallas kernels. Static:
    # they shape the cache pytree and the HLO, never traced.
    kv_dtype: str = "fp32"             # fp32 | bf16 | int8
    weight_dtype: str = "fp32"         # fp32 | bf16 | int8

    def applies_to_layer(self, idx: int) -> bool:
        return self.layers == "all" or idx % 2 == 0


# ------------------------------- policy --------------------------------------

def _leaf(v, static: bool):
    if static:
        return v
    return jnp.asarray(v, jnp.float32)


@jax.tree_util.register_dataclass
@dataclass
class ElasticPolicy:
    """Runtime compute budget — a pytree of (possibly traced) scalars.

    Capacities are fractions in (0, 1]; top-k values are absolute counts
    (``FULL_TOPK`` means "all"). ``theta`` is the decode-time threshold on
    each token router's sigmoid (paper §B.1 uses 0.5). ``student <= 0``
    disables all routing (exact teacher), per batch row when shaped (B,).
    """
    mlp_token_capacity: Scalar = 1.0
    mha_token_capacity: Scalar = 1.0
    depth_capacity: Scalar = 1.0
    mha_head_topk: Scalar = FULL_TOPK
    mlp_expert_topk: Scalar = FULL_TOPK
    vlm_token_capacity: Scalar = 1.0
    theta: Scalar = 0.5
    student: Scalar = 1.0

    # ---- constructors ----
    @classmethod
    def uniform(cls, budget: float, *, n_heads: Optional[int] = None,
                n_experts: Optional[int] = None, theta: float = 0.5,
                static: bool = False) -> "ElasticPolicy":
        """Same fractional budget on every knob. Head/expert top-k are
        resolved when the counts are given, else left at "all"."""
        topk = lambda n: (max(1, min(n, int(math.ceil(budget * n - 1e-9))))
                          if n else FULL_TOPK)
        return cls(
            mlp_token_capacity=_leaf(budget, static),
            mha_token_capacity=_leaf(budget, static),
            depth_capacity=_leaf(budget, static),
            mha_head_topk=_leaf(topk(n_heads), static),
            mlp_expert_topk=_leaf(topk(n_experts), static),
            vlm_token_capacity=_leaf(budget, static),
            theta=_leaf(theta, static),
            student=_leaf(1.0, static),
        )

    @classmethod
    def teacher(cls, *, static: bool = False) -> "ElasticPolicy":
        """Exact frozen-teacher pass-through (routers bypassed)."""
        p = cls.uniform(1.0, static=static)
        return dataclasses.replace(p, student=_leaf(0.0, static))

    @classmethod
    def stack(cls, policies: Sequence["ElasticPolicy"]) -> "ElasticPolicy":
        """Batch per-request policies into one: every leaf becomes (B,)."""
        return jax.tree.map(
            lambda *ls: jnp.stack([jnp.asarray(l, jnp.float32) for l in ls]),
            *policies)

    # ---- per-request (B,) slot rows ----
    def broadcast_rows(self, batch: int) -> "ElasticPolicy":
        """Materialize every leaf as a (B,) float32 array — the live slot
        policy a continuous-batching engine splices admissions into."""
        return jax.tree.map(
            lambda v: jnp.broadcast_to(
                jnp.asarray(v, jnp.float32), (batch,)) + 0.0, self)

    def clamp_capacities(self, floor: float) -> "ElasticPolicy":
        """Lower-bound every capacity fraction at ``floor`` (in (0, 1]).
        The SLO controller's degradation stages go through this so a
        misconfigured or runaway controller can never drive a live row
        to a vanishing capacity; top-k leaves already floor at 1 in the
        roofline solver and ``theta``/``student`` are not budgets."""
        f = jnp.float32(floor)
        clamp = lambda v: jnp.maximum(jnp.asarray(v, jnp.float32), f)
        return self.replace(
            mlp_token_capacity=clamp(self.mlp_token_capacity),
            mha_token_capacity=clamp(self.mha_token_capacity),
            depth_capacity=clamp(self.depth_capacity),
            vlm_token_capacity=clamp(self.vlm_token_capacity))

    def set_row(self, i, row: "ElasticPolicy", *,
                floor: Optional[float] = None) -> "ElasticPolicy":
        """Splice ``row`` (scalar leaves) into batch row ``i`` of this
        (B,)-leaf policy. ``i`` may be traced (dynamic_update_index), so
        admitting a request into a serving slot NEVER recompiles: the row
        update is part of the one compiled admission graph. ``floor``
        (optional) bounds the spliced row's capacities from below via
        ``clamp_capacities`` — the degradation path's safety rail."""
        if floor is not None:
            row = row.clamp_capacities(floor)
        def upd(live, r):
            live = jnp.asarray(live, jnp.float32)
            return jax.lax.dynamic_update_index_in_dim(
                live, jnp.asarray(r, jnp.float32), i, axis=0)
        return jax.tree.map(upd, self, row)

    # ---- per-layer schedules ----
    @property
    def has_layer_dim(self) -> bool:
        return any(getattr(l, "ndim", 0) >= 2 for l in jax.tree.leaves(self))

    def for_layer(self, i: int) -> "ElasticPolicy":
        """Select layer i from any (L, ...) leaf; scalars/(B,) pass through."""
        def sel(v):
            if getattr(v, "ndim", 0) >= 2:
                return v[i % v.shape[0]]
            return v
        return jax.tree.map(sel, self)

    def replace(self, **kw) -> "ElasticPolicy":
        return dataclasses.replace(self, **kw)


# ------------------------ legacy ElasticConfig shim ---------------------------

def spec_from_config(ecfg) -> ElasticSpec:
    """Map a legacy ``ElasticConfig`` onto the static half of the new API."""
    return ElasticSpec(
        mlp_token_routed=ecfg.mlp_token_capacity is not None,
        mha_token_routed=ecfg.mha_token_capacity is not None,
        mha_head_routed=ecfg.mha_head_topk is not None,
        depth_routed=(getattr(ecfg, "depth_routed", False)
                      or getattr(ecfg, "depth_capacity", None) is not None),
        mlp_n_experts=ecfg.mlp_n_experts,
        expert_routed=bool(ecfg.mlp_expert_topk),
        vlm_routed=ecfg.vlm_token_capacity is not None,
        vlm_router=ecfg.vlm_router,
        vlm_router_hidden=ecfg.vlm_router_hidden,
        lora_rank=ecfg.lora_rank,
        layers=ecfg.layers,
        router_dtype=ecfg.router_dtype,
        distill_loss=ecfg.distill_loss,
        distill_topk=ecfg.distill_topk,
        distill_temp=ecfg.distill_temp,
        lambda_load=ecfg.lambda_load,
        lambda_topk=ecfg.lambda_topk,
        routing_impl=ecfg.routing_impl,
        kernel_backend=getattr(ecfg, "kernel_backend", "auto"),
        kv_dtype=getattr(ecfg, "kv_dtype", "fp32"),
        weight_dtype=getattr(ecfg, "weight_dtype", "fp32"),
    )


def policy_from_config(ecfg) -> ElasticPolicy:
    """Runtime half of the shim. Values stay python floats/ints, so when the
    result is closed over (not passed as a jit argument) the original static
    top-k gather routing — and its per-budget recompile — is preserved."""
    return ElasticPolicy(
        mlp_token_capacity=(1.0 if ecfg.mlp_token_capacity is None
                            else float(ecfg.mlp_token_capacity)),
        mha_token_capacity=(1.0 if ecfg.mha_token_capacity is None
                            else float(ecfg.mha_token_capacity)),
        depth_capacity=(1.0 if getattr(ecfg, "depth_capacity", None) is None
                        else float(ecfg.depth_capacity)),
        mha_head_topk=(FULL_TOPK if ecfg.mha_head_topk is None
                       else int(ecfg.mha_head_topk)),
        mlp_expert_topk=(FULL_TOPK if not ecfg.mlp_expert_topk
                         else int(ecfg.mlp_expert_topk)),
        vlm_token_capacity=(1.0 if ecfg.vlm_token_capacity is None
                            else float(ecfg.vlm_token_capacity)),
        theta=0.5,
        student=1.0,
    )


def as_spec_policy(elastic, policy: Optional[ElasticPolicy] = None):
    """Coerce ``ElasticConfig | ElasticSpec | None`` (+ optional policy)
    into a (spec, policy) pair. The single entry point every model/training/
    serving layer funnels through; ``ElasticConfig`` is deprecated but keeps
    working unchanged through this shim."""
    if elastic is None:
        return None, None
    if isinstance(elastic, ElasticSpec):
        return elastic, (policy if policy is not None
                         else ElasticPolicy.uniform(1.0, static=True))
    # legacy ElasticConfig (duck-typed to avoid importing configs here)
    spec = spec_from_config(elastic)
    return spec, (policy if policy is not None else policy_from_config(elastic))


# ----------------------- ragged bucket resolution ----------------------------

def ragged_bucket(policy: Optional[ElasticPolicy], s: int,
                  *, n_buckets: Optional[int] = None,
                  align: Optional[int] = None,
                  spec: Optional[ElasticSpec] = None) -> Optional[int]:
    """Host-side bucket solver (sits next to the roofline budget solver):
    the smallest static capacity bucket covering the policy's token
    capacities at sequence length ``s``. This is the value to thread — as a
    STATIC argument — into ``forward`` / ``prefill`` / train steps when the
    policy itself is traced: each distinct bucket is one compile, and there
    are at most ``routing.RAGGED_N_BUCKETS`` of them per sequence length
    (plus the identity graph).

    Returns:
      * an int ``b < s`` — the covering capacity bucket;
      * ``routing.IDENTITY_BUCKET`` — the IDENTITY fast path: every row of
        the policy is at full budget (capacity >= 1) or in teacher mode,
        so the compiled graph skips partition + gather + scatter entirely
        and runs the bit-exact teacher math (this is what makes budget-1.0
        rows as fast as the unrouted model — the token routers still emit
        their aux losses). A sentinel, not a size, so it can never collide
        with a real bucket at a different sequence length;
      * ``None`` — no static plan possible: the policy is abstract (tracers
        — the budget is genuinely unknown at trace time), rows MIX full and
        partial budgets, or the covering bucket would be the full sequence
        without every row being full. Dense rank-masked fallback.

    ``spec`` (optional) refines the capacity model: without it the solver
    conservatively assumes both token knobs are live and ignores depth
    (the pre-depth behaviour, still correct for solver-produced policies
    whose leaves are all equal). With a spec, non-routed token knobs are
    dropped and ``depth_capacity`` composes multiplicatively — the block
    plan's capacity is ``depth * max(token caps)``, so depth 0.5 at token
    1.0 still lands on a half-size bucket instead of the identity graph."""
    from repro.core import routing as R
    if policy is None:
        return None
    caps = [policy.mha_token_capacity, policy.mlp_token_capacity,
            policy.student, policy.depth_capacity]
    vals = []
    for c in caps:
        if isinstance(c, jax.core.Tracer):
            return None
        vals.append(jnp.asarray(c, jnp.float32))
    # effective per-row capacity: teacher rows (student <= 0) force 1.0
    if spec is not None:
        one = jnp.float32(1.0)
        cap_rows = jnp.maximum(
            vals[0] if spec.mha_token_routed else one,
            vals[1] if spec.mlp_token_routed else one)
        if spec.depth_routed:
            cap_rows = cap_rows * jnp.minimum(vals[3], 1.0)
    else:
        cap_rows = jnp.maximum(vals[0], vals[1])
    eff = jnp.where(vals[2] <= 0.0, 1.0, cap_rows)
    if float(jnp.min(eff)) >= 1.0:
        return R.IDENTITY_BUCKET                # identity: all rows full
    if float(jnp.max(eff)) >= 1.0:
        return None                             # mixed full/partial rows
    kw = {}
    if n_buckets is not None:
        kw["n_buckets"] = n_buckets
    if align is not None:
        kw["align"] = align
    cap = float(jnp.max(eff))
    b = R.bucket_for(R.capacity_k(cap, s, mxu=True), s, **kw)
    return b if b < s else None


# ------------------------- budget -> capacity solver --------------------------

def stack_flops_per_token(cfg, spec: ElasticSpec, *, ctx: int = 1024):
    """Analytic per-token forward FLOPs, split into (fixed, routed) parts.

    Same analytic model as ``launch/dryrun.model_flops`` (parameter matmuls
    at 2 FLOPs/MAC plus the quadratic attention term at average context
    ``ctx``), but decomposed per elastic knob so a budget can be solved for.

    ``routed`` maps knob name -> FLOPs that scale with that knob's fraction.
    Token capacities and head/expert fractions COMPOSE multiplicatively on
    the module they share (handled in ``_active_fraction``).
    """
    D, F = cfg.d_model, cfg.d_ff
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    fixed = 2 * cfg.padded_vocab * D * (1 if cfg.tie_embeddings else 2)
    attn_head = attn_kv = mlp = mixer = 0.0
    n_gate = 3 if cfg.act in ("swiglu", "geglu") else 2
    for i, kind in enumerate(cfg.layer_kinds):
        elastic_l = spec.applies_to_layer(i)
        if kind in ("attn", "xattn"):
            w = cfg.layer_windows[i]
            c = min(ctx, w) if (w and w > 0) else ctx
            qo = 2 * 2 * D * H * Dh          # q + o projections
            kv = 2 * 2 * D * K * Dh          # k + v projections
            quad = 2 * 2 * c * H * Dh        # QK^T + PV
            if kind == "xattn":
                qo, kv, quad = 2 * qo, 2 * kv, 2 * quad
            if elastic_l:
                attn_head += qo + quad
                attn_kv += kv
            else:
                fixed += qo + kv + quad
        elif kind == "ssm" and cfg.ssm_state:
            di = cfg.d_inner
            c_ssm = 2 * D * (2 * di + 2 * cfg.ssm_state) + 2 * di * D
            (mixer, fixed) = (mixer + c_ssm, fixed) if elastic_l \
                else (mixer, fixed + c_ssm)
        elif kind == "rglru" and cfg.lru_width:
            w = cfg.lru_width
            c_lru = 2 * D * 2 * w + 2 * w * D + 2 * 2 * w * w
            (mixer, fixed) = (mixer + c_lru, fixed) if elastic_l \
                else (mixer, fixed + c_lru)
        if kind != "ssm":
            if cfg.moe is not None:
                m = cfg.moe
                c_mlp = m.top_k * n_gate * 2 * D * m.d_expert
                if m.n_shared_experts:
                    fixed += n_gate * 2 * D * m.d_shared
            else:
                c_mlp = n_gate * 2 * D * F
            if elastic_l:
                mlp += c_mlp
            else:
                fixed += c_mlp
    routed = {"attn_head": attn_head, "attn_kv": attn_kv,
              "mlp": mlp, "mixer": mixer}
    return fixed, routed


def _active_fraction(cfg, spec: ElasticSpec, s: float, *, ctx: int) -> float:
    """FLOP fraction of the full model when every enabled knob is set to
    fraction ``s`` (top-k values rounded to real integer counts)."""
    fixed, routed = stack_flops_per_token(cfg, spec, ctx=ctx)
    # Depth skip removes the WHOLE layer for unselected tokens, so its
    # fraction multiplies every routed term (attention, KV writes, mixer,
    # MLP) — depth 0.75 x token 0.75 composes to ~0.56 of routed FLOPs.
    frac_depth = s if spec.depth_routed else 1.0
    cap_tok_mha = (s if spec.mha_token_routed else 1.0) * frac_depth
    cap_tok_mlp = (s if spec.mlp_token_routed else 1.0) * frac_depth
    frac_head = 1.0
    if spec.mha_head_routed:
        frac_head = max(1, math.ceil(s * cfg.n_heads - 1e-9)) / cfg.n_heads
    frac_exp = 1.0
    if spec.expert_routed:
        n_e = cfg.moe.n_experts if cfg.moe is not None else spec.mlp_n_experts
        if n_e:
            frac_exp = max(1, math.ceil(s * n_e - 1e-9)) / n_e
    active = (fixed
              + routed["attn_head"] * cap_tok_mha * frac_head
              + routed["attn_kv"] * cap_tok_mha
              + routed["mixer"] * cap_tok_mha
              + routed["mlp"] * cap_tok_mlp * frac_exp)
    total = fixed + sum(routed.values())
    return active / max(total, 1.0)


def solve_budget(cfg, spec: ElasticSpec, budget: float, *, ctx: int = 1024,
                 theta: float = 0.5, static: bool = False,
                 iters: int = 40) -> ElasticPolicy:
    """Bisect the shared knob fraction ``s`` so the model's active-FLOP
    fraction (roofline cost model) hits ``budget``; returns the policy.

    budget >= the model's fixed-compute floor collapses gracefully: at
    budget >= 1 the policy is exactly the lossless teacher."""
    if budget >= 1.0:
        return ElasticPolicy.uniform(1.0, theta=theta, static=static)
    lo, hi = 1e-3, 1.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if _active_fraction(cfg, spec, mid, ctx=ctx) > budget:
            hi = mid
        else:
            lo = mid
    s = 0.5 * (lo + hi)
    n_e = cfg.moe.n_experts if cfg.moe is not None else spec.mlp_n_experts
    return ElasticPolicy.uniform(
        s, n_heads=cfg.n_heads if spec.mha_head_routed else None,
        n_experts=n_e if spec.expert_routed else None,
        theta=theta, static=static)


# ------------------------------ schedules ------------------------------------

def capacity_anneal(start: float, end: float, steps: int):
    """Linear budget schedule for distillation: start at (near-)teacher
    capacity, anneal down to the target budget. Returns step -> budget."""
    def at(step: int) -> float:
        if steps <= 0:
            return end
        t = min(1.0, max(0.0, step / steps))
        return start + (end - start) * t
    return at
