"""LoRA adapters for q/v projections (paper §5.1, Fig. 6: rank >= 1 rescues
MHA input-subset selection). B is zero-initialized so the adapter starts as
the identity; trained with the same self-distillation objective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lora_init(key, d_in: int, d_out: int, rank: int):
    ka, _ = jax.random.split(key)
    return {
        "a": jax.random.normal(ka, (d_in, rank), jnp.float32) / math.sqrt(d_in),
        "b": jnp.zeros((rank, d_out), jnp.float32),
    }


def lora_apply(lp, x, scale: float = 1.0):
    """Additive low-rank delta: x @ A @ B * scale, computed in f32."""
    h = x.astype(jnp.float32) @ lp["a"] @ lp["b"]
    return (h * scale).astype(x.dtype)
