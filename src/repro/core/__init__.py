"""ElastiFormer core: spec/policy, routing (Alg. 1/2), moefy, LoRA,
distillation."""
from repro.core.policy import (ElasticPolicy, ElasticSpec, as_spec_policy,
                               capacity_anneal, policy_from_config,
                               solve_budget, spec_from_config)
from repro.core.routing import (RouteAux, bce_topk_loss, param_route_weights,
                                param_router_init, route_tokens,
                                token_logits, token_router_init, topk_indices,
                                topk_mask, topk_mask_dyn)
from repro.core.moefy import moefy_mlp, unmoefy_mlp
from repro.core.lora import lora_apply, lora_init
from repro.core.distill import (cosine_distance, distill_loss, kl_divergence,
                                topk_kl, topk_kl_from_gathered)

__all__ = [
    "ElasticPolicy", "ElasticSpec", "as_spec_policy", "capacity_anneal",
    "policy_from_config", "solve_budget", "spec_from_config",
    "RouteAux", "bce_topk_loss", "param_route_weights", "param_router_init",
    "route_tokens", "token_logits", "token_router_init", "topk_indices",
    "topk_mask", "topk_mask_dyn", "moefy_mlp", "unmoefy_mlp", "lora_apply",
    "lora_init", "cosine_distance", "distill_loss", "kl_divergence",
    "topk_kl", "topk_kl_from_gathered",
]
