"""Lossless dense-MLP -> MoE block decomposition (paper §4.1).

    y = W2 sigma(W1 x) = [W2,1 W2,2] sigma([W1,1; W1,2] x)

Row-split the up (and gate) projections, column-split the down projection.
With all experts selected at uniform weight 1 (the M*softmax normalization),
the moefied module is bit-identical in f32 to the dense module.
"""
from __future__ import annotations

import jax.numpy as jnp


def moefy_mlp(params: dict, n_experts: int) -> dict:
    """params: {'wi': (D,F), 'wo': (F,D), optional 'wg': (D,F)} ->
    {'wi': (E,D,F/E), 'wo': (E,F/E,D), optional 'wg': (E,D,F/E)}."""
    wi, wo = params["wi"], params["wo"]
    d, f = wi.shape
    assert f % n_experts == 0, f"d_ff={f} not divisible by {n_experts} experts"
    fe = f // n_experts
    out = {
        "wi": jnp.transpose(wi.reshape(d, n_experts, fe), (1, 0, 2)),
        "wo": wo.reshape(n_experts, fe, d),
    }
    if "wg" in params:
        out["wg"] = jnp.transpose(params["wg"].reshape(d, n_experts, fe), (1, 0, 2))
    return out


def unmoefy_mlp(params: dict) -> dict:
    """Inverse of moefy_mlp (used by tests to assert losslessness)."""
    wi = params["wi"]
    e, d, fe = wi.shape
    out = {
        "wi": jnp.transpose(wi, (1, 0, 2)).reshape(d, e * fe),
        "wo": params["wo"].reshape(e * fe, d),
    }
    if "wg" in params:
        out["wg"] = jnp.transpose(params["wg"], (1, 0, 2)).reshape(d, e * fe)
    return out
