"""Self-distillation objectives (paper §4.2, Fig. 4).

Variants compared by the paper (language output modality):
  * forward KL  D_KL(p_student || p_teacher)   (paper's naming convention)
  * reverse KL  D_KL(p_teacher || p_student)
  * top-K KL: teacher probs reduced to (K+1)-vector = top-K probs + residual
    bucket; student arranged by the teacher's top-K token indices.
  * temperature scaling of both logit sets before softmax.

The paper adopts **forward KL on top-50 tokens** for LM/VLM, and cosine
distance between output token embeddings for ViT encoders.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _log_softmax(logits, temp: float):
    return jax.nn.log_softmax(logits.astype(jnp.float32) / temp, axis=-1)


def kl_divergence(student_logits, teacher_logits, temp: float = 1.0,
                  direction: str = "fwd"):
    """Full-vocab KL per token, meaned. direction follows the paper's naming:
    'fwd' = KL(student || teacher), 'rev' = KL(teacher || student)."""
    ls = _log_softmax(student_logits, temp)
    lt = _log_softmax(teacher_logits, temp)
    if direction == "fwd":
        ps = jnp.exp(ls)
        kl = jnp.sum(ps * (ls - lt), axis=-1)
    else:
        pt = jnp.exp(lt)
        kl = jnp.sum(pt * (lt - ls), axis=-1)
    return jnp.mean(kl) * temp * temp


def topk_kl(student_logits, teacher_logits, k: int = 50, temp: float = 1.0,
            direction: str = "fwd"):
    """Top-K KL [paper §4.2]: (K+1)-dim distributions with a residual bucket."""
    lt = _log_softmax(teacher_logits, temp)
    ls = _log_softmax(student_logits, temp)
    t_top, t_idx = jax.lax.top_k(lt, k)                       # (..., K)
    s_top = jnp.take_along_axis(ls, t_idx, axis=-1)
    return _residual_bucket_kl(s_top, t_top, direction) * temp * temp


def topk_kl_from_gathered(s_top, t_top, direction: str = "fwd"):
    """Same as topk_kl but on already-gathered log-probs (distributed path)."""
    return _residual_bucket_kl(s_top, t_top, direction)


def _residual_bucket_kl(s_top, t_top, direction):
    def aug(logp):
        p = jnp.exp(logp)
        resid = jnp.clip(1.0 - jnp.sum(p, axis=-1, keepdims=True), 1e-9, 1.0)
        return jnp.concatenate([logp, jnp.log(resid)], axis=-1)
    ls, lt = aug(s_top), aug(t_top)
    if direction == "fwd":
        kl = jnp.sum(jnp.exp(ls) * (ls - lt), axis=-1)
    else:
        kl = jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1)
    return jnp.mean(kl)


def cosine_distance(student_emb, teacher_emb, eps: float = 1e-6):
    """ViT-encoder objective: 1 - cos(student, teacher) per token, meaned."""
    s = student_emb.astype(jnp.float32)
    t = teacher_emb.astype(jnp.float32)
    num = jnp.sum(s * t, axis=-1)
    den = jnp.linalg.norm(s, axis=-1) * jnp.linalg.norm(t, axis=-1) + eps
    return jnp.mean(1.0 - num / den)


def distill_loss(student_out, teacher_out, ecfg, mask: Optional[jnp.ndarray] = None):
    """Dispatch on ecfg.distill_loss. *_out are logits (LM) or embeddings (ViT)."""
    kind = ecfg.distill_loss
    if kind == "cosine":
        return cosine_distance(student_out, teacher_out)
    if kind == "topk_kl":
        return topk_kl(student_out, teacher_out, k=ecfg.distill_topk,
                       temp=ecfg.distill_temp, direction="fwd")
    if kind == "topk_kl_rev":
        return topk_kl(student_out, teacher_out, k=ecfg.distill_topk,
                       temp=ecfg.distill_temp, direction="rev")
    if kind == "fwd_kl":
        return kl_divergence(student_out, teacher_out, ecfg.distill_temp, "fwd")
    if kind == "rev_kl":
        return kl_divergence(student_out, teacher_out, ecfg.distill_temp, "rev")
    raise ValueError(f"unknown distill loss {kind}")
