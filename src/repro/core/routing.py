"""ElastiFormer routing primitives (the paper's Alg. 1 & 2 + §B).

Two schemes:
  * input subset selection  — scalar sigmoid router per token, top-k (k=c*T)
    during training, threshold theta at causal inference (§B.1), BCE aux loss.
  * parameter subset selection — M-way router, w = M*softmax(W_r x), top-k
    submodules, straight-through via output scaling, load-balance aux (§B.2).

Capacities and top-k counts come in two flavors (see core/policy.py):
  * python numbers — trace-time constants; top-k executes on a *ragged
    capacity bucket* (default) or exact *gather* buffer with real FLOP
    savings in the lowered HLO;
  * traced jnp scalars / (B,) arrays — one compiled graph serves every
    budget (and mixed per-request budgets inside one batch): with a static
    ``bucket`` hint the ragged path keeps the FLOP savings (one graph per
    bucket, <= RAGGED_N_BUCKETS total), without one it falls back to
    rank-based validity *masking* at full shapes. Any capacity >= 1 (or
    top-k >= M, or ``student <= 0``) short-circuits to the exact unrouted
    module: router weights are forced to 1, the paper's losslessness
    property.

The ragged machinery (``capacity_buckets`` / ``bucket_for`` /
``make_plan`` / ``resolve_bucket``) stably partitions the sequence
valid-first: the selected tokens form a position-ascending prefix of a
static bucket-sized buffer, the true count rides along as a traced scalar
that the Pallas kernels use to skip trailing tiles. A block's full routing
decision is one ``RoutingPlan`` — gather indices, inverse scatter
permutation, validity, count, membership — derived from a SINGLE sort and
shared by every student in the block; ``resolve_bucket`` returning the
full sequence length is the identity fast path (full budget: skip the
partition entirely).

All router math is float32 regardless of backbone dtype.
"""
from __future__ import annotations

import inspect
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp



def _z():
    return jnp.zeros((), jnp.float32)


class RouteAux(NamedTuple):
    load: jnp.ndarray   # load-balance loss contribution (scalar)
    topk: jnp.ndarray   # BCE top-k consistency loss contribution (scalar)
    sel: jnp.ndarray    # sum over routers of selected-token fraction
    cnt: jnp.ndarray    # number of routers contributing to `sel`

    @staticmethod
    def zero():
        return RouteAux(_z(), _z(), _z(), _z())

    @staticmethod
    def of(load=None, topk=None, keep=None):
        """keep: bool selection mask -> records its mean as a sel-rate."""
        sel = cnt = None
        if keep is not None:
            sel = jnp.mean(keep.astype(jnp.float32))
            cnt = jnp.ones((), jnp.float32)
        return RouteAux(load if load is not None else _z(),
                        topk if topk is not None else _z(),
                        sel if sel is not None else _z(),
                        cnt if cnt is not None else _z())

    def __add__(self, o):
        return RouteAux(self.load + o.load, self.topk + o.topk,
                        self.sel + o.sel, self.cnt + o.cnt)

    @property
    def sel_rate(self):
        """Mean fraction of tokens processed across token routers."""
        return self.sel / jnp.maximum(self.cnt, 1.0)


# ----------------------- input subset selection -----------------------------

def token_router_init(key, d: int):
    w = jax.random.normal(key, (d,), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def token_logits(rp, x):
    """Scalar routing logits per token. x: (..., D) -> (...,) f32."""
    return x.astype(jnp.float32) @ rp["w"] + rp["b"]


def topk_indices(scores, k: int):
    """Top-k indices along the last axis, sorted ascending (causal order)."""
    _, idx = jax.lax.top_k(scores, k)
    return jnp.sort(idx, axis=-1)


def topk_mask(scores, k: int):
    """Boolean membership mask of the top-k entries along the last axis."""
    kth = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= kth


# ----------------- static/traced scalar plumbing (policy leaves) -------------

def is_static(v) -> bool:
    """True for python numbers (trace-time constants from the legacy
    ``ElasticConfig`` path); traced policy leaves are jnp arrays/tracers."""
    return isinstance(v, (int, float))


def bcast_to(v, ndim: int):
    """Right-pad a leading-dims value ((), (B,), ...) with singleton axes so
    it broadcasts against an (B, ..., n) tensor of rank ``ndim``."""
    if is_static(v):
        return v
    v = jnp.asarray(v)
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


# Trace-time counter over the sorts issued by the routing machinery (the
# test hook behind the "one RoutingPlan sort per block" invariant). Every
# argsort in this module MUST go through _argsort so the counter is honest.
PLAN_SORT_COUNT = 0


def _argsort(x, axis: int = -1):
    global PLAN_SORT_COUNT
    PLAN_SORT_COUNT += 1
    return jnp.argsort(x, axis=axis)


def invert_permutation(perm):
    """Inverse of a batched permutation along the last axis WITHOUT a second
    sort: inv[..., perm[..., i]] = i via an int32 scatter (O(S) vs the
    O(S log S) argsort-of-argsort it replaces)."""
    s = perm.shape[-1]
    flat = perm.reshape(-1, s)
    b = jnp.arange(flat.shape[0])[:, None]
    ar = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), flat.shape)
    inv = jnp.zeros_like(flat).at[b, flat].set(ar)
    return inv.reshape(perm.shape)


def token_ranks(scores):
    """Descending rank of each entry along the last axis (0 = largest).
    ONE sort: the inverse permutation is derived by scatter, not by the
    legacy argsort(argsort(-scores)) double sort (bit-identical: jnp.argsort
    is stable, so ties still break by ascending position)."""
    return invert_permutation(_argsort(-scores, axis=-1))


def topk_mask_dyn(scores, k):
    """topk_mask with a *traced* k ((), or any leading-dims shape): an entry
    is kept iff its descending rank is < k. Ties broken by position."""
    return token_ranks(scores) < bcast_to(k, scores.ndim)


def topk_mask_any(scores, k):
    if is_static(k):
        return topk_mask(scores, int(k))
    return topk_mask_dyn(scores, k)


def capacity_k(capacity, s: int, mxu: bool = False):
    """ceil(capacity * s) clipped to [1, s]; python int when static.

    ``mxu``: on long sequences (s >= 1024) round the count up to a multiple
    of 128 (MXU-friendly gather sizes) — the traced path applies the SAME
    rule so one-graph masking selects exactly the tokens the static gather
    compile would have. Every call site (gather, dense mask, ragged bucket
    selection) must pass the same ``mxu`` so all three execution paths pick
    the exact same token set."""
    if is_static(capacity):
        k = int(math.ceil(capacity * s))
        if mxu and s >= 1024:
            k = min(s, -(-k // 128) * 128)
        return max(1, min(s, k))
    k = jnp.ceil(capacity * s)
    if mxu and s >= 1024:
        k = jnp.minimum(s, jnp.ceil(k / 128) * 128)
    return jnp.clip(k, 1, s)


# --------------------- ragged capacity buckets ------------------------------

RAGGED_N_BUCKETS = 4     # static graphs per sequence length, max
RAGGED_ALIGN = 128       # MXU lane alignment of bucket sizes

# Sentinel bucket hint meaning "every row is at FULL budget": compile the
# identity graph (no partition/gather/scatter — bit-exact teacher math).
# Deliberately not a valid buffer size, so a real bucket solved for one
# sequence length can never be mistaken for the identity assertion when a
# shorter batch happens to match it.
IDENTITY_BUCKET = -1


def capacity_buckets(s: int, *, n_buckets: int = RAGGED_N_BUCKETS,
                     align: int = RAGGED_ALIGN):
    """Static ragged buffer sizes for sequence length ``s``: ``n_buckets``
    evenly spaced fractions of s, each rounded up to a multiple of ``align``
    (shrunk on short sequences so buckets stay distinct), capped at s.
    Every budget maps onto one of these, so the one-compile-per-budget
    blow-up of the legacy gather path collapses to <= n_buckets graphs."""
    align = max(1, min(align, -(-s // n_buckets)))
    out = []
    for i in range(1, n_buckets + 1):
        b = -(-s * i // n_buckets)            # ceil(s*i/n)
        b = min(s, -(-b // align) * align)    # round up to align
        if not out or b > out[-1]:
            out.append(b)
    return tuple(out)


def bucket_for(k: int, s: int, *, n_buckets: int = RAGGED_N_BUCKETS,
               align: int = RAGGED_ALIGN) -> int:
    """Smallest static bucket >= k tokens (k <= s)."""
    for b in capacity_buckets(s, n_buckets=n_buckets, align=align):
        if b >= k:
            return b
    return s


class RoutingPlan(NamedTuple):
    """One block's token-routing decision, derived from a SINGLE sort.

    The plan is the shared currency of the routed-execution layer: the
    attention and MLP/MoE students of a block consume the same plan instead
    of each re-deriving ranks (a double argsort), the valid-first partition
    (another argsort), and a scatter permutation per component.

    idx   : (..., bucket) i32 — gather indices; the selected tokens form a
            position-ascending prefix (causal attention over the prefix IS
            causal attention over the selected tokens), the tail holds the
            remaining tokens (position-ascending) and is masked by `valid`.
    inv   : (..., S) i32 — inverse scatter permutation: token position ->
            buffer slot (>= bucket: the token was dropped entirely). Turns
            the scatter-back into a cheap gather (`plan_scatter`).
    valid : (..., bucket) bool — prefix validity of the buffer rows.
    count : python int (static k) or (...,) i32 — true selected count; the
            scalar-prefetched ragged argument of the Pallas kernels.
    keep  : (..., S) bool — membership mask (BCE aux target / kv validity).
    bucket: static buffer size. bucket == S with every row kept is the
            identity plan — callers fast-path it and skip gather/scatter.
    """
    idx: jnp.ndarray
    inv: jnp.ndarray
    valid: jnp.ndarray
    count: object
    keep: jnp.ndarray
    bucket: int


def make_plan(scores, k, bucket: int) -> RoutingPlan:
    """Build a RoutingPlan from router scores with ONE sort.

    scores: (..., S); k: top-k count — python int, traced scalar, or
    per-row (B,); bucket: static buffer size (k is clamped to it).

    Derivation: one stable argsort of -scores gives the descending order;
    ranks are its inverse permutation (scatter, not a second sort); the
    valid-first destination of every token is a cumsum over the keep mask;
    the gather permutation is that destination's inverse (another scatter).
    Total: 1 sort + 2 int32 scatters + 2 cumsums, replacing the legacy
    3-sort chain (token_ranks x2 + ragged_select's partition argsort)."""
    s = scores.shape[-1]
    ranks = token_ranks(scores)                       # ONE sort (counted)
    if is_static(k):
        kk = max(1, min(int(k), bucket))
        keep = ranks < kk
        count = kk
    else:
        kk = jnp.minimum(k, bucket)
        keep = ranks < bcast_to(kk, scores.ndim)
        count = jnp.sum(keep, axis=-1).astype(jnp.int32)
    nk = jnp.cumsum(keep.astype(jnp.int32), axis=-1)
    n_keep = nk[..., -1:]
    dest = jnp.where(keep, nk - 1,
                     n_keep + jnp.cumsum((~keep).astype(jnp.int32), -1) - 1)
    perm = invert_permutation(dest)                   # scatter, not a sort
    idx = perm[..., :bucket].astype(jnp.int32)
    if is_static(k):
        valid = jnp.broadcast_to(jnp.arange(bucket) < count, idx.shape)
    else:
        valid = jnp.arange(bucket) < count[..., None]
    return RoutingPlan(idx, dest.astype(jnp.int32), valid, count, keep,
                       bucket)


def constrain_plan(plan: RoutingPlan) -> RoutingPlan:
    """Pin the plan's token-dim arrays to batch-over-data / REPLICATED over
    `model` under the active mesh (no-op outside one, or inside a manual
    shard_map region — callers gate on that): the plan is built once per
    block from full-(B, T) router scores, and every TP shard of the block
    must consume the SAME gather/scatter permutation — a model-sharded
    plan would route different tokens through different weight shards.
    Tiny int/bool arrays, so replication costs nothing; what it buys is
    that GSPMD never re-partitions the sort/cumsum chain (one sort per
    block stays one sort under the mesh)."""
    from repro.runtime import sharding as SH
    c = lambda a: (SH.constrain_batch(a)
                   if getattr(a, "ndim", 0) >= 1 else a)
    return plan._replace(idx=c(plan.idx), inv=c(plan.inv),
                         valid=c(plan.valid), count=c(plan.count),
                         keep=c(plan.keep))


def plan_gather(x, plan: RoutingPlan):
    """x: (B, S, ...) -> (B, bucket, ...) selected-first buffer."""
    return gather_tokens(x, plan.idx)


def plan_scatter(plan: RoutingPlan, shape_like, vals):
    """Inverse of plan_gather as a GATHER by the plan's inverse permutation
    (no scatter-add: XLA lowers batched scatter-adds to f32 upcasts plus
    full-buffer copies). vals: (B, bucket, ...) already weighted; rows the
    plan dropped (inv >= bucket) and the masked tail contribute zeros."""
    b = plan.bucket
    safe = jnp.minimum(plan.inv, b - 1)
    expand = (slice(None), slice(None)) + (None,) * (vals.ndim - 2)
    out = jnp.take_along_axis(vals, safe[expand], axis=1)
    live = (plan.inv < b) & plan.keep
    return jnp.where(live[expand], out, 0).astype(shape_like.dtype)


def ragged_select(scores, k, bucket: int):
    """Stable valid-first partition for ragged capacity-bucket routing.

    Legacy entry point, now a thin view over ``make_plan`` (one sort instead
    of three). Returns (idx (..., bucket) i32, valid (..., bucket) bool,
    count): ``idx[..., :k]`` are the top-k tokens in ascending POSITION
    order (the exact token set of ``topk_mask_dyn``, ties by position), the
    tail is filled with the remaining tokens and masked out by ``valid``;
    ``count`` is the number of valid prefix rows (python int when k is
    static) — the traced scalar the Pallas kernels take to skip trailing
    tiles.

    ``k`` is clamped to ``bucket``: callers must pass a covering bucket
    (``resolve_bucket`` / ``policy.ragged_bucket`` guarantee it); an
    undersized one degrades to a well-defined truncation — the top-bucket
    tokens — with ``keep``/``count``/``valid`` all agreeing on the executed
    set, never an all-valid mask over silently dropped tokens."""
    plan = make_plan(scores, k, bucket)
    return plan.idx, plan.valid, plan.count


def threshold_logit(theta):
    """Router-logit threshold equivalent to sigmoid(logit) > theta."""
    if is_static(theta):
        return math.log(theta / (1.0 - theta)) if 0.0 < theta < 1.0 \
            else (-jnp.inf if theta <= 0.0 else jnp.inf)
    theta = jnp.clip(jnp.asarray(theta, jnp.float32), 1e-6, 1.0 - 1e-6)
    return jnp.log(theta) - jnp.log1p(-theta)


def gate_capacity(capacity, student):
    """Teacher gating: ``student <= 0`` forces full capacity (exact teacher)."""
    if student is None:
        return capacity
    if is_static(student):
        return capacity if student > 0 else 1.0
    cap = capacity if not is_static(capacity) else jnp.asarray(
        capacity, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, cap, 1.0)


def gate_topk(k, student, n: int):
    """Teacher gating for parameter-subset top-k: student off -> all n."""
    if student is None:
        return k
    if is_static(student):
        return k if student > 0 else n
    kk = k if not is_static(k) else jnp.asarray(k, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, kk, n)


def is_full(v, limit=1.0):
    """capacity >= 1 (or top-k >= M): the knob requests the exact teacher.
    python bool when static, else a traced bool array."""
    if is_static(v):
        return v >= limit
    return jnp.asarray(v) >= limit


def token_gate(logits, scores, capacity, mode: str, *, theta=0.5,
               mxu: bool = False):
    """Unified keep-mask + router weight for input subset selection.

    Train: top-k by capacity (static fast path or traced rank masking; both
    use the same rounding — see ``capacity_k``'s ``mxu``).
    Infer: threshold theta on the router sigmoid (§B.1).
    Any capacity >= 1 forces (keep=all, weight=1) — exact teacher.
    Returns (keep bool (B,S), weight f32 (B,S)).
    """
    S = scores.shape[-1]
    if mode == "train":
        keep = topk_mask_any(scores, capacity_k(capacity, S, mxu=mxu))
    else:
        keep = logits > bcast_to(threshold_logit(theta), logits.ndim)
    full = is_full(capacity)
    if is_static(full):
        if full:
            return jnp.ones_like(keep, bool), jnp.ones_like(scores)
        return keep, keep * scores
    full = bcast_to(full, keep.ndim)
    keep = keep | full
    return keep, jnp.where(full, 1.0, keep * scores)


def bce_topk_loss(logits, in_topk):
    """§B.1 auxiliary loss: router sigmoid should predict top-k membership."""
    y = in_topk.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gather_tokens(x, idx):
    """x: (B,S,...) idx: (B,k) -> (B,k,...)."""
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 2)
    return jnp.take_along_axis(x, idx[expand], axis=1)


def scatter_add_tokens(shape_like, idx, vals):
    """Inverse of gather_tokens: zeros.at[b, idx].add(vals)."""
    y = jnp.zeros_like(shape_like)
    b = jnp.arange(y.shape[0])[:, None]
    return y.at[b, idx].add(vals.astype(y.dtype))


def _accepts_token_valid(f) -> bool:
    """True when f's signature exposes the ragged prefix contract
    (a ``token_valid`` parameter or ``**kwargs``)."""
    try:
        params = inspect.signature(f).parameters
    except (TypeError, ValueError):
        return False
    return "token_valid" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def resolve_bucket(capacity, s: int, bucket=None, impl: str = "ragged"):
    """Static plan buffer size for this trace. Returns one of:

      * ``None``  — no static plan possible: dense rank-masked fallback
        (traced capacity without a bucket hint, or a bucket that would
        round up to the full sequence without being full-budget);
      * ``s``     — the IDENTITY fast path: the caller asserts every row is
        at full budget (static capacity >= 1, or ``policy.ragged_bucket``
        returned ``s`` after checking the concrete policy host-side), so
        partition + gather + scatter are skipped entirely and the block
        runs the bit-exact teacher math;
      * ``0 < b < s`` — plan buffer size: the ragged capacity bucket, or
        the exact MXU-rounded top-k count under ``impl == "gather"``.

    Static capacities derive the size inline; traced capacities ride the
    caller's static ``bucket`` hint (which must cover the largest per-row
    top-k this graph will see). The identity assertion travels as the
    distinct ``IDENTITY_BUCKET`` sentinel (what ``policy.ragged_bucket``
    returns after checking the concrete policy host-side) — an ordinary
    hint that merely reaches ``s`` (solved for a longer sequence, applied
    to a shorter batch) degrades to the dense fallback like the pre-plan
    code, never to the unrouted graph."""
    if capacity is None:
        return None
    if is_static(capacity):
        if capacity >= 1.0:
            return s
        k = capacity_k(capacity, s, mxu=True)
        kb = min(s, k if impl == "gather" else bucket_for(k, s))
        return kb if kb < s else None
    if bucket is None:
        return None
    kb = int(bucket)
    if kb == IDENTITY_BUCKET:
        return s
    return kb if kb < s else None


def route_tokens(
    rp,
    x,                      # (B, S, D)
    f: Callable,            # f(x_sub, positions_sub) -> (B, k(or S), D)
    capacity,               # None | python float (static) | traced scalar/(B,)
    mode: str,              # base | train | infer
    positions=None,         # (S,) int32 positions (for RoPE/causal inside f)
    impl: str = "ragged",
    theta=0.5,              # inference threshold (policy.theta)
    student=None,           # policy.student: <=0 bypasses routing entirely
    bucket=None,            # static ragged buffer size (traced capacities)
    mxu: bool = True,       # capacity_k rounding — same flag on EVERY path
):
    """Input subset selection around a module f (residual added by caller).

    This is the standalone single-component API (and the model's inference
    thresholding path). The model's train-mode hot path does NOT come
    through here: ``models/blocks.block_apply`` inlines the same
    plan/identity semantics so one RoutingPlan can be SHARED across a
    block's components — keep the two in sync (tests/test_routing.py
    pins this function, tests/test_backend.py pins the block-level grid).

    Returns (delta, aux). delta is f's (router-weighted) contribution.
    Three implementations of the train-mode top-k:
      * ragged (default): gather into a capacity-bucket buffer (static
        bucket size, traced true count) — FLOPs proportional to the bucket,
        <= RAGGED_N_BUCKETS compiles per sequence length;
      * gather: legacy static top-k gather — smallest HLO, one compile PER
        budget; static capacities only;
      * dense_mask: full-shape compute with rank masking — one compile for
        every budget, no FLOP savings (reference/fallback; also serves
        inference thresholding and traced capacities without a bucket).
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if capacity is None or mode == "base":
        return f(x, positions), RouteAux.zero()

    capacity = gate_capacity(capacity, student)
    logits = token_logits(rp, x)            # (B, S)
    scores = jax.nn.sigmoid(logits)

    kb = None
    if mode == "train" and impl in ("ragged", "gather"):
        if impl == "ragged" or (is_static(capacity) and is_static(theta)):
            kb = resolve_bucket(capacity, S, bucket, impl=impl)
    if kb == S:
        # identity fast path: full budget on every row — skip partition,
        # gather, and scatter entirely (bit-exact: weights would be 1.0)
        keep = jnp.ones((B, S), bool)
        return f(x, positions), RouteAux.of(
            topk=bce_topk_loss(logits, keep), keep=keep)
    if kb is not None:
        k = capacity_k(capacity, S, mxu=mxu)
        plan = make_plan(scores, k, kb)      # the ONE sort of this call
        x_sel = plan_gather(x, plan)
        pos_sel = positions[plan.idx] if positions.ndim == 1 \
            else jnp.take_along_axis(positions, plan.idx, 1)
        # Modules that understand the ragged prefix contract (e.g. MoE
        # dispatch, where masked tail rows must not consume expert
        # capacity) get the validity mask and true count. Awareness is
        # declared by the SIGNATURE: expose a ``token_valid`` kwarg (or
        # **kwargs) — a wrapper that hides it opts its module out, so
        # wrap ragged-aware modules with functools.wraps or forward the
        # kwargs explicitly.
        if _accepts_token_valid(f):
            y_sel = f(x_sel, pos_sel, token_valid=plan.valid,
                      token_count=plan.count)
        else:
            y_sel = f(x_sel, pos_sel)
        w_sel = jnp.take_along_axis(scores, plan.idx, axis=1) * plan.valid
        delta = plan_scatter(plan, x,
                             y_sel * w_sel[..., None].astype(y_sel.dtype))
        return delta, RouteAux.of(topk=bce_topk_loss(logits, plan.keep),
                                  keep=plan.keep)

    # dense path: full-shape compute, rank/threshold masking (train w/
    # dense_mask impl, inference, and traced capacities without a bucket)
    keep, w = token_gate(logits, scores, capacity, mode, theta=theta, mxu=mxu)
    y = f(x, positions)
    delta = y * w[..., None].astype(y.dtype)
    if mode == "train":
        return delta, RouteAux.of(topk=bce_topk_loss(logits, keep), keep=keep)
    return delta, RouteAux.of(keep=keep)


# --------------------- parameter subset selection ---------------------------

def param_router_init(key, d: int, m: int):
    w = jax.random.normal(key, (d, m), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w}


def param_route_weights(rp, x, top_k, normalize_to_m: bool = True,
                        valid=None):
    """Alg. 1: w = M * softmax(W_r x); top-k selection mask.

    ``top_k`` may be a python int (static) or a traced scalar/(B,) array
    (rank masking; one compiled graph for every k). ``valid`` (x's leading
    dims) excludes rows from the load-balance statistics — ragged bucket
    buffers pass their prefix mask so the padded tail (whose outputs are
    weighted to zero anyway) cannot skew the aux loss.
    Returns (weights (...,M) f32, mask (...,M) bool, aux RouteAux).
    With k == M and a uniform router this reproduces the base module exactly
    (weights == 1 everywhere) — the paper's losslessness property.
    """
    m = rp["w"].shape[-1]
    logits = x.astype(jnp.float32) @ rp["w"]            # (..., M)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * m if normalize_to_m else probs
    k = min(int(top_k), m) if is_static(top_k) else jnp.clip(top_k, 1, m)
    mask = topk_mask_any(w, k)
    # §B.2 load-balance: E_m[frac_selected(m) * mean_prob(m)] * M
    red = tuple(range(probs.ndim - 1))
    if valid is None:
        frac = jnp.mean(mask.astype(jnp.float32), axis=red)
        mean_p = jnp.mean(probs, axis=red)
    else:
        vw = valid.astype(jnp.float32)[..., None]
        denom = jnp.maximum(jnp.sum(vw), 1.0)
        frac = jnp.sum(mask * vw, axis=red) / denom
        mean_p = jnp.sum(probs * vw, axis=red) / denom
    load = m * jnp.sum(frac * mean_p)
    return w, mask, RouteAux.of(load=load)
