"""ElastiFormer routing primitives (the paper's Alg. 1 & 2 + §B).

Two schemes:
  * input subset selection  — scalar sigmoid router per token, top-k (k=c*T)
    during training, threshold theta at causal inference (§B.1), BCE aux loss.
  * parameter subset selection — M-way router, w = M*softmax(W_r x), top-k
    submodules, straight-through via output scaling, load-balance aux (§B.2).

Capacities and top-k counts come in two flavors (see core/policy.py):
  * python numbers — trace-time constants; top-k executes on a *ragged
    capacity bucket* (default) or exact *gather* buffer with real FLOP
    savings in the lowered HLO;
  * traced jnp scalars / (B,) arrays — one compiled graph serves every
    budget (and mixed per-request budgets inside one batch): with a static
    ``bucket`` hint the ragged path keeps the FLOP savings (one graph per
    bucket, <= RAGGED_N_BUCKETS total), without one it falls back to
    rank-based validity *masking* at full shapes. Any capacity >= 1 (or
    top-k >= M, or ``student <= 0``) short-circuits to the exact unrouted
    module: router weights are forced to 1, the paper's losslessness
    property.

The ragged machinery (``capacity_buckets`` / ``bucket_for`` /
``ragged_select`` / ``resolve_bucket``) stably partitions the sequence
valid-first: the selected tokens form a position-ascending prefix of a
static bucket-sized buffer, the true count rides along as a traced scalar
that the Pallas kernels use to skip trailing tiles.

All router math is float32 regardless of backbone dtype.
"""
from __future__ import annotations

import inspect
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp



def _z():
    return jnp.zeros((), jnp.float32)


class RouteAux(NamedTuple):
    load: jnp.ndarray   # load-balance loss contribution (scalar)
    topk: jnp.ndarray   # BCE top-k consistency loss contribution (scalar)
    sel: jnp.ndarray    # sum over routers of selected-token fraction
    cnt: jnp.ndarray    # number of routers contributing to `sel`

    @staticmethod
    def zero():
        return RouteAux(_z(), _z(), _z(), _z())

    @staticmethod
    def of(load=None, topk=None, keep=None):
        """keep: bool selection mask -> records its mean as a sel-rate."""
        sel = cnt = None
        if keep is not None:
            sel = jnp.mean(keep.astype(jnp.float32))
            cnt = jnp.ones((), jnp.float32)
        return RouteAux(load if load is not None else _z(),
                        topk if topk is not None else _z(),
                        sel if sel is not None else _z(),
                        cnt if cnt is not None else _z())

    def __add__(self, o):
        return RouteAux(self.load + o.load, self.topk + o.topk,
                        self.sel + o.sel, self.cnt + o.cnt)

    @property
    def sel_rate(self):
        """Mean fraction of tokens processed across token routers."""
        return self.sel / jnp.maximum(self.cnt, 1.0)


# ----------------------- input subset selection -----------------------------

def token_router_init(key, d: int):
    w = jax.random.normal(key, (d,), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def token_logits(rp, x):
    """Scalar routing logits per token. x: (..., D) -> (...,) f32."""
    return x.astype(jnp.float32) @ rp["w"] + rp["b"]


def topk_indices(scores, k: int):
    """Top-k indices along the last axis, sorted ascending (causal order)."""
    _, idx = jax.lax.top_k(scores, k)
    return jnp.sort(idx, axis=-1)


def topk_mask(scores, k: int):
    """Boolean membership mask of the top-k entries along the last axis."""
    kth = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= kth


# ----------------- static/traced scalar plumbing (policy leaves) -------------

def is_static(v) -> bool:
    """True for python numbers (trace-time constants from the legacy
    ``ElasticConfig`` path); traced policy leaves are jnp arrays/tracers."""
    return isinstance(v, (int, float))


def bcast_to(v, ndim: int):
    """Right-pad a leading-dims value ((), (B,), ...) with singleton axes so
    it broadcasts against an (B, ..., n) tensor of rank ``ndim``."""
    if is_static(v):
        return v
    v = jnp.asarray(v)
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


def token_ranks(scores):
    """Descending rank of each entry along the last axis (0 = largest)."""
    return jnp.argsort(jnp.argsort(-scores, axis=-1), axis=-1)


def topk_mask_dyn(scores, k):
    """topk_mask with a *traced* k ((), or any leading-dims shape): an entry
    is kept iff its descending rank is < k. Ties broken by position."""
    return token_ranks(scores) < bcast_to(k, scores.ndim)


def topk_mask_any(scores, k):
    if is_static(k):
        return topk_mask(scores, int(k))
    return topk_mask_dyn(scores, k)


def capacity_k(capacity, s: int, mxu: bool = False):
    """ceil(capacity * s) clipped to [1, s]; python int when static.

    ``mxu``: on long sequences (s >= 1024) round the count up to a multiple
    of 128 (MXU-friendly gather sizes) — the traced path applies the SAME
    rule so one-graph masking selects exactly the tokens the static gather
    compile would have. Every call site (gather, dense mask, ragged bucket
    selection) must pass the same ``mxu`` so all three execution paths pick
    the exact same token set."""
    if is_static(capacity):
        k = int(math.ceil(capacity * s))
        if mxu and s >= 1024:
            k = min(s, -(-k // 128) * 128)
        return max(1, min(s, k))
    k = jnp.ceil(capacity * s)
    if mxu and s >= 1024:
        k = jnp.minimum(s, jnp.ceil(k / 128) * 128)
    return jnp.clip(k, 1, s)


# --------------------- ragged capacity buckets ------------------------------

RAGGED_N_BUCKETS = 4     # static graphs per sequence length, max
RAGGED_ALIGN = 128       # MXU lane alignment of bucket sizes


def capacity_buckets(s: int, *, n_buckets: int = RAGGED_N_BUCKETS,
                     align: int = RAGGED_ALIGN):
    """Static ragged buffer sizes for sequence length ``s``: ``n_buckets``
    evenly spaced fractions of s, each rounded up to a multiple of ``align``
    (shrunk on short sequences so buckets stay distinct), capped at s.
    Every budget maps onto one of these, so the one-compile-per-budget
    blow-up of the legacy gather path collapses to <= n_buckets graphs."""
    align = max(1, min(align, -(-s // n_buckets)))
    out = []
    for i in range(1, n_buckets + 1):
        b = -(-s * i // n_buckets)            # ceil(s*i/n)
        b = min(s, -(-b // align) * align)    # round up to align
        if not out or b > out[-1]:
            out.append(b)
    return tuple(out)


def bucket_for(k: int, s: int, *, n_buckets: int = RAGGED_N_BUCKETS,
               align: int = RAGGED_ALIGN) -> int:
    """Smallest static bucket >= k tokens (k <= s)."""
    for b in capacity_buckets(s, n_buckets=n_buckets, align=align):
        if b >= k:
            return b
    return s


def ragged_select(scores, k, bucket: int):
    """Stable valid-first partition for ragged capacity-bucket routing.

    scores: (..., S) router scores; k: top-k count — python int, traced
    scalar, or per-row (B,); bucket: static buffer size with k <= bucket.

    Returns (idx (..., bucket) i32, valid (..., bucket) bool, count):
    ``idx[..., :k]`` are the top-k tokens in ascending POSITION order (the
    exact token set of ``topk_mask_dyn``, ties by position), so causal
    attention over the buffer prefix is causal attention over the selected
    tokens; the tail is filled with the remaining (not-selected) tokens,
    also position-ascending, and masked out by ``valid``. ``count`` is the
    number of valid prefix rows (python int when k is static) — the traced
    scalar the Pallas kernels take to skip trailing tiles.

    ``k`` is clamped to ``bucket``: callers must pass a covering bucket
    (``resolve_bucket`` / ``policy.ragged_bucket`` guarantee it); an
    undersized one degrades to a well-defined truncation — the top-bucket
    tokens — with ``keep``/``count``/``valid`` all agreeing on the executed
    set, never an all-valid mask over silently dropped tokens."""
    s = scores.shape[-1]
    k = min(int(k), bucket) if is_static(k) else jnp.minimum(k, bucket)
    keep = topk_mask_dyn(scores, k)
    pos = jnp.arange(s, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(keep, pos, pos + s), axis=-1)
    idx = order[..., :bucket].astype(jnp.int32)
    if is_static(k):
        count = max(1, min(int(k), bucket))
        valid = jnp.broadcast_to(jnp.arange(bucket) < count,
                                 idx.shape)
    else:
        count = jnp.sum(keep, axis=-1).astype(jnp.int32)  # leading dims
        valid = jnp.arange(bucket) < count[..., None]
    return idx, valid, count


def threshold_logit(theta):
    """Router-logit threshold equivalent to sigmoid(logit) > theta."""
    if is_static(theta):
        return math.log(theta / (1.0 - theta)) if 0.0 < theta < 1.0 \
            else (-jnp.inf if theta <= 0.0 else jnp.inf)
    theta = jnp.clip(jnp.asarray(theta, jnp.float32), 1e-6, 1.0 - 1e-6)
    return jnp.log(theta) - jnp.log1p(-theta)


def gate_capacity(capacity, student):
    """Teacher gating: ``student <= 0`` forces full capacity (exact teacher)."""
    if student is None:
        return capacity
    if is_static(student):
        return capacity if student > 0 else 1.0
    cap = capacity if not is_static(capacity) else jnp.asarray(
        capacity, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, cap, 1.0)


def gate_topk(k, student, n: int):
    """Teacher gating for parameter-subset top-k: student off -> all n."""
    if student is None:
        return k
    if is_static(student):
        return k if student > 0 else n
    kk = k if not is_static(k) else jnp.asarray(k, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, kk, n)


def is_full(v, limit=1.0):
    """capacity >= 1 (or top-k >= M): the knob requests the exact teacher.
    python bool when static, else a traced bool array."""
    if is_static(v):
        return v >= limit
    return jnp.asarray(v) >= limit


def token_gate(logits, scores, capacity, mode: str, *, theta=0.5,
               mxu: bool = False):
    """Unified keep-mask + router weight for input subset selection.

    Train: top-k by capacity (static fast path or traced rank masking; both
    use the same rounding — see ``capacity_k``'s ``mxu``).
    Infer: threshold theta on the router sigmoid (§B.1).
    Any capacity >= 1 forces (keep=all, weight=1) — exact teacher.
    Returns (keep bool (B,S), weight f32 (B,S)).
    """
    S = scores.shape[-1]
    if mode == "train":
        keep = topk_mask_any(scores, capacity_k(capacity, S, mxu=mxu))
    else:
        keep = logits > bcast_to(threshold_logit(theta), logits.ndim)
    full = is_full(capacity)
    if is_static(full):
        if full:
            return jnp.ones_like(keep, bool), jnp.ones_like(scores)
        return keep, keep * scores
    full = bcast_to(full, keep.ndim)
    keep = keep | full
    return keep, jnp.where(full, 1.0, keep * scores)


def bce_topk_loss(logits, in_topk):
    """§B.1 auxiliary loss: router sigmoid should predict top-k membership."""
    y = in_topk.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gather_tokens(x, idx):
    """x: (B,S,...) idx: (B,k) -> (B,k,...)."""
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 2)
    return jnp.take_along_axis(x, idx[expand], axis=1)


def scatter_add_tokens(shape_like, idx, vals):
    """Inverse of gather_tokens: zeros.at[b, idx].add(vals)."""
    y = jnp.zeros_like(shape_like)
    b = jnp.arange(y.shape[0])[:, None]
    return y.at[b, idx].add(vals.astype(y.dtype))


def _accepts_token_valid(f) -> bool:
    """True when f's signature exposes the ragged prefix contract
    (a ``token_valid`` parameter or ``**kwargs``)."""
    try:
        params = inspect.signature(f).parameters
    except (TypeError, ValueError):
        return False
    return "token_valid" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def resolve_bucket(capacity, s: int, bucket=None):
    """Static ragged buffer size for this trace, or None when the ragged
    path cannot run (-> dense fallback): static capacities derive it from
    the capacity itself, traced capacities need the caller's static
    ``bucket`` hint (which must cover the largest per-row top-k this graph
    will see). A bucket >= s is dense anyway, so it also returns None."""
    if capacity is None:
        return None
    if is_static(capacity):
        if capacity >= 1.0:
            return None
        kb = bucket_for(capacity_k(capacity, s, mxu=True), s)
    elif bucket is None:
        return None
    else:
        kb = int(bucket)
    kb = min(kb, s)
    return kb if kb < s else None


def route_tokens(
    rp,
    x,                      # (B, S, D)
    f: Callable,            # f(x_sub, positions_sub) -> (B, k(or S), D)
    capacity,               # None | python float (static) | traced scalar/(B,)
    mode: str,              # base | train | infer
    positions=None,         # (S,) int32 positions (for RoPE/causal inside f)
    impl: str = "ragged",
    theta=0.5,              # inference threshold (policy.theta)
    student=None,           # policy.student: <=0 bypasses routing entirely
    bucket=None,            # static ragged buffer size (traced capacities)
    mxu: bool = True,       # capacity_k rounding — same flag on EVERY path
):
    """Input subset selection around a module f (residual added by caller).

    Returns (delta, aux). delta is f's (router-weighted) contribution.
    Three implementations of the train-mode top-k:
      * ragged (default): gather into a capacity-bucket buffer (static
        bucket size, traced true count) — FLOPs proportional to the bucket,
        <= RAGGED_N_BUCKETS compiles per sequence length;
      * gather: legacy static top-k gather — smallest HLO, one compile PER
        budget; static capacities only;
      * dense_mask: full-shape compute with rank masking — one compile for
        every budget, no FLOP savings (reference/fallback; also serves
        inference thresholding and traced capacities without a bucket).
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if capacity is None or mode == "base":
        return f(x, positions), RouteAux.zero()

    capacity = gate_capacity(capacity, student)
    logits = token_logits(rp, x)            # (B, S)
    scores = jax.nn.sigmoid(logits)

    if (mode == "train" and impl == "gather" and is_static(capacity)
            and is_static(theta) and capacity < 1.0):
        k = capacity_k(capacity, S, mxu=mxu)
        idx = topk_indices(scores, k)        # (B, k) ascending
        x_sel = gather_tokens(x, idx)
        pos_sel = positions[idx] if positions.ndim == 1 else jnp.take_along_axis(positions, idx, 1)
        y_sel = f(x_sel, pos_sel)
        w_sel = jnp.take_along_axis(scores, idx, axis=1)
        y_sel = y_sel * w_sel[..., None].astype(y_sel.dtype)
        delta = scatter_add_tokens(x, idx, y_sel)
        mask = topk_mask(scores, k)
        return delta, RouteAux.of(topk=bce_topk_loss(logits, mask), keep=mask)

    kb = resolve_bucket(capacity, S, bucket) if (
        mode == "train" and impl == "ragged") else None
    if kb is not None:
        k = capacity_k(capacity, S, mxu=mxu)
        idx, pvalid, cnt = ragged_select(scores, k, kb)
        x_sel = gather_tokens(x, idx)
        pos_sel = positions[idx] if positions.ndim == 1 \
            else jnp.take_along_axis(positions, idx, 1)
        # Modules that understand the ragged prefix contract (e.g. MoE
        # dispatch, where masked tail rows must not consume expert
        # capacity) get the validity mask and true count. Awareness is
        # declared by the SIGNATURE: expose a ``token_valid`` kwarg (or
        # **kwargs) — a wrapper that hides it opts its module out, so
        # wrap ragged-aware modules with functools.wraps or forward the
        # kwargs explicitly.
        if _accepts_token_valid(f):
            y_sel = f(x_sel, pos_sel, token_valid=pvalid, token_count=cnt)
        else:
            y_sel = f(x_sel, pos_sel)
        w_sel = jnp.take_along_axis(scores, idx, axis=1) * pvalid
        delta = scatter_add_tokens(
            x, idx, y_sel * w_sel[..., None].astype(y_sel.dtype))
        keep = topk_mask_dyn(scores, k)
        return delta, RouteAux.of(topk=bce_topk_loss(logits, keep), keep=keep)

    # dense path: full-shape compute, rank/threshold masking (train w/
    # dense_mask impl, inference, and traced capacities without a bucket)
    keep, w = token_gate(logits, scores, capacity, mode, theta=theta, mxu=mxu)
    y = f(x, positions)
    delta = y * w[..., None].astype(y.dtype)
    if mode == "train":
        return delta, RouteAux.of(topk=bce_topk_loss(logits, keep), keep=keep)
    return delta, RouteAux.of(keep=keep)


# --------------------- parameter subset selection ---------------------------

def param_router_init(key, d: int, m: int):
    w = jax.random.normal(key, (d, m), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w}


def param_route_weights(rp, x, top_k, normalize_to_m: bool = True,
                        valid=None):
    """Alg. 1: w = M * softmax(W_r x); top-k selection mask.

    ``top_k`` may be a python int (static) or a traced scalar/(B,) array
    (rank masking; one compiled graph for every k). ``valid`` (x's leading
    dims) excludes rows from the load-balance statistics — ragged bucket
    buffers pass their prefix mask so the padded tail (whose outputs are
    weighted to zero anyway) cannot skew the aux loss.
    Returns (weights (...,M) f32, mask (...,M) bool, aux RouteAux).
    With k == M and a uniform router this reproduces the base module exactly
    (weights == 1 everywhere) — the paper's losslessness property.
    """
    m = rp["w"].shape[-1]
    logits = x.astype(jnp.float32) @ rp["w"]            # (..., M)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * m if normalize_to_m else probs
    k = min(int(top_k), m) if is_static(top_k) else jnp.clip(top_k, 1, m)
    mask = topk_mask_any(w, k)
    # §B.2 load-balance: E_m[frac_selected(m) * mean_prob(m)] * M
    red = tuple(range(probs.ndim - 1))
    if valid is None:
        frac = jnp.mean(mask.astype(jnp.float32), axis=red)
        mean_p = jnp.mean(probs, axis=red)
    else:
        vw = valid.astype(jnp.float32)[..., None]
        denom = jnp.maximum(jnp.sum(vw), 1.0)
        frac = jnp.sum(mask * vw, axis=red) / denom
        mean_p = jnp.sum(probs * vw, axis=red) / denom
    load = m * jnp.sum(frac * mean_p)
    return w, mask, RouteAux.of(load=load)
