"""ElastiFormer routing primitives (the paper's Alg. 1 & 2 + §B).

Two schemes:
  * input subset selection  — scalar sigmoid router per token, top-k (k=c*T)
    during training, threshold theta at causal inference (§B.1), BCE aux loss.
  * parameter subset selection — M-way router, w = M*softmax(W_r x), top-k
    submodules, straight-through via output scaling, load-balance aux (§B.2).

Capacities and top-k counts come in two flavors (see core/policy.py):
  * python numbers — trace-time constants; the top-k *gather* path with real
    FLOP savings is available, at one compile per budget;
  * traced jnp scalars / (B,) arrays — rank-based validity *masking* at full
    shapes, so ONE compiled graph serves every budget (and mixed per-request
    budgets inside one batch). Any capacity >= 1 (or top-k >= M, or
    ``student <= 0``) short-circuits to the exact unrouted module: router
    weights are forced to 1, which is the paper's losslessness property.

All router math is float32 regardless of backbone dtype.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp



def _z():
    return jnp.zeros((), jnp.float32)


class RouteAux(NamedTuple):
    load: jnp.ndarray   # load-balance loss contribution (scalar)
    topk: jnp.ndarray   # BCE top-k consistency loss contribution (scalar)
    sel: jnp.ndarray    # sum over routers of selected-token fraction
    cnt: jnp.ndarray    # number of routers contributing to `sel`

    @staticmethod
    def zero():
        return RouteAux(_z(), _z(), _z(), _z())

    @staticmethod
    def of(load=None, topk=None, keep=None):
        """keep: bool selection mask -> records its mean as a sel-rate."""
        sel = cnt = None
        if keep is not None:
            sel = jnp.mean(keep.astype(jnp.float32))
            cnt = jnp.ones((), jnp.float32)
        return RouteAux(load if load is not None else _z(),
                        topk if topk is not None else _z(),
                        sel if sel is not None else _z(),
                        cnt if cnt is not None else _z())

    def __add__(self, o):
        return RouteAux(self.load + o.load, self.topk + o.topk,
                        self.sel + o.sel, self.cnt + o.cnt)

    @property
    def sel_rate(self):
        """Mean fraction of tokens processed across token routers."""
        return self.sel / jnp.maximum(self.cnt, 1.0)


# ----------------------- input subset selection -----------------------------

def token_router_init(key, d: int):
    w = jax.random.normal(key, (d,), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def token_logits(rp, x):
    """Scalar routing logits per token. x: (..., D) -> (...,) f32."""
    return x.astype(jnp.float32) @ rp["w"] + rp["b"]


def topk_indices(scores, k: int):
    """Top-k indices along the last axis, sorted ascending (causal order)."""
    _, idx = jax.lax.top_k(scores, k)
    return jnp.sort(idx, axis=-1)


def topk_mask(scores, k: int):
    """Boolean membership mask of the top-k entries along the last axis."""
    kth = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= kth


# ----------------- static/traced scalar plumbing (policy leaves) -------------

def is_static(v) -> bool:
    """True for python numbers (trace-time constants from the legacy
    ``ElasticConfig`` path); traced policy leaves are jnp arrays/tracers."""
    return isinstance(v, (int, float))


def bcast_to(v, ndim: int):
    """Right-pad a leading-dims value ((), (B,), ...) with singleton axes so
    it broadcasts against an (B, ..., n) tensor of rank ``ndim``."""
    if is_static(v):
        return v
    v = jnp.asarray(v)
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


def token_ranks(scores):
    """Descending rank of each entry along the last axis (0 = largest)."""
    return jnp.argsort(jnp.argsort(-scores, axis=-1), axis=-1)


def topk_mask_dyn(scores, k):
    """topk_mask with a *traced* k ((), or any leading-dims shape): an entry
    is kept iff its descending rank is < k. Ties broken by position."""
    return token_ranks(scores) < bcast_to(k, scores.ndim)


def topk_mask_any(scores, k):
    if is_static(k):
        return topk_mask(scores, int(k))
    return topk_mask_dyn(scores, k)


def capacity_k(capacity, s: int, mxu: bool = False):
    """ceil(capacity * s) clipped to [1, s]; python int when static.

    ``mxu``: on long sequences (s >= 1024) round the count up to a multiple
    of 128 (MXU-friendly gather sizes) — the traced path applies the SAME
    rule so one-graph masking selects exactly the tokens the static gather
    compile would have."""
    if is_static(capacity):
        k = int(math.ceil(capacity * s))
        if mxu and s >= 1024:
            k = min(s, -(-k // 128) * 128)
        return max(1, min(s, k))
    k = jnp.ceil(capacity * s)
    if mxu and s >= 1024:
        k = jnp.minimum(s, jnp.ceil(k / 128) * 128)
    return jnp.clip(k, 1, s)


def threshold_logit(theta):
    """Router-logit threshold equivalent to sigmoid(logit) > theta."""
    if is_static(theta):
        return math.log(theta / (1.0 - theta)) if 0.0 < theta < 1.0 \
            else (-jnp.inf if theta <= 0.0 else jnp.inf)
    theta = jnp.clip(jnp.asarray(theta, jnp.float32), 1e-6, 1.0 - 1e-6)
    return jnp.log(theta) - jnp.log1p(-theta)


def gate_capacity(capacity, student):
    """Teacher gating: ``student <= 0`` forces full capacity (exact teacher)."""
    if student is None:
        return capacity
    if is_static(student):
        return capacity if student > 0 else 1.0
    cap = capacity if not is_static(capacity) else jnp.asarray(
        capacity, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, cap, 1.0)


def gate_topk(k, student, n: int):
    """Teacher gating for parameter-subset top-k: student off -> all n."""
    if student is None:
        return k
    if is_static(student):
        return k if student > 0 else n
    kk = k if not is_static(k) else jnp.asarray(k, jnp.float32)
    return jnp.where(jnp.asarray(student) > 0, kk, n)


def is_full(v, limit=1.0):
    """capacity >= 1 (or top-k >= M): the knob requests the exact teacher.
    python bool when static, else a traced bool array."""
    if is_static(v):
        return v >= limit
    return jnp.asarray(v) >= limit


def token_gate(logits, scores, capacity, mode: str, *, theta=0.5,
               mxu: bool = False):
    """Unified keep-mask + router weight for input subset selection.

    Train: top-k by capacity (static fast path or traced rank masking; both
    use the same rounding — see ``capacity_k``'s ``mxu``).
    Infer: threshold theta on the router sigmoid (§B.1).
    Any capacity >= 1 forces (keep=all, weight=1) — exact teacher.
    Returns (keep bool (B,S), weight f32 (B,S)).
    """
    S = scores.shape[-1]
    if mode == "train":
        keep = topk_mask_any(scores, capacity_k(capacity, S, mxu=mxu))
    else:
        keep = logits > bcast_to(threshold_logit(theta), logits.ndim)
    full = is_full(capacity)
    if is_static(full):
        if full:
            return jnp.ones_like(keep, bool), jnp.ones_like(scores)
        return keep, keep * scores
    full = bcast_to(full, keep.ndim)
    keep = keep | full
    return keep, jnp.where(full, 1.0, keep * scores)


def bce_topk_loss(logits, in_topk):
    """§B.1 auxiliary loss: router sigmoid should predict top-k membership."""
    y = in_topk.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gather_tokens(x, idx):
    """x: (B,S,...) idx: (B,k) -> (B,k,...)."""
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 2)
    return jnp.take_along_axis(x, idx[expand], axis=1)


def scatter_add_tokens(shape_like, idx, vals):
    """Inverse of gather_tokens: zeros.at[b, idx].add(vals)."""
    y = jnp.zeros_like(shape_like)
    b = jnp.arange(y.shape[0])[:, None]
    return y.at[b, idx].add(vals.astype(y.dtype))


def route_tokens(
    rp,
    x,                      # (B, S, D)
    f: Callable,            # f(x_sub, positions_sub) -> (B, k(or S), D)
    capacity,               # None | python float (static) | traced scalar/(B,)
    mode: str,              # base | train | infer
    positions=None,         # (S,) int32 positions (for RoPE/causal inside f)
    impl: str = "gather",
    theta=0.5,              # inference threshold (policy.theta)
    student=None,           # policy.student: <=0 bypasses routing entirely
):
    """Input subset selection around a module f (residual added by caller).

    Returns (delta, aux). delta is f's (router-weighted) contribution.
    Static capacities keep the top-k gather path (smaller HLO, per-budget
    compile); traced capacities run dense with rank masking so one compiled
    graph serves every budget.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if capacity is None or mode == "base":
        return f(x, positions), RouteAux.zero()

    capacity = gate_capacity(capacity, student)
    logits = token_logits(rp, x)            # (B, S)
    scores = jax.nn.sigmoid(logits)

    if (mode == "train" and impl == "gather" and is_static(capacity)
            and is_static(theta) and capacity < 1.0):
        k = max(1, min(S, int(math.ceil(capacity * S))))
        idx = topk_indices(scores, k)        # (B, k) ascending
        x_sel = gather_tokens(x, idx)
        pos_sel = positions[idx] if positions.ndim == 1 else jnp.take_along_axis(positions, idx, 1)
        y_sel = f(x_sel, pos_sel)
        w_sel = jnp.take_along_axis(scores, idx, axis=1)
        y_sel = y_sel * w_sel[..., None].astype(y_sel.dtype)
        delta = scatter_add_tokens(x, idx, y_sel)
        mask = topk_mask(scores, k)
        return delta, RouteAux.of(topk=bce_topk_loss(logits, mask), keep=mask)

    # dense path: full-shape compute, rank/threshold masking (train w/
    # dense_mask impl, inference, and every traced-capacity case)
    keep, w = token_gate(logits, scores, capacity, mode, theta=theta)
    y = f(x, positions)
    delta = y * w[..., None].astype(y.dtype)
    if mode == "train":
        return delta, RouteAux.of(topk=bce_topk_loss(logits, keep), keep=keep)
    return delta, RouteAux.of(keep=keep)


# --------------------- parameter subset selection ---------------------------

def param_router_init(key, d: int, m: int):
    w = jax.random.normal(key, (d, m), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w}


def param_route_weights(rp, x, top_k, normalize_to_m: bool = True):
    """Alg. 1: w = M * softmax(W_r x); top-k selection mask.

    ``top_k`` may be a python int (static) or a traced scalar/(B,) array
    (rank masking; one compiled graph for every k).
    Returns (weights (...,M) f32, mask (...,M) bool, aux RouteAux).
    With k == M and a uniform router this reproduces the base module exactly
    (weights == 1 everywhere) — the paper's losslessness property.
    """
    m = rp["w"].shape[-1]
    logits = x.astype(jnp.float32) @ rp["w"]            # (..., M)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * m if normalize_to_m else probs
    k = min(int(top_k), m) if is_static(top_k) else jnp.clip(top_k, 1, m)
    mask = topk_mask_any(w, k)
    # §B.2 load-balance: E_m[frac_selected(m) * mean_prob(m)] * M
    red = tuple(range(probs.ndim - 1))
    frac = jnp.mean(mask.astype(jnp.float32), axis=red)
    mean_p = jnp.mean(probs, axis=red)
    load = m * jnp.sum(frac * mean_p)
    return w, mask, RouteAux.of(load=load)
