"""ElastiFormer routing primitives (the paper's Alg. 1 & 2 + §B).

Two schemes:
  * input subset selection  — scalar sigmoid router per token, top-k (k=c*T)
    during training, threshold 0.5 at causal inference (§B.1), BCE aux loss.
  * parameter subset selection — M-way router, w = M*softmax(W_r x), top-k
    submodules, straight-through via output scaling, load-balance aux (§B.2).

All router math is float32 regardless of backbone dtype.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp



def _z():
    return jnp.zeros((), jnp.float32)


class RouteAux(NamedTuple):
    load: jnp.ndarray   # load-balance loss contribution (scalar)
    topk: jnp.ndarray   # BCE top-k consistency loss contribution (scalar)
    sel: jnp.ndarray    # sum over routers of selected-token fraction
    cnt: jnp.ndarray    # number of routers contributing to `sel`

    @staticmethod
    def zero():
        return RouteAux(_z(), _z(), _z(), _z())

    @staticmethod
    def of(load=None, topk=None, keep=None):
        """keep: bool selection mask -> records its mean as a sel-rate."""
        sel = cnt = None
        if keep is not None:
            sel = jnp.mean(keep.astype(jnp.float32))
            cnt = jnp.ones((), jnp.float32)
        return RouteAux(load if load is not None else _z(),
                        topk if topk is not None else _z(),
                        sel if sel is not None else _z(),
                        cnt if cnt is not None else _z())

    def __add__(self, o):
        return RouteAux(self.load + o.load, self.topk + o.topk,
                        self.sel + o.sel, self.cnt + o.cnt)

    @property
    def sel_rate(self):
        """Mean fraction of tokens processed across token routers."""
        return self.sel / jnp.maximum(self.cnt, 1.0)


# ----------------------- input subset selection -----------------------------

def token_router_init(key, d: int):
    w = jax.random.normal(key, (d,), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def token_logits(rp, x):
    """Scalar routing logits per token. x: (..., D) -> (...,) f32."""
    return x.astype(jnp.float32) @ rp["w"] + rp["b"]


def topk_indices(scores, k: int):
    """Top-k indices along the last axis, sorted ascending (causal order)."""
    _, idx = jax.lax.top_k(scores, k)
    return jnp.sort(idx, axis=-1)


def topk_mask(scores, k: int):
    """Boolean membership mask of the top-k entries along the last axis."""
    kth = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= kth


def bce_topk_loss(logits, in_topk):
    """§B.1 auxiliary loss: router sigmoid should predict top-k membership."""
    y = in_topk.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def gather_tokens(x, idx):
    """x: (B,S,...) idx: (B,k) -> (B,k,...)."""
    expand = (slice(None), slice(None)) + (None,) * (x.ndim - 2)
    return jnp.take_along_axis(x, idx[expand], axis=1)


def scatter_add_tokens(shape_like, idx, vals):
    """Inverse of gather_tokens: zeros.at[b, idx].add(vals)."""
    y = jnp.zeros_like(shape_like)
    b = jnp.arange(y.shape[0])[:, None]
    return y.at[b, idx].add(vals.astype(y.dtype))


def route_tokens(
    rp,
    x,                      # (B, S, D)
    f: Callable,            # f(x_sub, positions_sub) -> (B, k(or S), D)
    capacity: Optional[float],
    mode: str,              # base | train | infer
    positions=None,         # (S,) int32 positions (for RoPE/causal inside f)
    impl: str = "gather",
):
    """Input subset selection around a module f (residual added by caller).

    Returns (delta, aux). delta is f's (router-weighted) contribution.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if capacity is None or mode == "base":
        return f(x, positions), RouteAux.zero()

    logits = token_logits(rp, x)            # (B, S)
    scores = jax.nn.sigmoid(logits)

    if mode == "infer":
        # §B.1: threshold 0.5 (== logit 0); dense compute, masked output.
        keep = (logits > 0.0)
        y = f(x, positions)
        delta = y * (keep * scores)[..., None].astype(y.dtype)
        return delta, RouteAux.of(keep=keep)

    k = max(1, min(S, int(math.ceil(capacity * S))))
    if impl == "dense_mask":
        mask = topk_mask(scores, k)
        y = f(x, positions)
        delta = y * (mask * scores)[..., None].astype(y.dtype)
    else:
        idx = topk_indices(scores, k)        # (B, k) ascending
        x_sel = gather_tokens(x, idx)
        pos_sel = positions[idx] if positions.ndim == 1 else jnp.take_along_axis(positions, idx, 1)
        y_sel = f(x_sel, pos_sel)
        w_sel = jnp.take_along_axis(scores, idx, axis=1)
        y_sel = y_sel * w_sel[..., None].astype(y_sel.dtype)
        delta = scatter_add_tokens(x, idx, y_sel)
        mask = topk_mask(scores, k)
    aux = RouteAux.of(topk=bce_topk_loss(logits, mask), keep=mask)
    return delta, aux


# --------------------- parameter subset selection ---------------------------

def param_router_init(key, d: int, m: int):
    w = jax.random.normal(key, (d, m), jnp.float32) * (1.0 / math.sqrt(d))
    return {"w": w}


def param_route_weights(rp, x, top_k: int, normalize_to_m: bool = True):
    """Alg. 1: w = M * softmax(W_r x); top-k selection mask.

    Returns (weights (...,M) f32, mask (...,M) bool, aux RouteAux).
    With k == M and a uniform router this reproduces the base module exactly
    (weights == 1 everywhere) — the paper's losslessness property.
    """
    m = rp["w"].shape[-1]
    logits = x.astype(jnp.float32) @ rp["w"]            # (..., M)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * m if normalize_to_m else probs
    mask = topk_mask(w, min(top_k, m))
    # §B.2 load-balance: E_m[frac_selected(m) * mean_prob(m)] * M
    red = tuple(range(probs.ndim - 1))
    frac = jnp.mean(mask.astype(jnp.float32), axis=red)
    mean_p = jnp.mean(probs, axis=red)
    load = m * jnp.sum(frac * mean_p)
    return w, mask, RouteAux.of(load=load)
