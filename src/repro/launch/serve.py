"""Serving driver: load (or init) a model + trained routers, run the elastic
threshold-routed decode over a stream of requests.

Per-request compute budgets ride on the traced ElasticPolicy: one compiled
decode step serves every budget, including mixed budgets inside one batch.

Closed loop (submit everything, drain):
    python -m repro.launch.serve --arch toy-lm --requests 16 --max-new 32
    python -m repro.launch.serve --arch toy-lm --budget 0.25,0.5,1.0

Open loop (continuous batching under Poisson arrivals; reports throughput,
per-request latency, and slot occupancy):
    python -m repro.launch.serve --arch toy-lm --arrival-rate 8 \
        --requests 32 --budget 0.4,0.8,1.0

SPMD serving (`--mesh data,model`): the engine runs across the mesh —
params by the TP name rules, KV caches kv-head-sharded, slots packed
per data replica — and the open-loop report breaks occupancy and latency
out per replica. `--remesh-at N` re-meshes the LIVE engine after the N-th
submission (to `--remesh-to`, or the next `valid_mesh_shapes` entry):
    python -m repro.launch.serve --arch toy-lm --mesh 2,4 \
        --arrival-rate 8 --requests 32 --remesh-at 16 --remesh-to 1,4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_elastic
from repro.models import model_init, router_init
from repro.runtime.elastic import make_mesh, valid_mesh_shapes
from repro.training import GenRequest, ServingEngine


def _budget_list(s: str):
    try:
        vals = [float(b) for b in s.split(",")]
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--budget expects a float or comma list of floats, got {s!r}")
    for v in vals:
        if not 0.0 < v <= 1.0:
            raise argparse.ArgumentTypeError(
                f"budgets must be fractions in (0, 1], got {v}")
    return vals


def _mesh_shape(s: str):
    try:
        d, m = (int(x) for x in s.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected a 'data,model' int pair, got {s!r}")
    if d < 1 or m < 1:
        raise argparse.ArgumentTypeError(f"mesh axes must be >= 1, got {s!r}")
    return (d, m)


def open_loop(engine, requests, rate: float, seed: int = 0, arrive=None,
              remesh_at=None, remesh_to=None):
    """Submit ``requests`` at Poisson arrival times (``rate`` req/s, or an
    explicit ``arrive`` schedule in seconds) while continuously stepping the
    engine; returns (handles, elapsed_seconds). Each handle's ``t_submit``
    is pinned to its *scheduled* arrival, so ``latency`` measures
    arrival -> last token (queueing included) — the same baseline a
    lockstep discipline is judged by.

    ``remesh_at=N``: after the N-th submission, re-mesh the LIVE engine to
    the ``remesh_to`` (data, model) shape — in-flight requests keep
    decoding the same tokens on the new mesh."""
    if arrive is None:
        rng = np.random.default_rng(seed)
        arrive = np.cumsum(rng.exponential(1.0 / rate, len(requests)))
    handles = [None] * len(requests)
    i, t0 = 0, time.perf_counter()
    remeshed = remesh_at is None
    while i < len(requests) or engine.has_work:
        now = time.perf_counter() - t0
        while i < len(requests) and arrive[i] <= now:
            handles[i] = engine.submit(requests[i])
            handles[i].t_submit = t0 + arrive[i]
            i += 1
        if not remeshed and i >= remesh_at:
            remeshed = True
            tm = time.perf_counter()
            engine.reshard(make_mesh(remesh_to, ("data", "model")))
            print(f"[serve] re-meshed live to (data, model)={remesh_to} "
                  f"after {i} submissions ({time.perf_counter() - tm:.2f}s, "
                  f"{engine.scheduler.active} requests in flight)")
        if engine.step() == 0 and i < len(requests):
            # idle: sleep until the next arrival
            wait = arrive[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.05))
    return handles, time.perf_counter() - t0


def latency_stats(handles) -> dict:
    """Latency columns (milliseconds) over SERVED handles — rejected /
    deadline-expired requests are excluded (their "latency" is time to
    rejection, not service). Columns: end-to-end mean/p50/p95, TTFT
    (arrival -> first token: queue wait + prefill) p50/p95, and
    inter-token latency (decode-step gap) mean/p95 — all sourced from the
    per-token timestamps on ``RequestHandle``."""
    done = [h for h in handles
            if h is not None and h.latency is not None
            and h.status != "rejected"]
    lat = np.asarray([h.latency for h in done], float)
    ttft = np.asarray([h.ttft for h in done if h.ttft is not None], float)
    itl = np.asarray([g for h in done for g in h.inter_token()], float)
    pct = lambda a, q: float(np.percentile(a, q) * 1e3) if a.size else 0.0
    return {
        "mean_ms": float(lat.mean() * 1e3) if lat.size else 0.0,
        "p50_ms": pct(lat, 50),
        "p95_ms": pct(lat, 95),
        "ttft_p50_ms": pct(ttft, 50),
        "ttft_p95_ms": pct(ttft, 95),
        "itl_mean_ms": float(itl.mean() * 1e3) if itl.size else 0.0,
        "itl_p95_ms": pct(itl, 95),
    }


def replica_report(engine, handles) -> str:
    """Per-replica occupancy + mean latency lines for the open-loop report
    (a handle's replica = the data shard its final slot lived on). After a
    live re-mesh the window is "since the re-mesh": the occupancy counters
    restart there (the old replica axis no longer exists), so requests that
    finished before it are excluded rather than re-attributed to replicas
    they never ran on."""
    sched = engine.scheduler
    t0 = engine.remeshed_at
    hs_all = [h for h in handles if h is not None and h.slot is not None
              and (t0 is None or h.t_done is None or h.t_done >= t0)]
    lines = [] if t0 is None else \
        [f"  (per-replica window: since the live re-mesh; "
         f"{len(handles) - len(hs_all)} earlier requests excluded)"]
    for r in range(sched.n_replicas):
        hs = [h for h in hs_all if sched.replica_of(h.slot) == r]
        st = latency_stats(hs)
        lines.append(
            f"  replica {r}: {len(hs)} requests, occupancy "
            f"{sched.replica_occupancy[r]:.0%}, e2e mean {st['mean_ms']:.0f}"
            f" / p50 {st['p50_ms']:.0f} / p95 {st['p95_ms']:.0f} ms, "
            f"ttft p95 {st['ttft_p95_ms']:.0f} ms, "
            f"itl p95 {st['itl_p95_ms']:.1f} ms")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-lm")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mode", default="infer", choices=["infer", "base"])
    ap.add_argument("--kv-layout", default="ring", choices=["ring", "paged"],
                    help="KV cache layout: 'ring' reserves max_seq per slot; "
                         "'paged' serves from a block-paged pool with prefix "
                         "sharing and chunked prefill (one compile for any "
                         "prompt length)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout only)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="total physical KV pages (default: ring-equivalent "
                         "HBM, i.e. batch * pages-per-full-sequence)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="KV cache storage dtype; int8 stores per-(token,"
                         "head) scales as sibling leaves and dequantizes "
                         "inside the decode kernels (docs/quantization.md)")
    ap.add_argument("--weight-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="base weight storage dtype; int8 quantizes per "
                         "output channel at engine init")
    ap.add_argument("--budget", default=None, type=_budget_list,
                    help="per-request compute budget(s) in (0,1]: a float, "
                         "or a comma list assigned round-robin (mixed "
                         "budgets batch together on one compiled step)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop mode: Poisson request arrivals at this "
                         "rate (req/s); reports per-request latency and "
                         "slot occupancy on top of throughput")
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "bursty", "diurnal"],
                    help="open-loop arrival process (benchmarks/workloads"
                         ".py): 'bursty' = 4x burst in the middle 40%% of "
                         "requests, 'diurnal' = sinusoidal rate around "
                         "--arrival-rate")
    ap.add_argument("--depth-routed", action="store_true",
                    help="enable the elastic depth router (per-token whole-"
                         "layer skip; docs/elastic_policy.md): budgets below "
                         "1.0 skip full blocks per token, decode skips write "
                         "no KV at that layer (per-layer validity masks)")
    ap.add_argument("--controller", action="store_true",
                    help="enable the SLO feedback controller (graceful "
                         "degradation: admission budgets -> in-flight "
                         "budgets -> load shedding -> remesh escalation; "
                         "docs/serving.md)")
    ap.add_argument("--slo-p95-ms", type=float, default=None,
                    help="p95 TTFT SLO target in ms for the default class "
                         "(implies --controller; default 500)")
    ap.add_argument("--slo-floor", type=float, default=0.25,
                    help="lowest budget the controller may degrade to")
    ap.add_argument("--flop-budget", type=float, default=None,
                    help="per-replica, per-step FLOP admission budget in "
                         "full-budget-row units (default: slots per "
                         "replica, i.e. slot-limited; without --mesh the "
                         "single replica holds all --batch slots)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the top-k logits (0 = all)")
    ap.add_argument("--eos", type=int, default=None,
                    help="stop token id (default: config eos_id)")
    ap.add_argument("--mesh", type=_mesh_shape, default=None,
                    help="run SPMD on a 'data,model' mesh (e.g. 2,4): TP "
                         "over `model`, the slot array split into `data` "
                         "replicas the scheduler packs independently")
    ap.add_argument("--remesh-at", type=int, default=None,
                    help="after this many submissions, re-mesh the LIVE "
                         "engine (open-loop only; in-flight requests "
                         "resume with identical tokens)")
    ap.add_argument("--remesh-to", type=_mesh_shape, default=None,
                    help="target 'data,model' shape for --remesh-at "
                         "(default: the next valid_mesh_shapes entry)")
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None:
        if args.batch % args.mesh[0]:
            ap.error(f"--batch {args.batch} must be a multiple of the mesh "
                     f"data axis {args.mesh[0]}")
        mesh = make_mesh(args.mesh, ("data", "model"))
    if args.remesh_at is not None:
        if args.mesh is None or args.arrival_rate is None:
            ap.error("--remesh-at requires --mesh and --arrival-rate")
        if args.remesh_to is None:
            n_dev = args.mesh[0] * args.mesh[1]
            cands = [s for s in valid_mesh_shapes(n_dev, args.mesh[1])
                     if s != tuple(args.mesh) and args.batch % s[0] == 0]
            if not cands:
                ap.error(f"no alternative mesh shape for {args.mesh} whose "
                         f"data axis divides --batch {args.batch}")
            args.remesh_to = cands[0]
        elif args.batch % args.remesh_to[0]:
            # fail at argparse time, not mid-serve with requests in flight
            ap.error(f"--batch {args.batch} must be a multiple of the "
                     f"--remesh-to data axis {args.remesh_to[0]}")

    cfg = get_config(args.arch, args.variant)
    ecfg = get_elastic(args.arch, cfg)
    if args.kv_layout == "paged" and ecfg is not None \
            and getattr(ecfg, "mlp_n_experts", 0):
        # paged prefill is chunked; moefied expert-capacity buffers depend
        # on the chunking, so the paged engine requires a dense MLP
        print(f"[serve] --kv-layout paged: dropping mlp_n_experts="
              f"{ecfg.mlp_n_experts} (dense MLP required; see docs/paged_kv.md)")
        ecfg = dataclasses.replace(ecfg, mlp_n_experts=0, mlp_expert_topk=0)
    if args.depth_routed and ecfg is not None:
        # depth_capacity=1.0 enables the router (spec.depth_routed) while the
        # default policy stays teacher-exact; budgets/controller lower it live
        ecfg = dataclasses.replace(ecfg, depth_capacity=1.0)
    controller = None
    if args.controller or args.slo_p95_ms is not None:
        from repro.runtime.controller import SLOController, SLOTarget
        slo_ms = args.slo_p95_ms if args.slo_p95_ms is not None else 500.0
        controller = SLOController(
            targets={"default": SLOTarget(p95_ttft_ms=slo_ms)},
            floor=args.slo_floor)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    engine = ServingEngine(params, rp, cfg, ecfg, mode=args.mode,
                           controller=controller,
                           batch_size=args.batch,
                           max_seq=args.prompt_len + args.max_new,
                           eos_id=args.eos,
                           step_flop_budget=args.flop_budget,
                           mesh=mesh, kv_layout=args.kv_layout,
                           page_size=args.page_size, n_pages=args.n_pages,
                           kv_dtype=args.kv_dtype,
                           weight_dtype=args.weight_dtype)
    budgets = args.budget
    rng = np.random.default_rng(0)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32), args.max_new,
                       budget=(budgets[i % len(budgets)] if budgets else None),
                       temperature=args.temperature, top_k=args.top_k,
                       seed=i)
            for i in range(args.requests)]

    if args.arrival_rate is not None:
        arrive = None
        if args.trace != "poisson":
            try:
                from benchmarks.workloads import arrival_times
            except ImportError:     # not launched from the repo root
                import pathlib
                import sys
                sys.path.insert(
                    0, str(pathlib.Path(__file__).resolve().parents[3]))
                from benchmarks.workloads import arrival_times
            arrive = arrival_times(args.trace, args.arrival_rate,
                                   len(reqs), seed=0)
        # warm the compile caches outside the timed window
        engine.generate([reqs[0]])
        engine.scheduler.reset_stats()
        handles, dt = open_loop(engine, reqs, args.arrival_rate,
                                arrive=arrive,
                                remesh_at=args.remesh_at,
                                remesh_to=args.remesh_to)
        n_tok = sum(len(h.output) for h in handles)
        st = latency_stats(handles)
        print(f"open loop: {len(reqs)} requests @ {args.arrival_rate} req/s "
              f"({args.trace}), {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s)")
        print(f"latency: e2e mean {st['mean_ms']:.0f} / p50 "
              f"{st['p50_ms']:.0f} / p95 {st['p95_ms']:.0f} ms; "
              f"ttft p50 {st['ttft_p50_ms']:.0f} / p95 "
              f"{st['ttft_p95_ms']:.0f} ms; itl mean "
              f"{st['itl_mean_ms']:.1f} / p95 {st['itl_p95_ms']:.1f} ms; "
              f"slot occupancy {engine.occupancy:.0%} "
              f"(budgets={budgets or 'config-default'})")
        if controller is not None:
            cs = controller.summary()
            served = sum(h.status == "done" for h in handles)
            print(f"controller: admission {cs['admission_budget']:.2f}, "
                  f"depth {cs['depth_budget']:.2f}, "
                  f"inflight {cs['inflight_budget']:.2f} after "
                  f"{cs['evals']} evals; events {cs['events'] or '{}'}; "
                  f"served {served}, shed {engine.n_rejected}, expired "
                  f"{engine.n_expired} (slo p95 ttft "
                  f"{controller.target_for('default').p95_ttft_ms:.0f} ms)")
        if engine.scheduler.n_replicas > 1 or mesh is not None:
            print(replica_report(engine, handles))
    else:
        t0 = time.perf_counter()
        outs = engine.generate(reqs)
        dt = time.perf_counter() - t0
        n_tok = sum(len(o) for o in outs)
        print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s, mode={args.mode}, "
              f"budgets={budgets or 'config-default'})")
        print("sample output:", outs[0][:16])
    print(f"compiles: {engine.compile_counts()} (budgets, slots, and "
          f"sampling knobs never recompile)")
    if args.kv_layout == "paged":
        st = engine.paged_stats()
        print(f"paged pool: peak {st['peak_allocated']}/{st['usable']} pages "
              f"(page_size={st['page_size']}, "
              f"{st['registered_prefixes']} prefixes registered)")


if __name__ == "__main__":
    main()
