"""Batched serving driver: load (or init) a model + trained routers, run the
elastic threshold-routed decode over a stream of requests.

Per-request compute budgets ride on the traced ElasticPolicy: one compiled
decode step serves every budget, including mixed budgets inside one batch.

python -m repro.launch.serve --arch toy-lm --requests 16 --max-new 32
python -m repro.launch.serve --arch toy-lm --budget 0.5
python -m repro.launch.serve --arch toy-lm --budget 0.25,0.5,1.0   # round-robin
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_elastic
from repro.models import model_init, router_init
from repro.training import GenRequest, ServingEngine


def _budget_list(s: str):
    try:
        vals = [float(b) for b in s.split(",")]
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--budget expects a float or comma list of floats, got {s!r}")
    for v in vals:
        if not 0.0 < v:
            raise argparse.ArgumentTypeError(
                f"budgets must be positive fractions, got {v}")
    return vals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-lm")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mode", default="infer", choices=["infer", "base"])
    ap.add_argument("--budget", default=None, type=_budget_list,
                    help="per-request compute budget(s) in (0,1]: a float, "
                         "or a comma list assigned round-robin (mixed "
                         "budgets batch together on one compiled step)")
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    ecfg = get_elastic(args.arch, cfg)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    engine = ServingEngine(params, rp, cfg, ecfg, mode=args.mode,
                           batch_size=args.batch,
                           max_seq=args.prompt_len + args.max_new)
    budgets = args.budget
    rng = np.random.default_rng(0)
    reqs = [GenRequest(rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32), args.max_new,
                       budget=(budgets[i % len(budgets)] if budgets else None))
            for i in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, mode={args.mode}, "
          f"budgets={budgets or 'config-default'})")
    print(f"compiles: {engine.compile_counts()} (budgets never recompile)")
    print("sample output:", outs[0][:16])


if __name__ == "__main__":
    main()
