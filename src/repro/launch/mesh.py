"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module constant, so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (one v5e pod);
multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
