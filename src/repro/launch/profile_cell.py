import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Lower one (arch x shape) cell and print an HLO-derived profile:
top op-kinds by output bytes, biggest single tensors, collective schedule.
This is the evidence base for each §Perf iteration.

Usage: PYTHONPATH=src python -m repro.launch.profile_cell --arch qwen2-7b \
           --shape train_4k [--multi-pod] [--unrolled]
"""
import argparse

from repro.configs import SHAPES, get_config, get_elastic
from repro.launch import dryrun as DR
from repro.launch.hloprof import biggest_tensors, profile_text, top_table
from repro.launch.mesh import make_production_mesh
from repro.models import build_pattern, flags


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unrolled", action="store_true",
                    help="profile the 1-period unrolled clone (faster, "
                    "per-layer attribution) instead of the full scan")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    ecfg = get_elastic(args.arch, cfg)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    if args.unrolled:
        cfg1 = DR.scale_layers(cfg, ecfg, 1)
        with flags.analysis_unroll():
            with mesh:
                compiled = DR.lower_cell(cfg1, ecfg, shape, mesh, shape.kind)
        period, _, _ = build_pattern(cfg, ecfg)
        print(f"# unrolled clone: {cfg1.n_layers} layers "
              f"(1 period of {len(period)}; full model {cfg.n_layers})")
    else:
        with mesh:
            compiled = DR.lower_cell(cfg, ecfg, shape, mesh, shape.kind)

    txt = compiled.as_text()
    print(f"\n== {args.arch} x {args.shape} "
          f"{'pod2x16x16' if args.multi_pod else 'pod16x16'} ==")
    ma = compiled.memory_analysis()
    print(f"memory: arg {ma.argument_size_in_bytes / 1e9:.2f} GB  "
          f"temp {ma.temp_size_in_bytes / 1e9:.2f} GB")
    ca = compiled.cost_analysis() or {}
    print(f"cost_analysis: flops {ca.get('flops', 0) / 1e12:.2f}T  "
          f"bytes {ca.get('bytes accessed', 0) / 1e9:.2f} GB")
    print("\n-- top op kinds by output bytes --")
    print(top_table(profile_text(txt), n=args.top))
    print("\n-- biggest single tensors --")
    for b, op, shp in biggest_tensors(txt, 15):
        print(f"{b / 1e9:9.3f} GB  {op:18s} {shp}")
    print("\n-- collectives --")
    for op, rec in sorted(DR.parse_collectives(txt).items()):
        print(f"{op:20s} count={rec['count']:5d} "
              f"bytes={rec['bytes'] / 1e9:.2f} GB")


if __name__ == "__main__":
    main()
