"""Poor-man's HLO profiler for the dry-run (no hardware, no traces).

Parses an HLO text module and attributes bytes (operand+output, from the
shape annotations) per op kind, plus collective counts/bytes. This is the
"profile" the §Perf hillclimb iterates against: it localizes WHICH ops
produce the cost_analysis aggregates (e.g. a dense (B,H,S,S) score tensor,
a resharding transpose, a remat-duplicated matmul).

Usage:
    from repro.launch.hloprof import profile_text, top_table
    prof = profile_text(compiled.as_text())
    print(top_table(prof, n=25))
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# one HLO instruction:  %name = <shape(s)> opcode(...operands/metadata...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
    r"((?:\(?[a-z0-9]+\[[0-9,]*\][^\s\)]*\)?,?\s*)+)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def profile_text(hlo: str) -> dict:
    """opcode -> {count, bytes, moved}; ``bytes`` = output shape bytes (a
    good HBM-write proxy; reads show up as some producer's out_bytes),
    ``moved`` = output + operand bytes (the bytes-touched roofline proxy
    the analysis passes and §Perf hillclimbs rank ops by — compiled HLO
    annotates every operand with its type, so reads are attributable
    per-consumer, not just per-producer)."""
    agg = defaultdict(lambda: {"count": 0, "bytes": 0, "moved": 0})
    for m in _INSTR_RE.finditer(hlo):
        shp, op, tail = m.group(1), m.group(2), m.group(3)
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            continue
        rec = agg[op]
        out = shape_bytes(shp)
        rec["count"] += 1
        rec["bytes"] += out
        # operand reads: every type annotation in the operand list (the
        # metadata tail carries no shape-typed tokens; unknown "dtypes"
        # like sharding device lists are skipped by shape_bytes)
        rec["moved"] += out + shape_bytes(tail.split(", metadata=")[0])
    return dict(agg)


def bytes_moved(hlo: str) -> int:
    """Total bytes touched (reads + writes) across the module — the
    memory-bound cost the FLOPs metric misses. Decode-step regressions
    show up here first (e.g. an unpinned cache write that re-materializes
    the whole slot array doubles this without changing flops)."""
    return sum(v["moved"] for v in profile_text(hlo).values())


# -------------------- input/output aliasing (donation) -----------------------

_ALIAS_SEG_RE = re.compile(r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}")
_ALIAS_PAIR_RE = re.compile(r"\{([0-9, ]*)\}:\s*\((\d+)")


def input_output_alias(hlo: str) -> dict:
    """Parse the compiled module's ``input_output_alias`` header into
    {param_index: output_tuple_index}. Empty when nothing is donated —
    which for a serving decode step means every call COPIES the KV cache;
    the analysis ``donation`` pass gates on this."""
    m = _ALIAS_SEG_RE.search(hlo)
    if not m:
        return {}
    out = {}
    for pair in _ALIAS_PAIR_RE.finditer(m.group(1)):
        out_idx = tuple(int(x) for x in pair.group(1).split(",") if x.strip())
        out[int(pair.group(2))] = out_idx
    return out


def entry_param_types(hlo: str) -> list:
    """Entry parameter type strings (e.g. ``f32[2,32,4,32]``) in parameter
    order, from ``entry_computation_layout`` — the positional key for
    matching donated params back to the caller's buffers."""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", hlo)
    if not m:
        return []
    return [f"{dt}[{dims}]" for dt, dims in _SHAPE_RE.findall(m.group(1))]


def donated_param_types(hlo: str) -> list:
    """Type strings of the donated (input/output-aliased) entry params."""
    types = entry_param_types(hlo)
    return [types[i] for i in sorted(input_output_alias(hlo))
            if i < len(types)]


_JAX2HLO = {"float32": "f32", "float64": "f64", "bfloat16": "bf16",
            "float16": "f16", "int8": "s8", "uint8": "u8", "int16": "s16",
            "uint16": "u16", "int32": "s32", "uint32": "u32", "int64": "s64",
            "uint64": "u64", "bool": "pred"}


def cache_read_bytes(hlo: str, caches) -> int:
    """Bytes of the compiled module's entry params that ARE the KV-cache
    leaves, matched by dtype+shape type string — the per-call HBM read
    cost of the cache (every leaf is threaded in whole each step). A
    quantized cache counts its int8 pools PLUS the f32 scale leaves, so
    the ratio against the fp32 cache is the honest bandwidth win the
    ``bytes_read`` bench column gates on."""
    import jax
    want = defaultdict(int)
    for leaf in jax.tree.leaves(caches):
        dt = _JAX2HLO.get(str(leaf.dtype))
        if dt is not None:
            want[f"{dt}[{','.join(map(str, leaf.shape))}]"] += 1
    total = 0
    for ts in entry_param_types(hlo):
        if want.get(ts, 0) > 0:
            want[ts] -= 1
            total += shape_bytes(ts)
    return total


def biggest_tensors(hlo: str, n: int = 15):
    """The n largest single instruction outputs (op, bytes, shape-str)."""
    out = []
    for m in _INSTR_RE.finditer(hlo):
        shp, op = m.group(1), m.group(2)
        if op in ("parameter", "constant", "get-tuple-element", "tuple"):
            continue
        out.append((shape_bytes(shp), op, shp.strip()[:90]))
    out.sort(reverse=True)
    return out[:n]


def compiled_flops(compiled) -> float:
    """Total lowered FLOPs of a jax ``Compiled`` (XLA cost analysis).
    This is the number the ragged FLOP-regression gate asserts on: a
    capacity-bucket compile must lower FEWER flops at lower budgets."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))


def lowered_flops(fn, *args, static_argnames=(), **kwargs) -> float:
    """jit-lower ``fn`` on ``args``/``kwargs`` and return its compiled FLOPs
    (no execution). ``static_argnames`` forwards to jax.jit — pass the
    ragged ``bucket`` through it."""
    import jax
    jitted = jax.jit(fn, static_argnames=static_argnames)
    return compiled_flops(jitted.lower(*args, **kwargs).compile())


def top_table(prof: dict, n: int = 20) -> str:
    rows = sorted(prof.items(), key=lambda kv: -kv[1]["bytes"])[:n]
    total = sum(v["bytes"] for v in prof.values())
    lines = [f"{'opcode':24s} {'count':>8s} {'GB_out':>10s} {'%':>6s}"]
    for op, v in rows:
        lines.append(f"{op:24s} {v['count']:8d} {v['bytes'] / 1e9:10.2f} "
                     f"{100 * v['bytes'] / max(total, 1):6.1f}")
    lines.append(f"{'TOTAL':24s} {'':8s} {total / 1e9:10.2f}")
    return "\n".join(lines)
