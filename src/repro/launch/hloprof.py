"""Poor-man's HLO profiler for the dry-run (no hardware, no traces).

Parses an HLO text module and attributes bytes (operand+output, from the
shape annotations) per op kind, plus collective counts/bytes. This is the
"profile" the §Perf hillclimb iterates against: it localizes WHICH ops
produce the cost_analysis aggregates (e.g. a dense (B,H,S,S) score tensor,
a resharding transpose, a remat-duplicated matmul).

Usage:
    from repro.launch.hloprof import profile_text, top_table
    prof = profile_text(compiled.as_text())
    print(top_table(prof, n=25))
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# one HLO instruction:  %name = <shape(s)> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
    r"((?:\(?[a-z0-9]+\[[0-9,]*\][^\s\)]*\)?,?\s*)+)\s*"
    r"([a-z][a-z0-9\-]*)\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def profile_text(hlo: str) -> dict:
    """opcode -> {count, out_bytes}; out_bytes = output shape bytes (a good
    HBM-write proxy; reads show up as some producer's out_bytes)."""
    agg = defaultdict(lambda: {"count": 0, "bytes": 0})
    for m in _INSTR_RE.finditer(hlo):
        shp, op = m.group(1), m.group(2)
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            continue
        rec = agg[op]
        rec["count"] += 1
        rec["bytes"] += shape_bytes(shp)
    return dict(agg)


def biggest_tensors(hlo: str, n: int = 15):
    """The n largest single instruction outputs (op, bytes, shape-str)."""
    out = []
    for m in _INSTR_RE.finditer(hlo):
        shp, op = m.group(1), m.group(2)
        if op in ("parameter", "constant", "get-tuple-element", "tuple"):
            continue
        out.append((shape_bytes(shp), op, shp.strip()[:90]))
    out.sort(reverse=True)
    return out[:n]


def compiled_flops(compiled) -> float:
    """Total lowered FLOPs of a jax ``Compiled`` (XLA cost analysis).
    This is the number the ragged FLOP-regression gate asserts on: a
    capacity-bucket compile must lower FEWER flops at lower budgets."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))


def lowered_flops(fn, *args, static_argnames=(), **kwargs) -> float:
    """jit-lower ``fn`` on ``args``/``kwargs`` and return its compiled FLOPs
    (no execution). ``static_argnames`` forwards to jax.jit — pass the
    ragged ``bucket`` through it."""
    import jax
    jitted = jax.jit(fn, static_argnames=static_argnames)
    return compiled_flops(jitted.lower(*args, **kwargs).compile())


def top_table(prof: dict, n: int = 20) -> str:
    rows = sorted(prof.items(), key=lambda kv: -kv[1]["bytes"])[:n]
    total = sum(v["bytes"] for v in prof.values())
    lines = [f"{'opcode':24s} {'count':>8s} {'GB_out':>10s} {'%':>6s}"]
    for op, v in rows:
        lines.append(f"{op:24s} {v['count']:8d} {v['bytes'] / 1e9:10.2f} "
                     f"{100 * v['bytes'] / max(total, 1):6.1f}")
    lines.append(f"{'TOTAL':24s} {'':8s} {total / 1e9:10.2f}")
    return "\n".join(lines)
