import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), record
memory_analysis / cost_analysis / the collective schedule, and derive the
three roofline terms.

Roofline accounting: XLA's HloCostAnalysis counts a while (lax.scan) body
ONCE regardless of trip count, so FLOPs/bytes/collective-bytes are taken
from two fully-unrolled shallow clones (1 and 2 pattern-periods deep,
flags.analysis_unroll) and extrapolated exactly:

    per_period = U2 - U1;   outside = U1 - per_period
    total(L)   = outside + (L / period_len) * per_period

The full-depth *scanned* compile (the production program) provides the
memory_analysis fits-proof and the collective schedule, and is what must
compile for the cell to PASS.

Usage:
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED, SHAPES, get_config, get_elastic,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models import (batch_specs, build_pattern, cache_specs,
                          decode_step, model_init, prefill, router_init)
from repro.models import flags
from repro.optim import cosine_schedule
from repro.runtime import sharding as SH
from repro.training import init_train_state, make_train_step

# TPU v5e hardware constants (assignment-mandated)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\.]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group(2).lower()
        b = _shape_bytes(m.group(1))
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


# ------------------------------ lowering ------------------------------------

def scale_layers(cfg, ecfg, k_periods: int):
    period, _, _ = build_pattern(cfg, ecfg)
    new = dataclasses.replace(cfg, n_layers=k_periods * len(period))
    if cfg.encoder is not None:
        ep, _, _ = build_pattern(cfg.encoder, ecfg)
        new = dataclasses.replace(
            new, encoder=dataclasses.replace(
                cfg.encoder, n_layers=k_periods * len(ep)))
    return new


def _abstract_state(cfg, ecfg):
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: model_init(key, cfg, ecfg))
    rp = jax.eval_shape(lambda: router_init(key, cfg, ecfg))
    return params, rp


def _replicated_tree(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lower_cell(cfg, ecfg, shape, mesh, kind: str, microbatch=None):
    """Build (fn, arg_shapes, in_shardings) and lower+compile. Returns the
    compiled object."""
    params, rp = _abstract_state(cfg, ecfg)
    p_sh = SH.param_shardings(params, mesh)
    rp_sh = _replicated_tree(rp, mesh)
    B, S = shape.global_batch, shape.seq_len

    if kind == "train":
        step = make_train_step(cfg, ecfg, lr=cosine_schedule(1e-4, 1000),
                               mesh=mesh, remat=True, chunked=True,
                               microbatch=microbatch)
        state = jax.eval_shape(init_train_state, rp)
        batch = batch_specs(cfg, S, B, "train")
        lowered = jax.jit(step, in_shardings=(
            _replicated_tree(state, mesh), p_sh,
            SH.input_shardings(batch, mesh),
        )).lower(state, params, batch)
    elif kind == "prefill":
        fn = partial(prefill, cfg=cfg, ecfg=ecfg, mode="infer",
                     max_cache_len=S)
        batch = batch_specs(cfg, S, B, "prefill")
        lowered = jax.jit(lambda p, r, b: fn(p, r, b), in_shardings=(
            p_sh, rp_sh, SH.input_shardings(batch, mesh),
        )).lower(params, rp, batch)
    elif kind == "decode":
        fn = partial(decode_step, cfg=cfg, ecfg=ecfg, mode="infer")
        caches = cache_specs(cfg, B, S)
        c_sh = SH.cache_shardings(caches, cfg, mesh)
        token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        t = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(
            lambda p, r, tok, c, tt: fn(p, r, tok, c, tt),
            in_shardings=(p_sh, rp_sh,
                          SH.fitted(SH.batch_spec(mesh, 1), (B, 1), mesh),
                          c_sh, NamedSharding(mesh, P())),
        ).lower(params, rp, token, caches, t)
    else:
        raise ValueError(kind)
    return lowered.compile()


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = parse_collectives(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(c["bytes"] for c in colls.values())),
        "collectives": colls,
    }


def model_flops(cfg, shape, kind: str) -> float:
    """Analytic useful FLOPs: parameter matmuls (2N/token) PLUS the
    quadratic attention term (2·ctx·H·Dh per token per attn layer for each
    of QK^T and PV) — without it, long-context cells report a bogus
    useful_flop_ratio (attention dominates 32k+ prefill)."""
    n = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (1 if kind == "decode" else S)
    # average context seen by a query token
    ctx = {"train": S / 2, "prefill": S / 2, "decode": S}[kind]
    attn_per_tok = 0.0
    for i, k in enumerate(cfg.layer_kinds):
        w = cfg.layer_windows[i]
        c = min(ctx, w) if (w and w > 0) else ctx
        if k in ("attn", "xattn"):
            attn_per_tok += 2 * 2 * c * cfg.n_heads * cfg.d_head
        if k == "xattn":  # cross attention over the encoder/image context
            enc = cfg.n_image_tokens or cfg.encoder_seq or 0
            attn_per_tok += 2 * 2 * enc * cfg.n_heads * cfg.d_head
    fwd = 2 * n * tokens + attn_per_tok * tokens
    mult = 3 if kind == "train" else 1   # teacher fwd + student fwd + bwd
    return mult * fwd


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_roofline: bool = False, variant: str = "baseline",
             microbatch=None):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_tag), exist_ok=True)
    path = os.path.join(out_dir, mesh_tag, f"{arch}__{shape_name}__{variant}.json")
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "variant": variant, "kind": shape.kind, "status": "running"}
    if not shape_applicable(arch, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k requires a sub-quadratic mixer; this is "
                        "a pure full-attention architecture (DESIGN.md §5)")
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[dryrun] {arch} x {shape_name}: SKIPPED (full attention)")
        return rec

    cfg = get_config(arch)
    ecfg = get_elastic(arch, cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    try:
        t0 = time.time()
        with mesh:
            compiled = lower_cell(cfg, ecfg, shape, mesh, shape.kind,
                                  microbatch=microbatch)
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "total_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes) / 1e9,
        }
        rec["fits_hbm16"] = rec["memory"]["total_gb"] < 16.0
        sc = _cost(compiled)
        rec["scanned_cost"] = {k: sc[k] for k in ("flops", "bytes",
                                                  "coll_bytes")}
        rec["collective_schedule"] = sc["collectives"]
        del compiled

        if not multi_pod and not skip_roofline:
            period, _, _ = build_pattern(cfg, ecfg)
            with flags.analysis_unroll():
                with mesh:
                    c1 = _cost(lower_cell(scale_layers(cfg, ecfg, 1), ecfg,
                                          shape, mesh, shape.kind,
                                          microbatch=microbatch))
                    c2 = _cost(lower_cell(scale_layers(cfg, ecfg, 2), ecfg,
                                          shape, mesh, shape.kind,
                                          microbatch=microbatch))
            nper = cfg.n_layers / len(period)
            terms = {}
            for key in ("flops", "bytes", "coll_bytes"):
                per = c2[key] - c1[key]
                outside = c1[key] - per
                terms[key] = max(0.0, outside + nper * per)
            # cost_analysis is per-device (post-SPMD module)
            t_comp = terms["flops"] / PEAK_FLOPS
            t_mem = terms["bytes"] / HBM_BW
            t_coll = terms["coll_bytes"] / ICI_BW
            mf = model_flops(cfg, shape, shape.kind)
            rec["roofline"] = {
                "hlo_flops_per_dev": terms["flops"],
                "hlo_bytes_per_dev": terms["bytes"],
                "coll_bytes_per_dev": terms["coll_bytes"],
                "t_compute_s": t_comp,
                "t_memory_s": t_mem,
                "t_collective_s": t_coll,
                "dominant": max(
                    [("compute", t_comp), ("memory", t_mem),
                     ("collective", t_coll)], key=lambda kv: kv[1])[0],
                "model_flops_total": mf,
                "model_flops_per_dev": mf / n_chips,
                "useful_flop_ratio": (mf / n_chips) / max(terms["flops"], 1.0),
                "roofline_fraction": min(
                    1.0, (mf / n_chips / PEAK_FLOPS)
                    / max(t_comp, t_mem, t_coll, 1e-12)),
            }
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    json.dump(rec, open(path, "w"), indent=1)
    dom = rec.get("roofline", {}).get("dominant", "-")
    print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: {rec['status']} "
          f"(mem {rec.get('memory', {}).get('total_gb', 0):.2f} GB/dev, "
          f"dominant={dom})", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    archs = ASSIGNED if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(args.out, tag,
                                    f"{arch}__{shape}__{args.variant}.json")
                if args.skip_existing and os.path.exists(path):
                    st = json.load(open(path)).get("status")
                    if st in ("ok", "skipped"):
                        continue
                run_cell(arch, shape, mp, args.out,
                         skip_roofline=args.skip_roofline,
                         variant=args.variant, microbatch=args.microbatch)


if __name__ == "__main__":
    main()
