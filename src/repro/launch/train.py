"""Distributed ElastiFormer self-distillation training driver.

Wires together: config registry -> mesh -> sharded frozen base model ->
router init -> distillation train step -> fault-tolerant supervised loop
(checkpoint/restart, straggler watchdog) -> deterministic sharded data.

On this CPU container it is exercised end-to-end with smoke configs and a
(1,1) mesh (tests/test_train_loop.py, examples/train_elastic_lm.py); on a
pod the same code runs under the production mesh from launch/mesh.py.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, get_elastic
from repro.core.policy import (as_spec_policy, capacity_anneal, ragged_bucket,
                               solve_budget)
from repro.data import LMDataPipeline
from repro.launch.mesh import make_production_mesh
from repro.models import model_init, router_init, router_param_count
from repro.optim import cosine_schedule
from repro.runtime import (FailureInjector, StragglerWatchdog, make_mesh,
                           run_resilient)
from repro.runtime import sharding as SH
from repro.training import TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")


def build_trainer(arch: str, *, variant: str = "full", mesh=None,
                  lr: float = 1e-4, total_steps: int = 1000,
                  seq_len: int = 512, global_batch: int = 32,
                  remat: bool = True, compression: bool = False,
                  seed: int = 0, ecfg=None):
    cfg = get_config(arch, variant)
    ecfg = ecfg or get_elastic(arch, cfg)
    key = jax.random.PRNGKey(seed)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    log.info("base params: %.3fM frozen; router params: %d (%.5f%%)",
             sum(x.size for x in jax.tree.leaves(params)) / 1e6,
             router_param_count(rp),
             100 * router_param_count(rp)
             / max(1, sum(x.size for x in jax.tree.leaves(params))))
    if mesh is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params,
            SH.param_shardings(params, mesh))
    state = init_train_state(rp, use_compression=compression)
    step_fn = jax.jit(make_train_step(
        cfg, ecfg, lr=cosine_schedule(lr, total_steps), mesh=mesh,
        remat=remat, chunked=cfg.vocab_size > 0,
        compress_axis="pod" if (compression and mesh is not None
                                and "pod" in mesh.axis_names) else None),
        donate_argnums=(0,), static_argnames=("bucket",))
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    return cfg, ecfg, params, state, step_fn, pipe


def train(arch: str, *, variant: str = "smoke", total_steps: int = 100,
          seq_len: int = 128, global_batch: int = 8, lr: float = 1e-3,
          ckpt_dir: str = "/tmp/repro_ckpt", save_every: int = 25,
          use_mesh: bool = False, multi_pod: bool = False,
          inject_failures: tuple = (), seed: int = 0,
          budget: float = None, anneal_from: float = None,
          anneal_steps: int = None):
    """``budget``: target compute budget; capacities come from the roofline
    budget solver instead of the config defaults. ``anneal_from``: start the
    distillation near that budget and anneal linearly to ``budget`` over
    ``anneal_steps`` (default: all steps). The policy is a *traced* argument
    of the jitted train step, so the whole schedule runs on ONE compile."""
    mesh = make_production_mesh(multi_pod=multi_pod) if use_mesh else None
    cfg, ecfg, params, state, step_fn, pipe = build_trainer(
        arch, variant=variant, mesh=mesh, lr=lr, total_steps=total_steps,
        seq_len=seq_len, global_batch=global_batch, seed=seed)
    ckpt = Checkpointer(ckpt_dir, keep=3)
    box = {"state": state, "metrics": {}}

    policy_at = None
    if budget is None and (anneal_from is not None
                           or anneal_steps is not None):
        raise ValueError("--anneal-from/--anneal-steps require --budget "
                         "(the anneal target)")
    if budget is not None:
        spec, _ = as_spec_policy(ecfg)
        sched = capacity_anneal(
            anneal_from if anneal_from is not None else budget, budget,
            anneal_steps if anneal_steps is not None else total_steps)
        cache = {}

        def policy_at(step: int):
            b = round(sched(step), 4)
            if b not in cache:   # solver output as traced jnp leaves
                # ragged: the STATIC capacity bucket rides beside the traced
                # policy — the whole anneal schedule costs one compile per
                # bucket (<= routing.RAGGED_N_BUCKETS), each doing work
                # proportional to its bucket instead of full dense shapes;
                # a full-budget start resolves the IDENTITY sentinel bucket,
                # so the anneal's teacher-speed steps skip routing work
                # while the routers keep their BCE/load gradients
                pol = solve_budget(cfg, spec, b)
                bkt = (ragged_bucket(pol, seq_len, spec=spec)
                       if spec.routing_impl == "ragged" else None)
                cache[b] = (pol, bkt)
            return cache[b]

    def do_step(step: int) -> dict:
        batch = {"tokens": jnp.asarray(pipe.batch_at(step))}
        if policy_at is None:
            box["state"], m = step_fn(box["state"], params, batch)
        else:
            pol, bkt = policy_at(step)
            box["state"], m = step_fn(box["state"], params, batch, pol,
                                      bucket=bkt)
        box["metrics"] = {k: float(v) for k, v in m.items()}
        if step % 10 == 0:
            log.info("step %d %s", step, box["metrics"])
        return box["metrics"]

    def save(step: int):
        ckpt.save(step, {"router": box["state"].router_params,
                         "opt_m": box["state"].opt.m,
                         "opt_v": box["state"].opt.v},
                  extra={"step": step, "data": pipe.state(),
                         "opt_step": int(box["state"].opt.step)})

    def restore() -> int:
        latest = ckpt.latest_step()
        if latest is None:
            box["state"] = init_train_state(state.router_params)
            return 0
        tree = {"router": box["state"].router_params,
                "opt_m": box["state"].opt.m, "opt_v": box["state"].opt.v}
        loaded, extra = ckpt.restore(latest, tree)
        opt = box["state"].opt._replace(
            step=jnp.asarray(extra["opt_step"], jnp.int32),
            m=loaded["opt_m"], v=loaded["opt_v"])
        box["state"] = TrainState(loaded["router"], opt, box["state"].ef)
        pipe.restore(extra["data"])
        return extra["step"]

    watchdog = StragglerWatchdog()
    metrics, restarts = run_resilient(
        start_step=restore(), total_steps=total_steps, do_step=do_step,
        save=save, restore=restore, save_every=save_every,
        injector=FailureInjector(inject_failures), watchdog=watchdog)
    ckpt.wait()
    return box["state"], metrics, restarts, watchdog


def main():
    logging.basicConfig(level=logging.INFO)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-lm")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--budget", type=float, default=None,
                    help="target compute budget in (0,1]; capacities from "
                         "the roofline budget solver")
    ap.add_argument("--anneal-from", type=float, default=None,
                    help="start budget of the linear capacity anneal "
                         "(traced policy: the schedule re-uses one compile)")
    ap.add_argument("--anneal-steps", type=int, default=None)
    args = ap.parse_args()
    _, metrics, restarts, _ = train(
        args.arch, variant=args.variant, total_steps=args.steps,
        seq_len=args.seq_len, global_batch=args.batch, lr=args.lr,
        ckpt_dir=args.ckpt, use_mesh=args.mesh, multi_pod=args.multi_pod,
        budget=args.budget, anneal_from=args.anneal_from,
        anneal_steps=args.anneal_steps)
    print("final:", metrics, "restarts:", restarts)


if __name__ == "__main__":
    main()
