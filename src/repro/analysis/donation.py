"""DONATE — buffer donation actually reaches the compiled executables.

``donate_argnums`` is a *request*: XLA only honors it when shapes,
layouts, and shardings line up, and silently falls back to copies when
they don't — the KV caches then exist twice per decode step. Two gates:

* ``DONATE-MISSING``: static check. Flatten the avals of each entry
  point's declared-donated args (``EntryPoint.donated``) and require that
  multiset to be covered by the compiled executable's
  ``input_output_alias`` table (parsed by ``launch.hloprof``). Matching is
  by aval, not parameter index, so ``keep_unused=False`` param dropping
  can't produce false alarms.
* ``DONATE-DEAD``: functional check. Call the jitted fn once with
  sacrificial deep copies and assert every donated leaf is actually
  ``is_deleted()`` afterwards — the end-to-end proof the alias survived
  all the way through runtime buffer management.
"""
from __future__ import annotations

from collections import Counter
from typing import List

import jax
import jax.numpy as jnp

from repro.analysis.framework import Finding
from repro.launch.hloprof import donated_param_types

PASS_NAME = "donation"


def _canon(type_str: str) -> str:
    """Normalize jax aval / HLO entry-layout type spellings to one form:
    jax says ``i32``/``bool`` where HLO says ``s32``/``pred``."""
    t = type_str.replace(" ", "").rstrip("~*")
    if t.startswith("i") and not t.startswith("int"):
        t = "s" + t[1:]
    return t.replace("bool[", "pred[")


def _donated_avals(ep, compiled) -> List[str]:
    """hloprof-style type strings (``f32[2,48]``) of every leaf of every
    declared-donated arg — in *per-device* shapes, since the SPMD HLO
    module's alias table speaks local shards, not global avals."""
    try:
        arg_shardings = compiled.input_shardings[0]
    except Exception:
        arg_shardings = None
    out = []
    for argnum in ep.donated:
        leaves = jax.tree.leaves(ep.args[argnum])
        shardings = [None] * len(leaves)
        if arg_shardings is not None and argnum < len(arg_shardings):
            cand = jax.tree.leaves(arg_shardings[argnum])
            if len(cand) == len(leaves):
                shardings = cand
        for leaf, sh in zip(leaves, shardings):
            shape = tuple(jnp.shape(leaf))
            if sh is not None:
                try:
                    shape = sh.shard_shape(shape)
                except Exception:
                    pass
            aval = jax.core.ShapedArray(shape, jnp.asarray(leaf).dtype)
            out.append(_canon(aval.str_short(short_dtypes=True)))
    return out


def _static_check(bundle, name: str) -> List[Finding]:
    ep = bundle.entries()[name]
    if not ep.donated:
        return []
    expected = Counter(_donated_avals(ep, bundle.compiled(name)))
    actual = Counter(
        _canon(t) for t in donated_param_types(bundle.compiled(name).as_text()))
    missing = expected - actual
    if missing:
        lost = ", ".join(f"{t} x{n}" for t, n in sorted(missing.items()))
        return [Finding(
            "DONATE-MISSING", f"serve.{name}",
            f"declared-donated buffers absent from input_output_alias: "
            f"{lost} — each lives twice per call",
            detail=f"expected {sorted(expected.elements())}\n"
                   f"aliased  {sorted(actual.elements())}")]
    return []


def _functional_check(bundle, name: str) -> List[Finding]:
    """Execute once on sacrificial copies; donated leaves must die."""
    ep = bundle.fresh_entry(name)
    if not ep.donated:
        return []
    copies = jax.tree.map(
        lambda x: jnp.copy(x) if isinstance(x, jax.Array) else x, ep.args)
    with bundle._ctx():
        out = ep.fn(*copies, **ep.static)
    jax.block_until_ready(out)
    finds = []
    for argnum in ep.donated:
        leaves = jax.tree.leaves(copies[argnum])
        live = [lf.aval.str_short() for lf in leaves
                if isinstance(lf, jax.Array) and not lf.is_deleted()]
        if live:
            finds.append(Finding(
                "DONATE-DEAD", f"serve.{name}",
                f"arg {argnum}: {len(live)}/{len(leaves)} donated leaves "
                f"still alive after the call ({', '.join(live[:4])}) — "
                "donation fell back to a copy"))
    return finds


def run(bundle) -> List[Finding]:
    finds: List[Finding] = []
    for name in bundle.entries():
        finds += _static_check(bundle, name)
        finds += _functional_check(bundle, name)
    return finds
