"""PAL — static verification of every Pallas kernel's launch geometry.

``kernels.analyzable_kernels()`` enumerates one representative call per
kernel; this pass intercepts ``pl.pallas_call`` (recording the grid spec
and concrete operands, returning zeros so the wrapper completes without
compiling anything) and then *statically evaluates* the launch:

* ``PAL-OOB``: every ``BlockSpec.index_map`` is enumerated over the full
  grid (with the real scalar-prefetch operands bound) and each returned
  block index must satisfy ``0 <= bi < cdiv(dim, block)`` — the proof
  that no tile reads or writes outside its operand. This is exactly the
  class of bug interpret-mode hides (OOB reads clamp) and hardware
  corrupts silently.
* ``PAL-ALIGN``: MXU/VREG tiling — a block's last dim must be a multiple
  of 128 (or cover the whole axis), its second-to-last a multiple of 8
  (or be 1, or cover the axis). Misaligned tiles compile but pad in VMEM,
  quietly wasting the systolic array.
* ``PAL-PREFETCH``: small integer control vectors (per-slot offsets,
  ragged counts) must ride ``num_scalar_prefetch`` — as blocked operands
  they'd serialize the grid on VMEM loads the indexing depends on; and
  prefetch operands must actually be small integer arrays.
"""
from __future__ import annotations

import contextlib
import itertools
import math
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis.framework import Finding

PASS_NAME = "pallas"

_MAX_GRID_POINTS = 65536


@contextlib.contextmanager
def record_pallas_calls():
    """Swap ``pl.pallas_call`` for a recorder: each launch appends
    ``{"kwargs": ..., "args": ...}`` and yields zeros of ``out_shape``."""
    records = []
    orig = pl.pallas_call

    def fake(kernel, **kw):
        def runner(*call_args):
            records.append({"kwargs": kw, "args": call_args})
            return jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), kw.get("out_shape"),
                is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))
        return runner

    pl.pallas_call = fake
    try:
        yield records
    finally:
        pl.pallas_call = orig


def _launch_geometry(rec):
    """-> (grid, nsp, prefetch_args, [(kind, spec, shape), ...])."""
    kw, args = rec["kwargs"], rec["args"]
    gs = kw.get("grid_spec")
    if gs is not None:
        nsp = int(getattr(gs, "num_scalar_prefetch", 0) or 0)
        grid, in_specs, out_specs = gs.grid, list(gs.in_specs), gs.out_specs
    else:
        nsp = 0
        grid = kw.get("grid") or ()
        in_specs = list(kw.get("in_specs") or [])
        out_specs = kw.get("out_specs")
    grid = (grid,) if isinstance(grid, int) else tuple(grid)
    prefetch = tuple(np.asarray(a) for a in args[:nsp])
    operands = list(args[nsp:])
    triples = [("in", s, tuple(np.shape(o)))
               for s, o in zip(in_specs, operands)]
    outs = jax.tree.leaves(
        kw.get("out_shape"),
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))
    out_specs = out_specs if isinstance(out_specs, (list, tuple)) \
        else [out_specs] * len(outs)
    triples += [("out", s, tuple(o.shape))
                for s, o in zip(out_specs, outs) if s is not None]
    return grid, nsp, prefetch, triples, operands


def verify_record(name: str, rec) -> List[Finding]:
    """All three gates over one recorded launch (exposed so tests can feed
    synthetic bad launches)."""
    finds = []
    grid, nsp, prefetch, triples, operands = _launch_geometry(rec)
    tgt = f"kernels.{name}"

    # ---- PAL-PREFETCH ----
    for i, p in enumerate(prefetch):
        if not np.issubdtype(p.dtype, np.integer) or p.ndim > 2:
            finds.append(Finding(
                "PAL-PREFETCH", tgt,
                f"scalar-prefetch operand {i} is {p.dtype}{list(p.shape)} — "
                "prefetch lane is for small integer control arrays"))
    for i, o in enumerate(operands):
        if hasattr(o, "dtype") and np.issubdtype(o.dtype, np.integer) \
                and getattr(o, "ndim", 99) <= 1:
            finds.append(Finding(
                "PAL-PREFETCH", tgt,
                f"integer control vector operand {nsp + i} "
                f"({o.dtype}{list(o.shape)}) is a blocked input — "
                "move it to num_scalar_prefetch so index maps can use it"))

    # ---- PAL-ALIGN ----
    for kind, spec, shape in triples:
        bs = tuple(getattr(spec, "block_shape", None) or ())
        if not bs or len(bs) != len(shape):
            continue
        concrete = [d if b is None else b for b, d in zip(bs, shape)]
        last, ldim = concrete[-1], shape[-1]
        if last % 128 != 0 and last != ldim:
            finds.append(Finding(
                "PAL-ALIGN", tgt,
                f"{kind}_spec block {concrete} on {list(shape)}: last dim "
                f"{last} is neither lane-aligned (x128) nor the full axis"))
        if len(concrete) >= 2:
            sub, sdim = concrete[-2], shape[-2]
            if sub % 8 != 0 and sub != 1 and sub != sdim:
                finds.append(Finding(
                    "PAL-ALIGN", tgt,
                    f"{kind}_spec block {concrete} on {list(shape)}: "
                    f"sublane dim {sub} is not a multiple of 8"))

    # ---- PAL-OOB ----
    n_points = math.prod(grid) if grid else 0
    if n_points and n_points <= _MAX_GRID_POINTS:
        ranges = [range(g) for g in grid]
        for kind, spec, shape in triples:
            imap = getattr(spec, "index_map", None)
            bs = tuple(getattr(spec, "block_shape", None) or ())
            if imap is None or len(bs) != len(shape):
                continue
            limits = [math.ceil(d / (b or d)) for b, d in zip(bs, shape)]
            bad = None
            for idx in itertools.product(*ranges):
                try:
                    bi = imap(*idx, *prefetch)
                except Exception as e:              # map itself blew up
                    bad = (idx, f"index_map raised {type(e).__name__}: {e}")
                    break
                bi = tuple(int(x) for x in (bi if isinstance(bi, tuple)
                                            else (bi,)))
                if len(bi) != len(limits) or any(
                        not 0 <= b < lim for b, lim in zip(bi, limits)):
                    bad = (idx, f"block index {bi} outside "
                                f"{[f'[0,{l})' for l in limits]}")
                    break
            if bad:
                finds.append(Finding(
                    "PAL-OOB", tgt,
                    f"{kind}_spec block {list(bs)} on {list(shape)} at grid "
                    f"point {bad[0]}: {bad[1]}"))
    elif n_points:
        finds.append(Finding(
            "PAL-OOB", tgt,
            f"grid has {n_points} points (> {_MAX_GRID_POINTS}); in-bounds "
            "enumeration skipped — shrink the analysis example",
            severity="warning"))
    return finds


def run(bundle=None) -> List[Finding]:
    """bundle is unused (kernel launches are self-contained) but accepted
    so the pass registry has one signature."""
    from repro.kernels import analyzable_kernels
    finds: List[Finding] = []
    for name, builder in analyzable_kernels().items():
        fn, args, kwargs = builder()
        with record_pallas_calls() as records:
            try:
                fn(*args, **kwargs)
            except Exception as e:
                finds.append(Finding(
                    "PAL-OOB", f"kernels.{name}",
                    f"analysis example failed under the recorder: "
                    f"{type(e).__name__}: {e}"))
                continue
        if not records:
            finds.append(Finding(
                "PAL-OOB", f"kernels.{name}",
                "analysis example never reached pl.pallas_call"))
        for rec in records:
            finds += verify_record(name, rec)
    return finds
