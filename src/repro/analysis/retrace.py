"""RETRACE — prove the serving/training graphs can't recompile per call.

The serving SLO (ROADMAP: "budgets, slots, temperatures, seeds never
recompile") is a *tracing* property, so it is checkable statically:

* ``RETRACE-VALUE-DEP``: lower each entry point twice with the same
  shapes/dtypes but different *values* (every numeric leaf perturbed) and
  diff the normalized StableHLO. Any difference means a Python-visible
  value leaked into the trace (a host-side ``int(x)``/``if x:`` or a
  constant baked from a non-tracer leaf) — the classic silent-recompile
  source.
* ``RETRACE-WEAK-TYPE``: example args carrying ``weak_type=True`` avals
  (bare Python scalars coerced by ``jnp.asarray``). A weak-typed operand
  retraces the first time it meets a strongly-typed one.
* ``RETRACE-PY-SCALAR``: raw Python ``int``/``float``/``bool`` leaves in
  traced argument trees — each distinct value becomes a fresh weak-typed
  constant signature.
* ``RETRACE-STATIC-UNHASHABLE``: static (compile-time) kwargs that aren't
  hashable — jit would raise at call time, but only on the path that
  passes them.
* ``RETRACE-COMPILE-COUNT``: a live mini-workload (two budgets, mixed
  temperatures/seeds/slots) against the bundle engine, asserting
  ``compile_counts()`` lands exactly at {prefill: 1, decode: 1}.
"""
from __future__ import annotations

import re
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.framework import Finding

PASS_NAME = "retrace"

_LOC_RE = re.compile(r"\s*loc\([^)]*\)")
_LOCDEF_RE = re.compile(r"^#loc.*$", re.M)


def _normalize(hlo_text: str) -> str:
    """StableHLO text minus source locations (which legitimately differ
    between two traces of the same function)."""
    return _LOCDEF_RE.sub("", _LOC_RE.sub("", hlo_text))


def _perturb(leaf):
    """Same shape/dtype/weak_type, different value."""
    if isinstance(leaf, (jax.Array, np.ndarray)) \
            and jnp.issubdtype(jnp.asarray(leaf).dtype, np.bool_):
        return leaf
    if isinstance(leaf, jax.Array):
        one = jnp.ones((), leaf.dtype)
        return (leaf + one).astype(leaf.dtype)
    if isinstance(leaf, np.ndarray):
        return (leaf + np.ones((), leaf.dtype)).astype(leaf.dtype)
    if isinstance(leaf, (int, float)) and not isinstance(leaf, bool):
        return leaf + 1       # a static/baked scalar shows up as a new const
    return leaf


def _diff_head(a: str, b: str, n: int = 6) -> str:
    la, lb = a.splitlines(), b.splitlines()
    out = []
    for i, (x, y) in enumerate(zip(la, lb)):
        if x != y:
            out.append(f"line {i}:\n  - {x.strip()}\n  + {y.strip()}")
            if len(out) >= n:
                break
    if len(la) != len(lb):
        out.append(f"line counts differ: {len(la)} vs {len(lb)}")
    return "\n".join(out)


def _lint_args(name: str, ep) -> List[Finding]:
    finds = []
    leaves = jax.tree.leaves(ep.args)
    for i, leaf in enumerate(leaves):
        if isinstance(leaf, jax.Array) and leaf.weak_type:
            finds.append(Finding(
                "RETRACE-WEAK-TYPE", f"serve.{name}",
                f"arg leaf {i} ({leaf.dtype}{list(leaf.shape)}) is "
                "weak-typed; wrap with an explicit dtype "
                "(jnp.float32(x), not jnp.asarray(x)) or the first mixed-"
                "dtype op retraces"))
        elif isinstance(leaf, (bool, int, float)):
            finds.append(Finding(
                "RETRACE-PY-SCALAR", f"serve.{name}",
                f"arg leaf {i} is a Python {type(leaf).__name__}; every "
                "distinct value is a distinct weak-typed jit signature"))
    for k, v in ep.static.items():
        try:
            hash(v)
        except TypeError:
            finds.append(Finding(
                "RETRACE-STATIC-UNHASHABLE", f"serve.{name}",
                f"static kwarg {k!r} ({type(v).__name__}) is unhashable; "
                "jit will reject the call"))
    return finds


def _value_dep(bundle, name: str) -> List[Finding]:
    ep = bundle.entries()[name]
    base = _normalize(bundle.lowered(name).as_text())
    args2 = jax.tree.map(_perturb, ep.args)
    with bundle._ctx():
        other = _normalize(ep.fn.lower(*args2, **ep.static).as_text())
    if base != other:
        return [Finding(
            "RETRACE-VALUE-DEP", f"serve.{name}",
            "lowering changed when only argument VALUES changed — a value "
            "is baked into the graph and will retrace per call",
            detail=_diff_head(base, other))]
    return []


def _workload(bundle) -> List[Finding]:
    """Live retrace probe: mixed budgets/temps/seeds through the real
    scheduler must leave exactly one compile per entry point."""
    from repro.training.serve import GenRequest
    eng = bundle.engine
    before = dict(eng.compile_counts())
    prompt = np.arange(1, 9, dtype=np.int32)
    for i, (budget, temp) in enumerate([(0.5, 0.0), (0.75, 0.8)]):
        eng.submit(GenRequest(prompt, max_new_tokens=3, budget=budget,
                              temperature=temp, top_k=2 * i, seed=7 * i))
    for _ in range(24):
        if not eng.has_work:
            break
        eng.step()
    after = eng.compile_counts()
    finds = []
    if after != {"prefill": 1, "decode": 1}:
        finds.append(Finding(
            "RETRACE-COMPILE-COUNT", "serve.engine",
            f"compile_counts {before} -> {after} over a 2-budget mixed-"
            "sampling workload; expected exactly {'prefill': 1, "
            "'decode': 1}"))
    # the paged engine's contract is stronger: chunked prefill keeps ONE
    # compile across DIFFERENT prompt lengths (the ring engine is allowed
    # one compile per length; the paged one is not)
    peng = getattr(bundle, "paged_engine", None)
    if peng is not None:
        for i, plen in enumerate((3, 8, 13, 21)):
            peng.submit(GenRequest(np.arange(1, plen + 1, dtype=np.int32),
                                   max_new_tokens=2, budget=0.5 + 0.1 * i))
        for _ in range(48):
            if not peng.has_work:
                break
            peng.step()
        pafter = peng.compile_counts()
        if pafter != {"prefill": 1, "decode": 1}:
            finds.append(Finding(
                "RETRACE-COMPILE-COUNT", "serve.paged_engine",
                f"paged compile_counts {pafter} over 4 distinct prompt "
                "lengths; chunked prefill must keep exactly {'prefill': 1, "
                "'decode': 1}"))
    return finds


def run(bundle) -> List[Finding]:
    finds: List[Finding] = []
    for name, ep in bundle.entries().items():
        finds += _lint_args(name, ep)
        finds += _value_dep(bundle, name)
    finds += _workload(bundle)
    return finds
