"""repro.analysis — static lint of the serving/training graphs and kernels.

``python -m repro.analysis`` builds the real toy-config entry points
(``graphs.build_bundle``) and runs every registered pass over them:

    retrace    value-dependent lowering / weak types / compile-count creep
    sharding   unpinned cache writes, missing out_shardings on donated outs
    host_sync  host callbacks + host-resident operands on the hot path
    donation   declared donations actually alias (HLO table + is_deleted)
    dtype      large silent bf16->f32 upcasts, x64 leaks
    pallas     kernel grid/BlockSpec in-bounds + MXU alignment + prefetch

Each pass is ``run(bundle) -> list[Finding]``; add a pass by appending to
``PASSES``. Waivers (``--waive RULE[:TARGET-GLOB]`` or a waiver file)
silence known findings without hiding them from the report.
"""
from repro.analysis import (donation, dtype_lint, host_sync, pallas_lint,
                            retrace, sharding_lint)
from repro.analysis.framework import (Finding, Report, Waiver,
                                      load_waiver_file)
from repro.analysis.graphs import GraphBundle, build_bundle

PASSES = [
    (retrace.PASS_NAME, retrace.run),
    (sharding_lint.PASS_NAME, sharding_lint.run),
    (host_sync.PASS_NAME, host_sync.run),
    (donation.PASS_NAME, donation.run),
    (dtype_lint.PASS_NAME, dtype_lint.run),
    (pallas_lint.PASS_NAME, pallas_lint.run),
]

__all__ = ["Finding", "Report", "Waiver", "load_waiver_file", "GraphBundle",
           "build_bundle", "PASSES", "run_all"]


def run_all(bundle=None, waivers=(), only=None, mesh_shape=None) -> Report:
    """Run every registered pass (or the ``only`` subset) and fold the
    findings into one Report. ``bundle=None`` builds the default toy
    bundle (optionally on ``mesh_shape``)."""
    if bundle is None:
        bundle = build_bundle(mesh_shape=mesh_shape)
    report = Report(meta={
        "mesh": list(bundle.mesh.devices.shape) if bundle.mesh else None,
        "arch": type(bundle.cfg).__name__,
        "entries": sorted(bundle.entries()),
    })
    for name, fn in PASSES:
        if only and name not in only:
            continue
        report.extend(name, fn(bundle), waivers)
    return report
