"""The real entry-point graphs every analysis pass lints.

``build_bundle()`` stands up the toy config exactly the way production
does — ``ServingEngine`` (optionally on a `(data, model)` mesh) for the
admit/decode graphs, ``make_train_step`` for the training graph — and
caches one jaxpr / lowering / compilation per entry point so six passes
don't pay six traces. Passes never invent their own call signatures: the
serving args come from ``ServingEngine.entry_points()`` (built by the same
code paths a live call uses), so a refactor that changes the contract
changes what gets linted automatically.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_elastic
from repro.core.policy import as_spec_policy, ragged_bucket, solve_budget
from repro.models import model_init, router_init
from repro.training import ServingEngine
from repro.training.serve import EntryPoint
from repro.training.train_step import init_train_state, make_train_step


def _f32(cfg):
    """Analysis runs the smoke config in f32 (CPU-exact, and the dtype
    lint's no-bf16-upcast baseline)."""
    new = dataclasses.replace(cfg, dtype="float32")
    if cfg.encoder is not None:
        new = dataclasses.replace(
            new, encoder=dataclasses.replace(cfg.encoder, dtype="float32"))
    return new


@dataclasses.dataclass
class GraphBundle:
    """Entry points + shared trace/lower/compile caches."""
    cfg: object
    ecfg: object
    params: object
    rp: object
    engine: ServingEngine
    paged_engine: Optional[ServingEngine] = None
    mesh: object = None
    seq_len: int = 32
    train_batch: int = 4
    _entries: Optional[dict] = None
    _jaxprs: dict = dataclasses.field(default_factory=dict)
    _lowered: dict = dataclasses.field(default_factory=dict)
    _compiled: dict = dataclasses.field(default_factory=dict)

    # --------------------------- entry points --------------------------------

    def entries(self) -> dict:
        """{name: EntryPoint} over every graph the stack compiles: the
        serving admit/decode pair (ring AND paged KV layouts) plus the
        train step."""
        if self._entries is None:
            self._entries = dict(self.engine.entry_points())
            if self.paged_engine is not None:
                for k, ep in self.paged_engine.entry_points().items():
                    self._entries[f"paged_{k}"] = ep
            self._entries["train"] = self._train_entry()
        return self._entries

    def fresh_entry(self, name: str) -> EntryPoint:
        """Entry point with the engine's *current* buffers — the cached
        ``entries()`` args go stale (deleted) once any pass actually steps
        the engine, because the serving jits donate their caches."""
        if name == "train":
            return self.entries()["train"]
        if name.startswith("paged_"):
            return self.paged_engine.entry_points()[name[len("paged_"):]]
        return self.engine.entry_points()[name]

    def _train_entry(self) -> EntryPoint:
        spec, _ = as_spec_policy(self.ecfg)
        step_fn = jax.jit(
            make_train_step(self.cfg, self.ecfg, lr=1e-3, mesh=self.mesh,
                            chunked=self.cfg.vocab_size > 0),
            static_argnames=("bucket",), donate_argnums=(0,))
        state = init_train_state(self.rp)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(
            0, max(2, self.cfg.vocab_size),
            size=(self.train_batch, self.seq_len)), jnp.int32)}
        pol = solve_budget(self.cfg, spec, 0.5)
        bucket = (ragged_bucket(pol, self.seq_len, spec=spec)
                  if spec.routing_impl == "ragged" else None)
        return EntryPoint(step_fn, (state, self.params, batch, pol),
                          {"bucket": bucket}, donated=(0,))

    # ------------------------ shared trace caches ----------------------------

    def jaxpr(self, name: str):
        if name not in self._jaxprs:
            ep = self.entries()[name]
            fn = partial(ep.fn, **ep.static) if ep.static else ep.fn
            with self._ctx():
                self._jaxprs[name] = jax.make_jaxpr(fn)(*ep.args)
        return self._jaxprs[name]

    def lowered(self, name: str):
        if name not in self._lowered:
            ep = self.entries()[name]
            with self._ctx():
                self._lowered[name] = ep.fn.lower(*ep.args, **ep.static)
        return self._lowered[name]

    def compiled(self, name: str):
        if name not in self._compiled:
            self._compiled[name] = self.lowered(name).compile()
        return self._compiled[name]

    def _ctx(self):
        from contextlib import nullcontext
        return self.mesh if self.mesh is not None else nullcontext()


def build_bundle(mesh_shape=None, arch: str = "toy-lm", mode: str = "infer",
                 max_seq: int = 48, seq_len: int = 32,
                 kv_dtype: str = "fp32",
                 weight_dtype: str = "fp32",
                 depth: bool = True) -> GraphBundle:
    """Stand up the toy-config serving + training graphs (optionally on a
    `(data, model)` mesh — works on one device with shape (1, 1), and on
    the CI 8-fake-device job with (2, 4)). ``kv_dtype``/``weight_dtype``
    build the SERVING engines quantized (docs/quantization.md) so the
    dtype pass can audit the int8 graphs; the train step always runs the
    fp32 master weights. ``depth`` enables the elastic depth router
    (docs/elastic_policy.md) so the linted serve graphs carry the
    per-layer KV-validity mask writes the depth router drives."""
    cfg = _f32(get_config(arch, "smoke"))
    ecfg = get_elastic(arch, cfg)
    if depth and ecfg is not None \
            and getattr(ecfg, "depth_capacity", None) is None:
        ecfg = dataclasses.replace(ecfg, depth_capacity=1.0)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ecfg)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ecfg)
    mesh = None
    if mesh_shape is not None:
        from repro.runtime.elastic import make_mesh
        mesh = make_mesh(tuple(mesh_shape), ("data", "model"))
    batch = max(2, mesh_shape[0]) if mesh_shape else 2
    engine = ServingEngine(params, rp, cfg, ecfg, mode=mode,
                           batch_size=batch, max_seq=max_seq, mesh=mesh,
                           kv_dtype=kv_dtype, weight_dtype=weight_dtype)
    # the paged-KV engine lints alongside the ring one: its chunked-prefill
    # admit and paged decode are separate compiled graphs with their own
    # donation/pin/retrace contracts. Paged mode requires a dense MLP, so
    # it gets its own router set under a no-experts elastic config.
    paged_engine = None
    if all(k == "attn" for k in cfg.layer_kinds) and cfg.moe is None \
            and cfg.encoder is None:
        pecfg = dataclasses.replace(ecfg, mlp_n_experts=0, mlp_expert_topk=0)
        pparams = model_init(key, cfg, pecfg)
        prp = router_init(jax.random.fold_in(key, 1), cfg, pecfg)
        paged_engine = ServingEngine(pparams, prp, cfg, pecfg, mode=mode,
                                     batch_size=batch, max_seq=max_seq,
                                     mesh=mesh, kv_layout="paged",
                                     page_size=8, kv_dtype=kv_dtype,
                                     weight_dtype=weight_dtype)
    return GraphBundle(cfg, ecfg, params, rp, engine,
                       paged_engine=paged_engine, mesh=mesh, seq_len=seq_len)
