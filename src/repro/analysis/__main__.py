"""CLI: ``python -m repro.analysis [--mesh D,M] [--json out.json] ...``

Exit status is 1 iff any unwaived error-severity finding remains — the
contract the ``lint-graphs`` CI job enforces.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static lint of the serving/training graphs + kernels "
                    "(see docs/analysis.md for the rule catalog)")
    ap.add_argument("--mesh", default="1,1", metavar="DATA,MODEL",
                    help="mesh shape for the bundle; 'none' lints unsharded "
                         "graphs (sharding pass goes vacuous). Default 1,1 "
                         "— a trivial mesh so constraint/pin rules stay "
                         "active on one device.")
    ap.add_argument("--arch", default="toy-lm")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="build the serving engines with this KV cache "
                         "storage dtype (audits the quantized graphs)")
    ap.add_argument("--weight-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="base weight storage dtype for the serving "
                         "engines")
    ap.add_argument("--no-depth", action="store_true",
                    help="lint without the elastic depth router (default: "
                         "depth enabled, so the per-layer KV-validity mask "
                         "writes are in the audited graphs)")
    ap.add_argument("--pass", dest="only", action="append", metavar="NAME",
                    help="run only this pass (repeatable)")
    ap.add_argument("--waive", action="append", default=[],
                    metavar="RULE[:TARGET-GLOB]")
    ap.add_argument("--waiver-file", default="analysis-waivers.txt",
                    help="waiver file (default: ./analysis-waivers.txt if "
                         "present)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable report ('-' = stdout)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="include finding detail blocks in the table")
    args = ap.parse_args(argv)

    from repro.analysis import (Waiver, build_bundle, load_waiver_file,
                                run_all)

    waivers = [Waiver.parse(w) for w in args.waive]
    if os.path.exists(args.waiver_file):
        waivers += load_waiver_file(args.waiver_file)

    mesh_shape = None
    if args.mesh.lower() not in ("none", ""):
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))

    bundle = build_bundle(mesh_shape=mesh_shape, arch=args.arch,
                          kv_dtype=args.kv_dtype,
                          weight_dtype=args.weight_dtype,
                          depth=not args.no_depth)
    report = run_all(bundle, waivers=waivers, only=args.only)

    if args.json == "-":
        print(report.to_json())
    else:
        if args.json:
            with open(args.json, "w") as f:
                f.write(report.to_json())
        print(report.table(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
