"""HOST — device<->host synchronization hazards on the serving hot path.

* ``HOST-CALLBACK``: a host-callback primitive (``pure_callback``,
  ``io_callback``, ``debug_callback``, legacy ``outside_call``) inside a
  jitted serving/training graph. Each firing stalls the dispatch queue on
  a device->host->device round trip — debug prints left in the decode
  step are the classic offender.
* ``HOST-OPERAND``: a ``numpy.ndarray`` leaf in an entry point's example
  args. jit re-uploads host-resident operands on every call; serving state
  arrays must live on device between steps (the engine keeps scheduler
  state in numpy deliberately, but hands jnp views to the jits —
  ``entry_points()`` reflects exactly what a live call passes).
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.analysis.framework import Finding, eqn_site, walk_eqns

PASS_NAME = "host_sync"

_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "outside_call",
    "host_callback_call",
})


def _callbacks(bundle, name: str) -> List[Finding]:
    finds = []
    for _, eqn in walk_eqns(bundle.jaxpr(name)):
        if eqn.primitive.name in _CALLBACK_PRIMS:
            cb = eqn.params.get("callback", "")
            finds.append(Finding(
                "HOST-CALLBACK", f"serve.{name}",
                f"{eqn.primitive.name} at {eqn_site(eqn)} stalls every "
                f"call on a host round trip{f' ({cb})' if cb else ''}"))
    return finds


def _host_operands(name: str, ep) -> List[Finding]:
    finds = []
    for i, leaf in enumerate(jax.tree.leaves(ep.args)):
        if isinstance(leaf, np.ndarray):
            finds.append(Finding(
                "HOST-OPERAND", f"serve.{name}",
                f"arg leaf {i} ({leaf.dtype}{list(leaf.shape)}) is a host "
                "numpy array — re-uploaded on every call; keep hot-path "
                "state on device"))
    return finds


def run(bundle) -> List[Finding]:
    finds: List[Finding] = []
    for name, ep in bundle.entries().items():
        finds += _callbacks(bundle, name)
        finds += _host_operands(name, ep)
    return finds
