"""DTYPE — silent precision/width surprises on the hot path.

* ``DTYPE-UPCAST``: a ``convert_element_type`` from bf16/f16 to f32 whose
  result is large (>= 64Ki elements) inside a serving/training graph.
  Deliberate f32 accumulation lives inside the Pallas kernels (whose
  sub-jaxprs the walker skips) and in tiny reductions; a *large* upcast in
  the surrounding graph doubles HBM traffic for that tensor — usually a
  missing ``preferred_element_type`` or a ref-path helper leaking into
  production. On the f32 analysis config this is vacuously clean; run the
  CLI against a bf16 variant to audit a real deployment graph.
* ``DTYPE-WIDE``: any f64/s64 value in the graph — an x64 leak (a Python
  float threading through ``np.float64`` or an enabled-x64 import order
  bug). CPU silently runs it; TPU pays a 2x emulation penalty or errors.
* ``DTYPE-QUANT-HBM``: a large (>= 64Ki elements) int8 -> f32
  ``convert_element_type`` in a SERVING graph. The quantization contract
  (docs/quantization.md) is that int8 KV pages and weights dequantize
  INSIDE the Pallas kernels, in-register after the tile load; the walker
  skipping ``pallas_call`` sub-jaxprs is exactly that allowlist, so any
  int8 upcast this rule can see is HBM-visible — a whole cache or weight
  materialized at 4x its stored footprint, forfeiting the bandwidth the
  int8 format bought. Training graphs are exempt (masters are fp32;
  quantization is serving-only).
"""
from __future__ import annotations

import math
from typing import List

from repro.analysis.framework import Finding, eqn_site, walk_eqns

PASS_NAME = "dtype"

_NARROW = ("bfloat16", "float16")
_UPCAST_MIN_ELEMS = 64 * 1024
_WIDE = ("float64", "int64", "uint64", "complex128")


def _findings_for(bundle, name: str) -> List[Finding]:
    finds = []
    wide_seen = set()
    for _, eqn in walk_eqns(bundle.jaxpr(name)):
        if eqn.primitive.name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.outvars[0].aval
            if (str(src.dtype) in _NARROW and str(dst.dtype) == "float32"
                    and math.prod(dst.shape) >= _UPCAST_MIN_ELEMS):
                finds.append(Finding(
                    "DTYPE-UPCAST", f"serve.{name}",
                    f"{src.str_short()} -> {dst.str_short()} at "
                    f"{eqn_site(eqn)}: large activation silently widened "
                    "to f32 (2x HBM for this tensor)"))
            if (name != "train" and str(src.dtype) == "int8"
                    and str(dst.dtype) == "float32"
                    and math.prod(dst.shape) >= _UPCAST_MIN_ELEMS):
                finds.append(Finding(
                    "DTYPE-QUANT-HBM", f"serve.{name}",
                    f"{src.str_short()} -> {dst.str_short()} at "
                    f"{eqn_site(eqn)}: int8 cache/weight dequantized "
                    "OUTSIDE the kernels — HBM sees the f32 copy, "
                    "forfeiting the 4x bandwidth win "
                    "(docs/quantization.md)"))
        for v in eqn.outvars:
            dt = str(getattr(v.aval, "dtype", ""))
            if dt in _WIDE:
                site = eqn_site(eqn)
                if (dt, site) not in wide_seen:
                    wide_seen.add((dt, site))
                    finds.append(Finding(
                        "DTYPE-WIDE", f"serve.{name}",
                        f"{dt} value produced by {eqn.primitive.name} at "
                        f"{site} — x64 leaked into the graph"))
    return finds


def run(bundle) -> List[Finding]:
    finds: List[Finding] = []
    for name in bundle.entries():
        finds += _findings_for(bundle, name)
    return finds
