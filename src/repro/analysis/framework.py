"""Finding / Report / waiver plumbing shared by every analysis pass.

A *pass* is a function ``run(bundle) -> list[Finding]`` registered in
``repro.analysis.PASSES``; the CLI (``python -m repro.analysis``) runs them
over the real serving/training graphs (see ``graphs.GraphBundle``) and
renders one ``Report``. The same ``Finding``/``Report`` types back
``benchmarks/check_bench_schema.py`` so every static gate in CI speaks one
schema (``--json`` artifacts diff cleanly across jobs).

Waivers: a rule can be silenced per target with ``Waiver(rule, target,
reason)`` — ``rule`` exact, ``target`` an fnmatch glob over the finding's
target string. The CLI reads ``--waive RULE[:TARGET-GLOB]`` flags and an
optional waiver file (one ``RULE[:TARGET-GLOB]  # reason`` per line);
waived findings are reported but never fail the run.

Also here: the jaxpr walker the graph-level passes share. It recurses
through every higher-order primitive (pjit/scan/while/cond/custom-vjp...)
by treating any ``Jaxpr``/``ClosedJaxpr`` found in ``eqn.params`` as a
child, so a lint rule written once sees cache writes inside a scanned layer
stack as well as at top level. ``pallas_call`` sub-jaxprs are skipped by
default: kernel-internal f32 accumulation upcasts are deliberate and the
kernels get their own dedicated verifier (``pallas_lint``).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Iterable, Iterator, List, Optional, Tuple

from jax._src import core as jax_core

SEVERITIES = ("error", "warning")


@dataclasses.dataclass
class Finding:
    """One rule violation at one site."""
    rule: str                 # e.g. "SHARD-CACHE-WRITE"
    target: str               # e.g. "serve.decode" / "kernels.moe_gmm"
    message: str              # one line, human-oriented
    severity: str = "error"   # "error" fails CI; "warning" is advisory
    detail: str = ""          # optional multi-line evidence (diffs, eqns)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if not d["detail"]:
            del d["detail"]
        return d

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule} @ {self.target}: {self.message}"


@dataclasses.dataclass
class Waiver:
    rule: str                 # exact rule id
    target: str = "*"         # fnmatch glob over Finding.target
    reason: str = ""

    def matches(self, f: Finding) -> bool:
        return f.rule == self.rule and fnmatch.fnmatch(f.target, self.target)

    @classmethod
    def parse(cls, text: str, reason: str = "") -> "Waiver":
        """``RULE`` or ``RULE:TARGET-GLOB``."""
        rule, _, target = text.partition(":")
        return cls(rule.strip(), target.strip() or "*", reason)


def load_waiver_file(path: str) -> List[Waiver]:
    """One waiver per line: ``RULE[:TARGET-GLOB]  # reason``. Blank lines
    and full-line comments are skipped."""
    out = []
    with open(path) as f:
        for line in f:
            body, _, comment = line.partition("#")
            body = body.strip()
            if body:
                out.append(Waiver.parse(body, reason=comment.strip()))
    return out


@dataclasses.dataclass
class Report:
    """The outcome of a set of passes over a set of graphs."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    waived: List[Finding] = dataclasses.field(default_factory=list)
    passes: List[str] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def extend(self, pass_name: str, findings: Iterable[Finding],
               waivers: Iterable[Waiver] = ()) -> None:
        self.passes.append(pass_name)
        for f in findings:
            (self.waived if any(w.matches(f) for w in waivers)
             else self.findings).append(f)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self, indent: int = 2) -> str:
        return json.dumps({
            "ok": self.ok,
            "passes": self.passes,
            "meta": self.meta,
            "findings": [f.to_dict() for f in self.findings],
            "waived": [f.to_dict() for f in self.waived],
        }, indent=indent)

    def table(self, verbose: bool = False) -> str:
        lines = [f"passes run: {', '.join(self.passes) or '(none)'}"]
        for f in self.findings:
            lines.append(str(f))
            if verbose and f.detail:
                lines += ["    " + ln for ln in f.detail.splitlines()[:20]]
        for f in self.waived:
            lines.append(f"(waived) {f}")
        n_err = len(self.errors)
        n_warn = len(self.findings) - n_err
        lines.append(f"{n_err} error(s), {n_warn} warning(s), "
                     f"{len(self.waived)} waived")
        return "\n".join(lines)


# ------------------------------ jaxpr walking --------------------------------

def _child_jaxprs(eqn) -> Iterator[jax_core.Jaxpr]:
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if isinstance(x, jax_core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax_core.Jaxpr):
                yield x


def walk_eqns(jaxpr, skip_prims=("pallas_call",)
              ) -> Iterator[Tuple[jax_core.Jaxpr, "jax_core.JaxprEqn"]]:
    """Yield ``(owning_jaxpr, eqn)`` for every equation, recursing into the
    sub-jaxprs of higher-order primitives (except ``skip_prims``). The
    owning jaxpr is yielded so rules can test whether an operand is one of
    its invars (= a long-lived buffer threaded in from outside)."""
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield jaxpr, eqn
        if eqn.primitive.name in skip_prims:
            continue
        for child in _child_jaxprs(eqn):
            yield from walk_eqns(child, skip_prims=skip_prims)


# Ops through which a buffer keeps its identity for lint purposes: a write
# into transpose(cache) is still a write into the cache, and a constraint
# on convert(update) still pins the update.
TRANSPARENT_PRIMS = frozenset({
    "transpose", "reshape", "convert_element_type", "squeeze",
    "broadcast_in_dim", "copy", "sharding_constraint",
})


def derives_from_invar(var, jaxpr, depth: int = 3) -> bool:
    """True if ``var`` is an invar of ``jaxpr``, or reaches one through at
    most ``depth`` transparent ops (see TRANSPARENT_PRIMS)."""
    if isinstance(var, jax_core.Literal):
        return False
    invars = set(map(id, jaxpr.invars))
    frontier = [var]
    producers = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            producers[id(ov)] = eqn
    for _ in range(depth + 1):
        nxt = []
        for v in frontier:
            if id(v) in invars:
                return True
            eqn = producers.get(id(v))
            if eqn is not None and eqn.primitive.name in TRANSPARENT_PRIMS:
                nxt.extend(iv for iv in eqn.invars
                           if not isinstance(iv, jax_core.Literal))
        frontier = nxt
    return False


def constrained_downstream(var, jaxpr, depth: int = 4) -> bool:
    """True if ``var`` (an eqn output) flows into a ``sharding_constraint``
    within ``depth`` hops of transparent ops inside the same jaxpr — the
    definition of a "pinned" cache write."""
    consumers = {}
    for eqn in jaxpr.eqns:
        for iv in eqn.invars:
            if not isinstance(iv, jax_core.Literal):
                consumers.setdefault(id(iv), []).append(eqn)
    frontier = [var]
    for _ in range(depth):
        nxt = []
        for v in frontier:
            for eqn in consumers.get(id(v), ()):
                if eqn.primitive.name == "sharding_constraint":
                    return True
                if eqn.primitive.name in TRANSPARENT_PRIMS:
                    nxt.extend(eqn.outvars)
        if not nxt:
            return False
        frontier = nxt
    return False


def eqn_site(eqn) -> str:
    """Best-effort ``file:line`` for an eqn, from its source_info."""
    try:
        from jax._src import source_info_util as siu
        try:
            frame = siu.user_frame(eqn.source_info)
        except Exception:
            frame = siu.user_frame(eqn.source_info.traceback)
        if frame is not None:
            return f"{frame.file_name.rsplit('/', 1)[-1]}:{frame.start_line}"
    except Exception:
        pass
    return "?"
