"""SHARD — mesh-placement invariants of the serving/training graphs.

* ``SHARD-CACHE-WRITE``: a batch-indexed ``dynamic_update_slice`` /
  ``scatter`` into a long-lived buffer (one threaded in through the
  jaxpr's invars) whose result is NOT pinned by a
  ``with_sharding_constraint`` within a few transparent ops. Unpinned,
  GSPMD is free to all-gather the cache around the write — the exact
  regression runtime/sharding.constrain_kv_cache exists to prevent.
  Covered buffers: rank>=3 *floating-point* tensors (the KV caches,
  policy state) and rank-2 *boolean* bitmaps (the per-layer KV-validity
  masks the depth router scatters every decode step — ring ``valid``,
  paged ``pvalid``; pinned by runtime/sharding.constrain_kv_mask and the
  rank-2 branch of constrain_page_pool). Integer bookkeeping scatters
  (pos rings, page tables, the MoE dispatch-index inversion) are
  deliberately below the radar: replicating those is cheap and pinning
  them would add collectives.
* ``SHARD-OUT-PIN``: a donated input that enters the graph sharded but
  whose aliased output compiles to a different sharding — the entry point
  is missing its ``out_shardings`` pin, so every call inserts a reshard
  (and donation degrades to copy-on-alias). Vacuous on a 1x1 mesh; the
  8-fake-device CI variant exercises it for real.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.framework import (Finding, constrained_downstream,
                                      derives_from_invar, eqn_site, walk_eqns)

PASS_NAME = "sharding"

_WRITE_PRIMS = ("dynamic_update_slice", "scatter", "scatter-add", "scatter_add")


def _cache_writes(bundle, name: str) -> List[Finding]:
    finds = []
    closed = bundle.jaxpr(name)
    for owner, eqn in walk_eqns(closed):
        if eqn.primitive.name not in _WRITE_PRIMS:
            continue
        operand = eqn.invars[0]
        aval = operand.aval
        is_cache = aval.ndim >= 3 and np.issubdtype(aval.dtype, np.floating)
        # rank-2 bool = KV-validity bitmap (ring valid / paged pvalid): the
        # depth router rewrites it per step, so an unpinned scatter
        # replicates the whole leaf per step. Integer bookkeeping (pos
        # rings, page tables, dispatch-index inversion) stays exempt.
        is_mask = aval.ndim == 2 and aval.dtype == np.bool_
        if not (is_cache or is_mask):
            continue
        if not derives_from_invar(operand, owner):
            continue                     # scratch value, not a live buffer
        idx = eqn.invars[1:] if eqn.primitive.name.startswith("scatter") \
            else eqn.invars[2:]
        if all(isinstance(v, jax.core.Literal) for v in idx):
            continue                     # static write: XLA sees through it
        out = eqn.outvars[0]
        if constrained_downstream(out, owner):
            continue
        finds.append(Finding(
            "SHARD-CACHE-WRITE", f"serve.{name}",
            f"{eqn.primitive.name} into {aval.str_short()} buffer at "
            f"{eqn_site(eqn)} has no with_sharding_constraint pin — GSPMD "
            "may all-gather the cache around the write"))
    return finds


def _equiv(a, b, ndim: int) -> bool:
    try:
        return a.is_equivalent_to(b, ndim)
    except Exception:
        return a == b


def _out_pins(bundle, name: str) -> List[Finding]:
    if bundle.mesh is None or bundle.mesh.size <= 1:
        return []
    ep = bundle.entries()[name]
    if not ep.donated:
        return []
    compiled = bundle.compiled(name)
    try:
        arg_sh = compiled.input_shardings[0]
        out_sh = jax.tree.leaves(compiled.output_shardings)
        out_avals = bundle.jaxpr(name).out_avals
    except Exception:
        return []
    outs = [(a.str_short(short_dtypes=True), a.ndim, s)
            for a, s in zip(out_avals, out_sh)]
    finds = []
    for argnum in ep.donated:
        if argnum >= len(arg_sh):
            continue
        leaves = jax.tree.leaves(ep.args[argnum])
        shardings = jax.tree.leaves(arg_sh[argnum])
        if len(shardings) != len(leaves):
            continue
        for leaf, ish in zip(leaves, shardings):
            aval = jax.core.ShapedArray(jnp.shape(leaf),
                                        jnp.asarray(leaf).dtype)
            key = aval.str_short(short_dtypes=True)
            if any(k == key and nd == aval.ndim and _equiv(ish, osh, nd)
                   for k, nd, osh in outs):
                continue
            finds.append(Finding(
                "SHARD-OUT-PIN", f"serve.{name}",
                f"donated arg {argnum} leaf {key} enters sharded "
                f"{getattr(ish, 'spec', ish)} but no same-aval output "
                "compiles to that sharding — the entry point is missing "
                "an out_shardings pin, so each call pays a reshard "
                "instead of aliasing in place"))
    return finds


def run(bundle) -> List[Finding]:
    if bundle.mesh is None:
        return []     # unsharded graphs place no constraints to lint
    finds: List[Finding] = []
    for name in bundle.entries():
        finds += _cache_writes(bundle, name)
        finds += _out_pins(bundle, name)
    return finds
