"""Elastic scaling: re-mesh a running job onto a different device count.

ElastiFormer training state is small (routers + LoRA + AdamW moments,
<0.1% of the model), and the base model is frozen — so scaling down/up is:
  1. drain + checkpoint (async save already in flight most of the time);
  2. rebuild the mesh at the new (pod, data, model) shape;
  3. re-derive shardings from the same logical rules (they are expressed
     against axis *names*, not sizes) and device_put the restored state.

`reshard` also serves checkpoint-portability: a checkpoint written on a
16x16 mesh restores onto 2x16x16 (or a single host) unchanged, because the
on-disk format is plain host arrays.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.runtime import sharding as SH


def make_mesh(shape: tuple, axes: tuple, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(shape))
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def valid_mesh_shapes(n_devices: int, model_axis: int):
    """Enumerate (data, model) shapes available after losing/gaining hosts —
    the controller picks the largest batch-preserving one."""
    out = []
    for m in (model_axis, model_axis // 2, model_axis * 2):
        if m and n_devices % m == 0:
            out.append((n_devices // m, m))
    return out


def reshard(tree, mesh: Mesh, specs_tree):
    """device_put every leaf onto `mesh` with its PartitionSpec."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs_tree)


def rescale_training_state(params, router_params, opt_state, new_mesh: Mesh):
    """Re-mesh all training state. Base params follow the TP rules; router
    and optimizer trees are replicated (tiny)."""
    p = reshard(params, new_mesh, SH.param_specs(params, new_mesh))
    rep = lambda t: jax.tree.map(
        lambda x: jax.device_put(x, SH.replicated(new_mesh)), t)
    return p, rep(router_params), rep(opt_state)


def rescale_serving_state(params, router_params, caches, cfg, new_mesh):
    """Re-mesh live SERVING state without a restart: base params follow the
    TP rules, routers replicate, and the live slot-array caches (attn k/v
    rings + valid/pos, ssm/rglru recurrent state, xattn context) follow the
    cache rules — the cache contents ARE the in-flight requests, so moving
    them (instead of dropping them) is what lets every running request
    resume with identical tokens. ``new_mesh=None`` gathers everything back
    onto the default single device (scale-to-one)."""
    if new_mesh is None:
        dev = jax.devices()[0]
        put = lambda t: jax.tree.map(lambda x: jax.device_put(x, dev), t)
        return put(params), put(router_params), put(caches)
    p = reshard(params, new_mesh, SH.param_specs(params, new_mesh))
    rp = jax.tree.map(
        lambda x: jax.device_put(x, SH.replicated(new_mesh)), router_params)
    c = reshard(caches, new_mesh, SH.cache_specs_tree(caches, cfg, new_mesh))
    return p, rp, c
