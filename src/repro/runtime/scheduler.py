"""Request lifecycle + slot scheduler for continuous-batching serving.

The serving engine owns a FIXED array of B decode slots (one compiled decode
step over all of them, finished/empty slots masked). This module owns the
host-side bookkeeping around that array:

* ``RequestHandle`` — the lifecycle object ``engine.submit`` returns:
  QUEUED -> RUNNING -> DONE | CANCELLED, a streaming ``tokens()`` iterator,
  and per-request latency timestamps.

* ``SlotScheduler`` — FIFO admission of queued requests into free slots,
  packed against a per-step FLOP budget: each request costs its compute
  budget (the roofline active-FLOP fraction its ``ElasticPolicy`` was solved
  for; 1.0 = full teacher row), and admissions stop when the sum over
  occupied slots would exceed ``flop_budget``. Low-budget requests therefore
  co-schedule more densely — elasticity is a *scheduling* signal, not just a
  quality knob. ``flop_budget=None`` means "one full-budget row per slot"
  (admission limited only by free slots).

The scheduler is deliberately model-free: it never touches jax. The engine
calls ``admit()`` / ``free()`` / ``tick()`` around its compiled steps.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Iterator, List, Optional, Tuple

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"


class RequestHandle:
    """Lifecycle handle for one submitted request.

    ``tokens()`` is a pull-based stream: it yields tokens already produced
    and, while the request is live, drives ``engine.step()`` to produce
    more. ``done`` is True once the request finished or was cancelled;
    ``output`` is the generated tokens so far (a list of ints).
    """

    _ids = itertools.count()

    def __init__(self, request, engine=None):
        self.id = next(self._ids)
        self.request = request
        self.status = QUEUED
        self.slot: Optional[int] = None
        self.output: List[int] = []
        self.finish_reason: Optional[str] = None   # length | eos | cancelled
        self.t_submit = time.perf_counter()
        self.t_first: Optional[float] = None
        self.t_done: Optional[float] = None
        self._engine = engine

    @property
    def done(self) -> bool:
        return self.status in (DONE, CANCELLED)

    @property
    def latency(self) -> Optional[float]:
        """Submit -> finish wall time in seconds (None while live)."""
        return None if self.t_done is None else self.t_done - self.t_submit

    def append(self, tok: int):
        if self.t_first is None:
            self.t_first = time.perf_counter()
        self.output.append(tok)

    def finish(self, reason: str):
        self.status = CANCELLED if reason == "cancelled" else DONE
        self.finish_reason = reason
        self.t_done = time.perf_counter()

    def tokens(self) -> Iterator[int]:
        """Stream generated tokens; drives the engine while the request is
        live (each ``engine.step()`` advances every active slot, so
        consuming one stream also progresses concurrent requests)."""
        i = 0
        while True:
            while i < len(self.output):
                yield self.output[i]
                i += 1
            if self.done:
                return
            if self._engine is None:
                raise RuntimeError("detached handle cannot stream")
            self._engine.step()

    def result(self):
        """Block (stepping the engine) until done; returns the token list."""
        for _ in self.tokens():
            pass
        return list(self.output)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, status={self.status}, "
                f"slot={self.slot}, n_tokens={len(self.output)})")


class SlotScheduler:
    """FIFO admission into a fixed slot array under a per-step FLOP budget.

    ``cost`` of a request = its compute-budget fraction (1.0 for
    budget-None / teacher rows). Admission packs greedily in arrival order:
    a request is admitted when a slot is free AND the occupied cost sum
    stays within ``flop_budget``. If nothing is running and the head
    request alone exceeds the budget it is admitted anyway (progress
    guarantee).
    """

    def __init__(self, n_slots: int, flop_budget: Optional[float] = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.flop_budget = (float(n_slots) if flop_budget is None
                            else float(flop_budget))
        self.slots: List[Optional[RequestHandle]] = [None] * n_slots
        self.costs: List[float] = [0.0] * n_slots
        self.queue: deque = deque()
        # occupancy accounting (slot-steps used / slot-steps available)
        self.steps = 0
        self.active_slot_steps = 0

    # ---- queue ----
    def enqueue(self, handle: RequestHandle, cost: float = 1.0):
        handle.status = QUEUED
        self.queue.append((handle, float(cost)))

    def drop_queued(self, handle: RequestHandle) -> bool:
        """Remove a still-queued handle; True if it was found."""
        for item in self.queue:
            if item[0] is handle:
                self.queue.remove(item)
                return True
        return False

    # ---- slots ----
    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def used_cost(self) -> float:
        return sum(c for s, c in zip(self.slots, self.costs) if s is not None)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self) -> List[Tuple[int, RequestHandle]]:
        """Pop queued requests into free slots under the FLOP budget;
        returns [(slot, handle)] for the engine to prefill."""
        out: List[Tuple[int, RequestHandle]] = []
        used = self.used_cost
        for slot in self.free_slots():
            if not self.queue:
                break
            handle, cost = self.queue[0]
            over = used + cost > self.flop_budget + 1e-9
            if over and (used > 0 or out):
                break               # wait for running work to drain
            self.queue.popleft()
            self.slots[slot], self.costs[slot] = handle, cost
            handle.slot, handle.status = slot, RUNNING
            used += cost
            out.append((slot, handle))
        return out

    def free(self, slot: int) -> None:
        self.slots[slot] = None
        self.costs[slot] = 0.0

    def tick(self):
        """Record one engine step for occupancy accounting."""
        self.steps += 1
        self.active_slot_steps += self.active

    def reset_stats(self):
        """Zero the occupancy counters (e.g. between benchmark windows)."""
        self.steps = 0
        self.active_slot_steps = 0

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per engine step so far."""
        if self.steps == 0:
            return 0.0
        return self.active_slot_steps / (self.steps * self.n_slots)
