"""Request lifecycle + slot scheduler for continuous-batching serving.

The serving engine owns a FIXED array of B decode slots (one compiled decode
step over all of them, finished/empty slots masked). This module owns the
host-side bookkeeping around that array:

* ``RequestHandle`` — the lifecycle object ``engine.submit`` returns:
  QUEUED -> RUNNING -> DONE | CANCELLED | REJECTED, a streaming
  ``tokens()`` iterator, and per-token latency timestamps (TTFT and
  inter-token gaps feed the SLO controller, see ``runtime/controller.py``).

* ``SlotScheduler`` — admission of queued requests into free slots, packed
  against a per-replica, per-step FLOP budget: each request costs its
  compute budget (the roofline active-FLOP fraction its ``ElasticPolicy``
  was solved for; 1.0 = full teacher row), and a request is placed on the
  least-loaded replica whose occupied cost sum stays within
  ``flop_budget``. Low-budget requests therefore co-schedule more densely —
  elasticity is a *scheduling* signal, not just a quality knob. Requests
  queue per tenant class (FIFO within a class, earliest-arrival across
  classes, so a single class reproduces the old global FIFO exactly), carry
  optional queue deadlines (expired entries are dropped before they burn a
  prefill, finish reason ``deadline_exceeded``), and can be shed under
  overload (finish reason ``rejected`` + a Retry-After hint on the handle).
  Under an SPMD mesh the slot array carries a data-parallel replica axis
  (flat slot i -> replica i // slots_per_replica); ``n_replicas=1`` (the
  default) is the old single-device behaviour. ``flop_budget=None`` means
  "one full-budget row per slot" (admission limited only by free slots).

The scheduler is deliberately model-free: it never touches jax. The engine
calls ``admit()`` / ``free()`` / ``tick()`` around its compiled steps.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, Iterator, List, Optional, Tuple

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"
REJECTED = "rejected"

# Terminal finish reasons that map to the REJECTED status: the server
# declined to serve the request (shed under overload, or its queue deadline
# passed before admission) — typed so clients can distinguish "retry later"
# from a served completion.
_REJECT_REASONS = ("rejected", "deadline_exceeded")

# Admission-cost floor: a request whose roofline budget fraction rounds to
# ~0 FLOPs still occupies a decode-slot lane of the compiled step (and, in
# the paged engine, real KV pages), so its scheduling cost can never be 0 —
# otherwise per-replica used-cost accounting sees a full replica as idle
# and zero-cost rows bypass the FLOP budget entirely. One slot-lane is
# never cheaper than 1/1024 of a full-budget row.
MIN_COST = 2.0 ** -10

DEFAULT_TENANT = "default"


class RequestHandle:
    """Lifecycle handle for one submitted request.

    ``tokens()`` is a pull-based stream: it yields tokens already produced
    and, while the request is live, drives ``engine.step()`` to produce
    more. ``done`` is True once the request reached any terminal state;
    ``output`` is the generated tokens so far (a list of ints).

    Timestamps come from the injected ``clock`` (default
    ``time.perf_counter``) so tests and the SLO controller can drive a
    fully deterministic clock: ``t_submit``, ``t_first``, per-token
    ``t_tokens``, ``t_done``. ``deadline`` (absolute, same clock) expires
    the request while queued; ``retry_after`` is the server's hint
    (seconds) when the request was shed.
    """

    _ids = itertools.count()

    def __init__(self, request, engine=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.id = next(self._ids)
        self.request = request
        self.status = QUEUED
        self.slot: Optional[int] = None
        self.output: List[int] = []
        # length | eos | cancelled | rejected | deadline_exceeded
        self.finish_reason: Optional[str] = None
        self._clock = clock
        self.t_submit = clock()
        self.t_first: Optional[float] = None
        self.t_done: Optional[float] = None
        self.t_tokens: List[float] = []
        self.tenant: str = DEFAULT_TENANT
        self.deadline: Optional[float] = None
        self.retry_after: Optional[float] = None
        self.budget_served: float = 1.0
        self._engine = engine

    @property
    def done(self) -> bool:
        return self.status in (DONE, CANCELLED, REJECTED)

    @property
    def latency(self) -> Optional[float]:
        """Submit -> finish wall time in seconds (None while live)."""
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first token in seconds (queue wait + prefill)."""
        return None if self.t_first is None else self.t_first - self.t_submit

    def inter_token(self) -> List[float]:
        """Gaps between consecutive token timestamps, seconds."""
        return [b - a for a, b in zip(self.t_tokens, self.t_tokens[1:])]

    def append(self, tok: int):
        t = self._clock()
        if self.t_first is None:
            self.t_first = t
        self.t_tokens.append(t)
        self.output.append(tok)

    def finish(self, reason: str):
        if reason == "cancelled":
            self.status = CANCELLED
        elif reason in _REJECT_REASONS:
            self.status = REJECTED
        else:
            self.status = DONE
        self.finish_reason = reason
        self.t_done = self._clock()

    def tokens(self) -> Iterator[int]:
        """Stream generated tokens; drives the engine while the request is
        live (each ``engine.step()`` advances every active slot, so
        consuming one stream also progresses concurrent requests)."""
        i = 0
        while True:
            while i < len(self.output):
                yield self.output[i]
                i += 1
            if self.done:
                return
            if self._engine is None:
                raise RuntimeError("detached handle cannot stream")
            self._engine.step()

    def result(self):
        """Block (stepping the engine) until done; returns the token list."""
        for _ in self.tokens():
            pass
        return list(self.output)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, status={self.status}, "
                f"slot={self.slot}, n_tokens={len(self.output)})")


class _QEntry:
    """One queued request. ``dropped`` tombstones the entry in place so
    ``drop_queued`` is O(1) (keyed by handle id); tombstones are swept
    lazily at queue heads and filtered from every view."""

    __slots__ = ("handle", "cost", "seq", "dropped")

    def __init__(self, handle: RequestHandle, cost: float, seq: int):
        self.handle = handle
        self.cost = cost
        self.seq = seq
        self.dropped = False


class SlotScheduler:
    """Admission into a fixed slot array under a per-replica FLOP budget.

    ``cost`` of a request = its compute-budget fraction (1.0 for
    budget-None / teacher rows). The slot array carries a data-parallel
    replica axis: flat slot ``i`` belongs to replica ``i // (n_slots //
    n_replicas)`` — exactly the batch rows a `(data, model)` mesh places on
    data shard ``i // spr``, so admission placement IS device placement.

    Admission order: requests queue FIFO **within** their tenant class and
    the earliest-arrival live head **across** classes goes first, so with a
    single class this is exactly the old global FIFO. A head request that
    cannot be placed (FLOP budget, or the paged engine's ``page_check``)
    blocks only its own class — another class's head may still fit — but
    within a class nothing jumps the queue. Each admitted request is
    placed on the least-loaded replica that has a free slot and whose
    occupied cost sum stays within ``flop_budget`` (a PER-REPLICA budget:
    every replica decodes the same compiled step, so the slowest replica's
    active FLOPs set the step time). If nothing is running anywhere and the
    globally-oldest head alone exceeds the budget it is admitted anyway
    (progress guarantee). ``n_replicas=1`` reproduces the old
    single-device packing exactly.
    """

    def __init__(self, n_slots: int, flop_budget: Optional[float] = None,
                 n_replicas: int = 1):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if n_replicas < 1 or n_slots % n_replicas:
            raise ValueError(f"n_slots={n_slots} must be a positive "
                             f"multiple of n_replicas={n_replicas}")
        self.n_slots = n_slots
        self.n_replicas = n_replicas
        self._budget_explicit = flop_budget is not None
        self.flop_budget = (float(n_slots // n_replicas)
                            if flop_budget is None else float(flop_budget))
        self.slots: List[Optional[RequestHandle]] = [None] * n_slots
        self.costs: List[float] = [0.0] * n_slots
        self._queues: Dict[str, Deque[_QEntry]] = {}
        self._by_id: Dict[int, _QEntry] = {}
        self._n_pending = 0
        self._seq = itertools.count()
        self._front_seq = -1            # requeue_front goes before seq 0
        # occupancy accounting (slot-steps used / slot-steps available)
        self.steps = 0
        self.active_slot_steps = 0
        self.replica_steps = 0          # restarts on re-mesh / reset
        self.replica_slot_steps = [0] * n_replicas

    # ---- replica axis ----
    @property
    def slots_per_replica(self) -> int:
        return self.n_slots // self.n_replicas

    def replica_of(self, slot: int) -> int:
        return slot // self.slots_per_replica

    def replica_used_cost(self, replica: int) -> float:
        spr = self.slots_per_replica
        lo = replica * spr
        return sum(c for s, c in zip(self.slots[lo:lo + spr],
                                     self.costs[lo:lo + spr])
                   if s is not None)

    def free_slots_in(self, replica: int) -> List[int]:
        spr = self.slots_per_replica
        lo = replica * spr
        return [lo + i for i, s in enumerate(self.slots[lo:lo + spr])
                if s is None]

    def set_replicas(self, n_replicas: int) -> None:
        """Re-mesh: re-derive the replica axis over the SAME flat slot
        array. Running requests keep their flat slots (the live cache rows
        do not move between batch indices — only the mesh layout changes
        underneath them); the slot-limited default budget re-scales to the
        new slots-per-replica, an explicit budget is kept. Per-replica
        occupancy counters restart (the axis they were counted over is
        gone); global occupancy accounting continues."""
        if n_replicas < 1 or self.n_slots % n_replicas:
            raise ValueError(f"n_slots={self.n_slots} must be a positive "
                             f"multiple of n_replicas={n_replicas}")
        self.n_replicas = n_replicas
        if not self._budget_explicit:
            self.flop_budget = float(self.slots_per_replica)
        self.replica_steps = 0
        self.replica_slot_steps = [0] * n_replicas

    # ---- queue ----
    @property
    def queue(self) -> List[Tuple[RequestHandle, float]]:
        """Arrival-ordered view of live queued entries as (handle, cost)
        pairs — the legacy single-deque shape, kept for callers/tests."""
        live = [e for q in self._queues.values() for e in q if not e.dropped]
        live.sort(key=lambda e: e.seq)
        return [(e.handle, e.cost) for e in live]

    def _tenant_queue(self, tenant: str) -> Deque[_QEntry]:
        q = self._queues.get(tenant)
        if q is None:
            q = self._queues[tenant] = deque()
        return q

    def _push(self, entry: _QEntry, front: bool) -> None:
        q = self._tenant_queue(entry.handle.tenant)
        (q.appendleft if front else q.append)(entry)
        self._by_id[entry.handle.id] = entry
        self._n_pending += 1

    def _remove(self, entry: _QEntry) -> None:
        entry.dropped = True
        self._by_id.pop(entry.handle.id, None)
        self._n_pending -= 1

    def enqueue(self, handle: RequestHandle, cost: float = 1.0):
        handle.status = QUEUED
        self._push(_QEntry(handle, max(float(cost), MIN_COST),
                           next(self._seq)), front=False)

    def requeue_front(self, handle: RequestHandle, cost: float = 1.0):
        """Put a PREEMPTED request back at the head of the queue (it was
        admitted first; preemption-by-page-pressure must not also cost it
        its FIFO position)."""
        handle.status = QUEUED
        handle.slot = None
        entry = _QEntry(handle, max(float(cost), MIN_COST), self._front_seq)
        self._front_seq -= 1
        self._push(entry, front=True)

    def drop_queued(self, handle: RequestHandle) -> bool:
        """Remove a still-queued handle; True if it was found. O(1): the
        entry is tombstoned in place via the handle-id index and swept
        lazily when it reaches a queue head."""
        entry = self._by_id.get(handle.id)
        if entry is None or entry.dropped:
            return False
        self._remove(entry)
        return True

    def expire_deadlines(self, now: float) -> List[RequestHandle]:
        """Drop every queued handle whose deadline has passed — BEFORE it
        is admitted and burns a prefill. Expired handles are finished with
        reason ``deadline_exceeded`` and returned."""
        out: List[RequestHandle] = []
        for q in self._queues.values():
            for entry in q:
                if entry.dropped:
                    continue
                dl = entry.handle.deadline
                if dl is not None and now >= dl:
                    self._remove(entry)
                    entry.handle.finish("deadline_exceeded")
                    out.append(entry.handle)
        return out

    def shed(self, n: int, priority=None) -> List[RequestHandle]:
        """Reject ``n`` queued requests (overload stage 3). Victims are
        picked newest-first within the most-sheddable class first
        (``priority(handle)`` — higher sheds first; default: arrival order
        only), finished with reason ``rejected``, and returned so the
        caller can attach Retry-After hints."""
        live = [e for q in self._queues.values() for e in q if not e.dropped]
        live.sort(key=lambda e: ((-priority(e.handle) if priority else 0),
                                 -e.seq))
        out: List[RequestHandle] = []
        for entry in live[:max(0, int(n))]:
            self._remove(entry)
            entry.handle.finish("rejected")
            out.append(entry.handle)
        return out

    # ---- slots ----
    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return self._n_pending

    @property
    def used_cost(self) -> float:
        return sum(c for s, c in zip(self.slots, self.costs) if s is not None)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _live_heads(self) -> List[_QEntry]:
        """Sweep tombstones off every class head; return live heads in
        arrival order (earliest seq first)."""
        heads: List[_QEntry] = []
        for q in self._queues.values():
            while q and q[0].dropped:
                q.popleft()
            if q:
                heads.append(q[0])
        heads.sort(key=lambda e: e.seq)
        return heads

    def admit(self, page_check=None,
              cost_cap: Optional[float] = None,
              cost_scale: Optional[float] = None
              ) -> List[Tuple[int, RequestHandle]]:
        """Pop queued requests into free slots under the per-replica FLOP
        budget; returns [(slot, handle)] for the engine to prefill. Each
        admitted request is placed on the least-loaded replica that can
        take it (lowest occupied cost, ties to the lowest replica index),
        so admissions spread across the replica axis instead of filling
        replica 0 first — no replica starves while another queues.

        ``page_check(handle, replica) -> bool`` (optional) is the paged
        engine's joint-packing hook: a replica is only a candidate when it
        also has the free KV pages the request's prompt needs, so
        admission packs on free pages AND FLOP budget together. A head
        request no replica can page never jumps its class's queue —
        admission stays FIFO per class and waits for frees/preemption.

        ``cost_cap`` (optional) is the SLO controller's degraded admission
        budget: each admission is charged ``min(cost, cost_cap)``, the
        price of the degraded policy row the engine will actually solve
        for it (stage-1 graceful degradation packs denser).

        ``cost_scale`` (optional) is the controller's depth cap: depth
        routing skips whole layers, so a request's FLOP cost is its
        budget fraction TIMES the depth fraction — admission packs on
        that composed cost, exactly what the engine reprices the slot to
        after the prefill."""
        out: List[Tuple[int, RequestHandle]] = []
        used = [self.replica_used_cost(r) for r in range(self.n_replicas)]
        while True:
            heads = self._live_heads()
            if not heads:
                break
            if not any(self.free_slots_in(r)
                       for r in range(self.n_replicas)):
                break               # every replica is slot-full
            placed = None
            for k, entry in enumerate(heads):
                cost = entry.cost
                if cost_cap is not None:
                    cost = max(MIN_COST, min(cost, float(cost_cap)))
                if cost_scale is not None:
                    cost = max(MIN_COST, cost * float(cost_scale))
                cands = [r for r in range(self.n_replicas)
                         if self.free_slots_in(r)]
                if page_check is not None:
                    cands = [r for r in cands
                             if page_check(entry.handle, r)]
                    if not cands:
                        continue    # this class waits for page frees
                fit = [r for r in cands
                       if used[r] + cost <= self.flop_budget + 1e-9]
                if not fit:
                    if k == 0 and self.active == 0 and not out:
                        fit = cands  # idle engine: progress guarantee
                    else:
                        continue    # wait for running work to drain
                r = min(fit, key=lambda i: (used[i], i))
                slot = self.free_slots_in(r)[0]
                self._remove(entry)
                self.slots[slot], self.costs[slot] = entry.handle, cost
                entry.handle.slot, entry.handle.status = slot, RUNNING
                used[r] += cost
                out.append((slot, entry.handle))
                placed = entry
                break
            if placed is None:
                break
        return out

    def reprice(self, slot: int, cost: float) -> None:
        """Re-price a RUNNING slot's FLOP cost (stage-2 in-flight budget
        degradation: the engine spliced a cheaper policy row into the
        slot, so the replica's admission headroom grows to match)."""
        if self.slots[slot] is not None:
            self.costs[slot] = max(float(cost), MIN_COST)

    def free(self, slot: int) -> None:
        self.slots[slot] = None
        self.costs[slot] = 0.0

    def tick(self):
        """Record one engine step for occupancy accounting."""
        self.steps += 1
        self.active_slot_steps += self.active
        self.replica_steps += 1
        for r in range(self.n_replicas):
            self.replica_slot_steps[r] += sum(
                s is not None for s in self.slots[
                    r * self.slots_per_replica:(r + 1) * self.slots_per_replica])

    def reset_stats(self):
        """Zero the occupancy counters (e.g. between benchmark windows)."""
        self.steps = 0
        self.active_slot_steps = 0
        self.replica_steps = 0
        self.replica_slot_steps = [0] * self.n_replicas

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per engine step so far."""
        if self.steps == 0:
            return 0.0
        return self.active_slot_steps / (self.steps * self.n_slots)

    @property
    def replica_occupancy(self) -> List[float]:
        """Per-replica mean active-slot fraction (since the last re-mesh /
        reset) — the open-loop report's balance check."""
        if self.replica_steps == 0:
            return [0.0] * self.n_replicas
        return [s / (self.replica_steps * self.slots_per_replica)
                for s in self.replica_slot_steps]
