"""Request lifecycle + slot scheduler for continuous-batching serving.

The serving engine owns a FIXED array of B decode slots (one compiled decode
step over all of them, finished/empty slots masked). This module owns the
host-side bookkeeping around that array:

* ``RequestHandle`` — the lifecycle object ``engine.submit`` returns:
  QUEUED -> RUNNING -> DONE | CANCELLED, a streaming ``tokens()`` iterator,
  and per-request latency timestamps.

* ``SlotScheduler`` — FIFO admission of queued requests into free slots,
  packed against a per-replica, per-step FLOP budget: each request costs its
  compute budget (the roofline active-FLOP fraction its ``ElasticPolicy``
  was solved for; 1.0 = full teacher row), and a request is placed on the
  least-loaded replica whose occupied cost sum stays within ``flop_budget``.
  Low-budget requests therefore co-schedule more densely — elasticity is a
  *scheduling* signal, not just a quality knob. Under an SPMD mesh the slot
  array carries a data-parallel replica axis (flat slot i -> replica
  i // slots_per_replica, exactly the mesh's batch-shard placement);
  ``n_replicas=1`` (the default) is the old single-device behaviour.
  ``flop_budget=None`` means "one full-budget row per slot" (admission
  limited only by free slots).

The scheduler is deliberately model-free: it never touches jax. The engine
calls ``admit()`` / ``free()`` / ``tick()`` around its compiled steps.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Iterator, List, Optional, Tuple

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"

# Admission-cost floor: a request whose roofline budget fraction rounds to
# ~0 FLOPs still occupies a decode-slot lane of the compiled step (and, in
# the paged engine, real KV pages), so its scheduling cost can never be 0 —
# otherwise per-replica used-cost accounting sees a full replica as idle
# and zero-cost rows bypass the FLOP budget entirely. One slot-lane is
# never cheaper than 1/1024 of a full-budget row.
MIN_COST = 2.0 ** -10


class RequestHandle:
    """Lifecycle handle for one submitted request.

    ``tokens()`` is a pull-based stream: it yields tokens already produced
    and, while the request is live, drives ``engine.step()`` to produce
    more. ``done`` is True once the request finished or was cancelled;
    ``output`` is the generated tokens so far (a list of ints).
    """

    _ids = itertools.count()

    def __init__(self, request, engine=None):
        self.id = next(self._ids)
        self.request = request
        self.status = QUEUED
        self.slot: Optional[int] = None
        self.output: List[int] = []
        self.finish_reason: Optional[str] = None   # length | eos | cancelled
        self.t_submit = time.perf_counter()
        self.t_first: Optional[float] = None
        self.t_done: Optional[float] = None
        self._engine = engine

    @property
    def done(self) -> bool:
        return self.status in (DONE, CANCELLED)

    @property
    def latency(self) -> Optional[float]:
        """Submit -> finish wall time in seconds (None while live)."""
        return None if self.t_done is None else self.t_done - self.t_submit

    def append(self, tok: int):
        if self.t_first is None:
            self.t_first = time.perf_counter()
        self.output.append(tok)

    def finish(self, reason: str):
        self.status = CANCELLED if reason == "cancelled" else DONE
        self.finish_reason = reason
        self.t_done = time.perf_counter()

    def tokens(self) -> Iterator[int]:
        """Stream generated tokens; drives the engine while the request is
        live (each ``engine.step()`` advances every active slot, so
        consuming one stream also progresses concurrent requests)."""
        i = 0
        while True:
            while i < len(self.output):
                yield self.output[i]
                i += 1
            if self.done:
                return
            if self._engine is None:
                raise RuntimeError("detached handle cannot stream")
            self._engine.step()

    def result(self):
        """Block (stepping the engine) until done; returns the token list."""
        for _ in self.tokens():
            pass
        return list(self.output)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, status={self.status}, "
                f"slot={self.slot}, n_tokens={len(self.output)})")


class SlotScheduler:
    """FIFO admission into a fixed slot array under a per-replica FLOP
    budget.

    ``cost`` of a request = its compute-budget fraction (1.0 for
    budget-None / teacher rows). The slot array carries a data-parallel
    replica axis: flat slot ``i`` belongs to replica ``i // (n_slots //
    n_replicas)`` — exactly the batch rows a `(data, model)` mesh places on
    data shard ``i // spr``, so admission placement IS device placement.
    Admission stays FIFO in arrival order; each head-of-queue request is
    placed on the least-loaded replica that has a free slot and whose
    occupied cost sum stays within ``flop_budget`` (a PER-REPLICA budget:
    every replica decodes the same compiled step, so the slowest replica's
    active FLOPs set the step time). If nothing is running anywhere and the
    head request alone exceeds the budget it is admitted anyway (progress
    guarantee). ``n_replicas=1`` reproduces the old single-device packing
    exactly.
    """

    def __init__(self, n_slots: int, flop_budget: Optional[float] = None,
                 n_replicas: int = 1):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if n_replicas < 1 or n_slots % n_replicas:
            raise ValueError(f"n_slots={n_slots} must be a positive "
                             f"multiple of n_replicas={n_replicas}")
        self.n_slots = n_slots
        self.n_replicas = n_replicas
        self._budget_explicit = flop_budget is not None
        self.flop_budget = (float(n_slots // n_replicas)
                            if flop_budget is None else float(flop_budget))
        self.slots: List[Optional[RequestHandle]] = [None] * n_slots
        self.costs: List[float] = [0.0] * n_slots
        self.queue: deque = deque()
        # occupancy accounting (slot-steps used / slot-steps available)
        self.steps = 0
        self.active_slot_steps = 0
        self.replica_steps = 0          # restarts on re-mesh / reset
        self.replica_slot_steps = [0] * n_replicas

    # ---- replica axis ----
    @property
    def slots_per_replica(self) -> int:
        return self.n_slots // self.n_replicas

    def replica_of(self, slot: int) -> int:
        return slot // self.slots_per_replica

    def replica_used_cost(self, replica: int) -> float:
        spr = self.slots_per_replica
        lo = replica * spr
        return sum(c for s, c in zip(self.slots[lo:lo + spr],
                                     self.costs[lo:lo + spr])
                   if s is not None)

    def free_slots_in(self, replica: int) -> List[int]:
        spr = self.slots_per_replica
        lo = replica * spr
        return [lo + i for i, s in enumerate(self.slots[lo:lo + spr])
                if s is None]

    def set_replicas(self, n_replicas: int) -> None:
        """Re-mesh: re-derive the replica axis over the SAME flat slot
        array. Running requests keep their flat slots (the live cache rows
        do not move between batch indices — only the mesh layout changes
        underneath them); the slot-limited default budget re-scales to the
        new slots-per-replica, an explicit budget is kept. Per-replica
        occupancy counters restart (the axis they were counted over is
        gone); global occupancy accounting continues."""
        if n_replicas < 1 or self.n_slots % n_replicas:
            raise ValueError(f"n_slots={self.n_slots} must be a positive "
                             f"multiple of n_replicas={n_replicas}")
        self.n_replicas = n_replicas
        if not self._budget_explicit:
            self.flop_budget = float(self.slots_per_replica)
        self.replica_steps = 0
        self.replica_slot_steps = [0] * n_replicas

    # ---- queue ----
    def enqueue(self, handle: RequestHandle, cost: float = 1.0):
        handle.status = QUEUED
        self.queue.append((handle, max(float(cost), MIN_COST)))

    def requeue_front(self, handle: RequestHandle, cost: float = 1.0):
        """Put a PREEMPTED request back at the head of the queue (it was
        admitted first; preemption-by-page-pressure must not also cost it
        its FIFO position)."""
        handle.status = QUEUED
        handle.slot = None
        self.queue.appendleft((handle, max(float(cost), MIN_COST)))

    def drop_queued(self, handle: RequestHandle) -> bool:
        """Remove a still-queued handle; True if it was found."""
        for item in self.queue:
            if item[0] is handle:
                self.queue.remove(item)
                return True
        return False

    # ---- slots ----
    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def used_cost(self) -> float:
        return sum(c for s, c in zip(self.slots, self.costs) if s is not None)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self, page_check=None) -> List[Tuple[int, RequestHandle]]:
        """Pop queued requests into free slots under the per-replica FLOP
        budget; returns [(slot, handle)] for the engine to prefill. The
        head of the queue is placed on the least-loaded replica that can
        take it (lowest occupied cost, ties to the lowest replica index),
        so admissions spread across the replica axis instead of filling
        replica 0 first — no replica starves while another queues.

        ``page_check(handle, replica) -> bool`` (optional) is the paged
        engine's joint-packing hook: a replica is only a candidate when it
        also has the free KV pages the request's prompt needs, so
        admission packs on free pages AND FLOP budget together. A head
        request no replica can page never jumps the queue — admission
        stays FIFO and waits for frees/preemption."""
        out: List[Tuple[int, RequestHandle]] = []
        used = [self.replica_used_cost(r) for r in range(self.n_replicas)]
        while self.queue:
            handle, cost = self.queue[0]
            cands = [r for r in range(self.n_replicas)
                     if self.free_slots_in(r)]
            if not cands:
                break               # every replica is slot-full
            if page_check is not None:
                cands = [r for r in cands if page_check(handle, r)]
                if not cands:
                    break           # wait for page frees / preemption
            fit = [r for r in cands
                   if used[r] + cost <= self.flop_budget + 1e-9]
            if not fit:
                if self.active > 0 or out:
                    break           # wait for running work to drain
                fit = cands         # idle engine: progress guarantee
            r = min(fit, key=lambda i: (used[i], i))
            slot = self.free_slots_in(r)[0]
            self.queue.popleft()
            self.slots[slot], self.costs[slot] = handle, cost
            handle.slot, handle.status = slot, RUNNING
            used[r] += cost
            out.append((slot, handle))
        return out

    def free(self, slot: int) -> None:
        self.slots[slot] = None
        self.costs[slot] = 0.0

    def tick(self):
        """Record one engine step for occupancy accounting."""
        self.steps += 1
        self.active_slot_steps += self.active
        self.replica_steps += 1
        for r in range(self.n_replicas):
            self.replica_slot_steps[r] += sum(
                s is not None for s in self.slots[
                    r * self.slots_per_replica:(r + 1) * self.slots_per_replica])

    def reset_stats(self):
        """Zero the occupancy counters (e.g. between benchmark windows)."""
        self.steps = 0
        self.active_slot_steps = 0
        self.replica_steps = 0
        self.replica_slot_steps = [0] * self.n_replicas

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per engine step so far."""
        if self.steps == 0:
            return 0.0
        return self.active_slot_steps / (self.steps * self.n_slots)

    @property
    def replica_occupancy(self) -> List[float]:
        """Per-replica mean active-slot fraction (since the last re-mesh /
        reset) — the open-loop report's balance check."""
        if self.replica_steps == 0:
            return [0.0] * self.n_replicas
        return [s / (self.replica_steps * self.slots_per_replica)
                for s in self.replica_slot_steps]
