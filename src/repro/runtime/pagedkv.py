"""Block-paged KV memory: the page pool, prefix sharing, and CoW forks.

The ring cache (``models/attention.py::attn_cache_init``) reserves one
``max_seq`` region per serving slot, so replica capacity is bounded by
``slots x max_seq`` no matter how short the live requests actually are.
This module replaces that reservation with a global pool of fixed-size
pages plus a per-slot int32 *page table*: slot ``b``'s KV for absolute
position ``t`` lives at ``(table[b, t // page_size], t % page_size)``.

Division of labour (see docs/paged_kv.md):

* **host side (this module)** — free lists, refcounts, the prefix-hash
  registry, and preemption accounting. Pure python, never traced.
* **traced side** — the page table rides the jitted entry points as a
  normal int32 operand (any allocation pattern reuses one compile), and
  every pool write inside the graphs carries a
  ``with_sharding_constraint`` pin (``runtime/sharding.py``).

Pages are refcounted so requests with a common prompt prefix share
physical KV: a *full* prompt page is registered under a chained hash of
its token blocks (namespaced by routing mode / budget / theta, since the
ElastiFormer token gate decides which positions hold valid KV), and a
later request with the same prefix increfs the page instead of
recomputing it. Shared pages are immutable; the only mutation of an
incref'd page is ``fork``'s copy-on-write of the *partial* tail page
into a fresh exclusively-owned page (``copy_page_in_tree``).

Replica locality: under SPMD serving the pool's page axis is sharded
over ``data`` alongside the slot axis, so replica ``r`` may only
reference pages in its own contiguous id range. The last page of each
replica's range is reserved as a *trash* page — in-graph writes of
inactive slots (table entry ``-1``) are remapped there instead of
branching, keeping the decode graph shape fixed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import sharding as SH


def n_pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` positions (ceil division)."""
    return -(-int(n_tokens) // int(page_size))


def prefix_keys(tokens, page_size: int, namespace=()) -> list:
    """Chained hash keys for every FULL page of a token prefix.

    ``key[i]`` commits to tokens ``[0, (i+1) * page_size)`` — a chain, so
    a lookup hit at page ``i`` implies hits at every earlier page. The
    namespace (routing mode, solved budget, gate threshold) is folded into
    the chain seed because the token gate's keep decisions — and therefore
    the KV bytes on the page — depend on it.
    """
    toks = np.asarray(tokens).reshape(-1)
    keys, h = [], hash(("pagedkv", tuple(namespace)))
    for i in range(len(toks) // page_size):
        blk = tuple(int(x) for x in toks[i * page_size:(i + 1) * page_size])
        h = hash((h, blk))
        keys.append(h)
    return keys


class PagePool:
    """Host-side allocator for the global KV page pool.

    ``n_pages`` counts TOTAL physical pages; each of the ``n_replicas``
    contiguous ranges donates its last id as the replica's trash page, so
    ``pages_per_replica - 1`` ids per replica are allocatable.
    """

    def __init__(self, n_pages: int, page_size: int, n_replicas: int = 1):
        if n_pages % n_replicas:
            raise ValueError(f"n_pages={n_pages} must be a multiple of "
                             f"n_replicas={n_replicas}")
        ppr = n_pages // n_replicas
        if ppr < 2:
            raise ValueError("need at least 2 pages per replica "
                             "(one allocatable + one trash)")
        self.n_pages, self.page_size = n_pages, page_size
        self.n_replicas, self.pages_per_replica = n_replicas, ppr
        # freelists are LIFO per replica; trash id excluded
        self._free = [list(range(r * ppr, (r + 1) * ppr - 1))[::-1]
                      for r in range(n_replicas)]
        self._ref = {}                      # page id -> refcount
        self._registry = {}                 # prefix key -> page id
        self._page_keys = {}                # page id -> set of prefix keys
        self.peak_allocated = 0

    # ------------------------------ placement ------------------------------

    def trash_page(self, replica: int) -> int:
        return (replica + 1) * self.pages_per_replica - 1

    def replica_of(self, page: int) -> int:
        return page // self.pages_per_replica

    @property
    def usable_per_replica(self) -> int:
        return self.pages_per_replica - 1

    def n_free(self, replica: int) -> int:
        return len(self._free[replica])

    def can_alloc(self, replica: int, n: int) -> bool:
        return self.n_free(replica) >= n

    # ----------------------------- alloc / free ----------------------------

    def alloc(self, replica: int, n: int):
        """-> list of ``n`` fresh page ids (refcount 1), or None if the
        replica's freelist cannot cover the request (caller preempts)."""
        if n < 0:
            raise ValueError("n < 0")
        if len(self._free[replica]) < n:
            return None
        ids = [self._free[replica].pop() for _ in range(n)]
        for p in ids:
            self._ref[p] = 1
        self.peak_allocated = max(self.peak_allocated, self.allocated)
        return ids

    def incref(self, page: int):
        self._ref[page] += 1

    def free(self, pages):
        """Decref every id; pages hitting zero return to their replica's
        freelist and are purged from the prefix registry."""
        for p in pages:
            p = int(p)
            if p < 0:
                continue
            if p not in self._ref:
                raise RuntimeError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                for k in self._page_keys.pop(p, ()):
                    self._registry.pop(k, None)
                self._free[self.replica_of(p)].append(p)

    # ---------------------------- prefix sharing ---------------------------

    def register_prefix(self, key, page: int):
        """Publish a fully-written prompt page under its chain key."""
        self._registry[key] = page
        self._page_keys.setdefault(page, set()).add(key)

    def lookup_prefix(self, key, replica: int):
        """-> page id of a live page holding this prefix block on the
        given replica, else None (pages never cross replicas)."""
        p = self._registry.get(key)
        if p is None or self.replica_of(p) != replica:
            return None
        return p

    # -------------------------------- stats --------------------------------

    @property
    def allocated(self) -> int:
        return len(self._ref)

    @property
    def shared(self) -> int:
        return sum(1 for c in self._ref.values() if c > 1)

    def stats(self) -> dict:
        return {"allocated": self.allocated,
                "free": sum(len(f) for f in self._free),
                "shared": self.shared,
                "registered_prefixes": len(self._registry),
                "peak_allocated": self.peak_allocated,
                "page_size": self.page_size,
                "usable": self.usable_per_replica * self.n_replicas}


# --------------------------- traced pool helpers ---------------------------

def _leaf_name(path) -> str:
    key = path[-1]
    return getattr(key, "key", getattr(key, "name", str(key)))


def copy_page_in_tree(caches, src, dst, n_keep, *, page_size, cfg):
    """Copy page ``src`` -> ``dst`` in every pool leaf of a cache tree,
    keeping only the first ``n_keep`` positions valid — the copy-on-write
    step of ``ServingEngine.fork`` for the parent's partial tail page.

    ``src``/``dst``/``n_keep`` are traced scalars, so one compile serves
    every fork. Pool leaves are identified by name (``kp``/``vp`` rank 4,
    ``kscale``/``vscale`` int8 dequant-scale pools rank 3, ``pvalid``
    rank 2, +1 leading dim per pattern-scan stack); the page axis is
    located from the rank, not the keystr.

    Quantized pools copy the int8 page AND its scale row VERBATIM —
    quantize-once-on-write (docs/quantization.md): re-quantizing a
    dequantized tail here would drift the child's bytes off the parent's,
    breaking fork/preemption-replay bit-stability. Invalidated positions
    (>= ``n_keep``) are masked via ``pvalid`` only.
    """
    keep = jnp.arange(page_size, dtype=jnp.int32) < n_keep
    _AX_OFF = {"kp": 4, "vp": 4, "kscale": 3, "vscale": 3, "pvalid": 2}

    def cp(path, leaf):
        name = _leaf_name(path)
        if name not in _AX_OFF:
            return leaf
        ax = leaf.ndim - _AX_OFF[name]
        row = jax.lax.dynamic_index_in_dim(leaf, src, axis=ax, keepdims=False)
        if name == "pvalid":
            row = row & keep
        out = jax.lax.dynamic_update_index_in_dim(leaf, row, dst, axis=ax)
        if name != "pvalid":
            out = SH.constrain_page_pool(out, cfg,
                                         scale=name in ("kscale", "vscale"))
        return out

    return jax.tree_util.tree_map_with_path(cp, caches)
