from repro.runtime import sharding
from repro.runtime.controller import SLOController, SLOTarget
from repro.runtime.elastic import (make_mesh, rescale_serving_state,
                                   rescale_training_state, reshard,
                                   valid_mesh_shapes)
from repro.runtime.fault_tolerance import (FailureInjector, SimulatedFailure,
                                           StragglerWatchdog, maybe_escalate,
                                           remesh_fallback, run_resilient,
                                           serve_resilient)
from repro.runtime.pagedkv import PagePool
from repro.runtime.scheduler import RequestHandle, SlotScheduler
