"""Fault tolerance & straggler mitigation for the training loop.

Single-controller view of what runs per-host at pod scale:
  * StragglerWatchdog — EWMA of step wall-times; a step exceeding
    `threshold x` the EWMA flags the slow host (here: logs + counter; on a
    real fleet this feeds the re-dispatch / hot-spare controller).
  * run_resilient — supervision loop: on any step failure it restores the
    latest verified checkpoint (params/opt/data state) and replays from
    there. Deterministic data (pipeline.batch_at(step)) makes the replay
    bitwise-reproducible — asserted by tests/test_fault_tolerance.py.
  * FailureInjector — deterministic fault injection for tests/drills.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise SimulatedFailure at the given steps (once each)."""
    at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatchdog:
    threshold: float = 2.5
    decay: float = 0.9
    ewma: Optional[float] = None
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (ewma %.3fs) — "
                        "flagging for re-dispatch", step, dt, self.ewma)
        self.ewma = dt if self.ewma is None else \
            self.decay * self.ewma + (1 - self.decay) * dt
        return slow


def run_resilient(
    *, start_step: int, total_steps: int,
    do_step: Callable[[int], dict],
    save: Callable[[int], None], restore: Callable[[], int],
    save_every: int = 50, max_restarts: int = 10,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
):
    """Supervised training loop. `do_step(step)` runs one step and returns
    metrics; `save(step)` checkpoints; `restore()` reloads the latest
    checkpoint and returns its step. Returns (last_metrics, n_restarts)."""
    step = start_step
    restarts = 0
    metrics = {}
    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.perf_counter()
            metrics = do_step(step)
            if watchdog is not None:
                watchdog.observe(step, time.perf_counter() - t0)
            step += 1
            if step % save_every == 0 or step == total_steps:
                save(step)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restoring latest checkpoint",
                        step, e)
            step = restore()
    return metrics, restarts
