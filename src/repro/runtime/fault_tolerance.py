"""Fault tolerance & straggler mitigation for the training AND serving loops.

Single-controller view of what runs per-host at pod scale:
  * StragglerWatchdog — EWMA of step wall-times; a step exceeding
    `threshold x` the EWMA flags the slow host (here: logs + counter; on a
    real fleet this feeds the re-dispatch / hot-spare controller).
  * run_resilient — training supervision loop: on any step failure it
    restores the latest verified checkpoint (params/opt/data state) and
    replays from there. Deterministic data (pipeline.batch_at(step)) makes
    the replay bitwise-reproducible — asserted by
    tests/test_fault_tolerance.py.
  * serve_resilient — the serving twin: on a step failure the ServingEngine
    drains and RE-MESHES onto a fallback (data, model) shape instead of
    killing the server — in-flight requests live in the slot caches, which
    `engine.reshard` moves, so they resume with identical tokens.
  * FailureInjector — deterministic fault injection for tests/drills.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise SimulatedFailure at the given steps (once each)."""
    at_steps: tuple = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatchdog:
    threshold: float = 2.5
    decay: float = 0.9
    ewma: Optional[float] = None
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (ewma %.3fs) — "
                        "flagging for re-dispatch", step, dt, self.ewma)
            # A flagged sample is EXCLUDED from the baseline (its dt is
            # clamped out of the EWMA entirely): folding a straggler's dt
            # in would inflate the baseline by up to
            # `decay + (1-decay)*threshold` per flagged step, so a
            # sustained slowdown would stop being flagged after a few
            # steps — exactly the signal the watchdog exists to hold.
            # The EWMA tracks what a HEALTHY step costs; stragglers are
            # anomalies against it, not contributors to it.
        else:
            self.ewma = dt if self.ewma is None else \
                self.decay * self.ewma + (1 - self.decay) * dt
        return slow


def run_resilient(
    *, start_step: int, total_steps: int,
    do_step: Callable[[int], dict],
    save: Callable[[int], None], restore: Callable[[], int],
    save_every: int = 50, max_restarts: int = 10,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
):
    """Supervised training loop. `do_step(step)` runs one step and returns
    metrics; `save(step)` checkpoints; `restore()` reloads the latest
    checkpoint and returns its step. Returns (last_metrics, n_restarts)."""
    step = start_step
    restarts = 0
    metrics = {}
    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.perf_counter()
            metrics = do_step(step)
            if watchdog is not None:
                watchdog.observe(step, time.perf_counter() - t0)
            step += 1
            if step % save_every == 0 or step == total_steps:
                save(step)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restoring latest checkpoint",
                        step, e)
            step = restore()
    return metrics, restarts


def remesh_fallback(engine, shapes: list) -> object:
    """Drain + re-mesh ``engine`` onto the first usable shape popped from
    ``shapes`` (mutated in place). An unusable shape (fewer devices left
    than it needs, batch not divisible by its data axis) is skipped rather
    than allowed to kill the server — the exhausted list still ends at the
    single-device fallback (``None`` mesh). Returns the mesh re-meshed to
    (``None`` for single device). Raises only when even the single-device
    fallback fails."""
    from repro.runtime.elastic import make_mesh
    while True:
        shape = shapes.pop(0) if shapes else None
        try:
            mesh = (make_mesh(shape, ("data", "model"))
                    if shape is not None else None)
            engine.reshard(mesh)
        except Exception as fe:
            if shape is None:         # even 1 device failed: give up
                raise
            log.warning("fallback shape %s unusable (%s); trying "
                        "the next", shape, fe)
            continue
        return mesh


def maybe_escalate(engine, shapes: list) -> bool:
    """SLO-controller saturation -> remesh escalation (degradation stage
    4): when the engine's controller has been pinned at the floor budget
    past its patience (``should_escalate``), drain + re-mesh onto the next
    fallback shape so the replica axis itself grows/changes — the knob
    beyond the budget knob. Consumes the escalation either way (a declined
    escalation — no shapes left, or a paged engine that cannot reshard —
    must not re-fire every step). Returns True if a remesh happened."""
    ctrl = getattr(engine, "controller", None)
    if ctrl is None or not getattr(ctrl, "should_escalate", False):
        return False
    if not shapes or getattr(engine, "kv_layout", "ring") != "ring":
        log.warning("controller escalation declined: %s",
                    "no fallback shapes left" if not shapes
                    else "paged engine cannot reshard live")
        ctrl.notify_remeshed()
        return False
    mesh = remesh_fallback(engine, shapes)
    log.warning("controller saturated at floor budget; escalated to %s",
                "1 device" if mesh is None else dict(mesh.shape))
    ctrl.notify_remeshed()
    return True


def serve_resilient(
    engine, *,
    fallback_shapes=(), max_restarts: int = 3,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
):
    """Drive ``engine.step()`` until idle, surviving replica failures.

    On a step failure (``SimulatedFailure`` from the injector — the stand-in
    for a lost replica/host) the engine drains and re-meshes onto the next
    entry of ``fallback_shapes`` (``(data, model)`` tuples, e.g. from
    ``runtime.elastic.valid_mesh_shapes`` after losing devices; an exhausted
    list falls back to a single device) instead of the failure killing the
    server. In-flight requests are NOT dropped: their state is the slot
    caches, which ``engine.reshard`` moves, so every running request resumes
    with identical (bitwise, greedy) tokens on the new mesh.

    If the engine carries an ``SLOController`` that saturates at the floor
    budget (``should_escalate``), the SAME fallback-shape path runs as a
    proactive escalation (``maybe_escalate``) — degradation stage 4.

    Returns ``(n_steps, n_restarts)``."""
    shapes = list(fallback_shapes)
    steps = restarts = 0
    while engine.has_work:
        try:
            maybe_escalate(engine, shapes)
            if injector is not None:
                injector.maybe_fail(steps)
            t0 = time.perf_counter()
            engine.step()
            if watchdog is not None:
                watchdog.observe(steps, time.perf_counter() - t0)
            steps += 1
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            mesh = remesh_fallback(engine, shapes)
            log.warning("serving step %d failed (%s); drained + "
                        "re-meshed to %s", steps, e,
                        "1 device" if mesh is None else dict(mesh.shape))
    return steps, restarts
