"""Logical->physical sharding rules (Megatron-style TP on the `model` axis,
DP over (`pod`,`data`)).

Rules are name+rank based over the parameter pytree, so one table covers all
ten architectures. Uneven head counts (phi3 40H, qwen2 28H, recurrentgemma
10H over a 16-way model axis) rely on GSPMD implicit padding — documented in
DESIGN.md §4.

KV caches shard kv-heads over `model` when divisible, else fall back to
sharding head_dim (always 128 | 64) — the fallback's extra collectives are a
§Perf target.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_axis_size(mesh: Optional[Mesh]) -> int:
    """Product of the data axes' sizes — the data-parallel replica count.
    THE definition shared by the serving scheduler's replica axis and the
    kernel wrappers' batch-shard predicates (they must agree: the scheduler
    packs per replica exactly what one batch shard decodes)."""
    if mesh is None:
        return 1
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape.get(a, 1)
    return n


def shard_map_compat(body, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma=False):
    """Version-compatible shard_map: newer JAX exposes ``jax.shard_map``
    (axis_names/check_vma kwargs); 0.4.x has only
    ``jax.experimental.shard_map.shard_map`` (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=check_vma)


def abstract_mesh(shape, axes):
    """Version-compatible ``jax.sharding.AbstractMesh`` constructor: newer
    JAX takes ``(axis_sizes, axis_names)``, older releases a single
    ``((name, size), ...)`` shape tuple. Lets tests exercise production
    (16, 16) axis sizes without 256 devices."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def active_mesh() -> Optional[Mesh]:
    """The mesh installed by `with mesh:` at trace time (None outside)."""
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    return m if m.axis_names else None


def constrain_batch(x):
    """Pin an activation's leading (batch) dim to the data axes — GSPMD
    loses batch parallelism through batch-indexed gather/scatter (§Perf H2:
    the MoE combine scatter was replicated to the full global batch).
    No-op outside a mesh context or when batch doesn't divide."""
    m = active_mesh()
    if m is None:
        return x
    spec = _fit_spec(batch_spec(m, x.ndim - 1), x.shape, m)
    return jax.lax.with_sharding_constraint(x, spec)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# ----------------------------- parameters -----------------------------------

_RULES = [
    # (regex on keystr tail, rank, PartitionSpec)
    (r"\['embed'\]$", 2, P("model", None)),            # (V, D) vocab-sharded
    (r"\['lm_head'\]$", 2, P(None, "model")),          # (D, V)
    (r"\['w[qkv]'\]$", 3, P(None, "model", None)),     # (D, H, Dh) heads
    (r"\['b[qkv]'\]$", 2, P("model", None)),           # (H, Dh)
    (r"\['mlp'\].*\['w[ig]'\]$", 3, P(None, None, "model")),  # MoE (E,D,Fe) TP-on-F
    (r"\['mlp'\].*\['wo'\]$", 3, P(None, "model", None)),     # MoE (E,Fe,D)
    (r"\['mlp'\].*\['w[ig]'\]$", 2, P(None, "model")),   # dense (D, F)
    (r"\['mlp'\].*\['wo'\]$", 2, P("model", None)),      # dense (F, D)
    (r"\['wo'\]$", 3, P("model", None, None)),         # attn out (H, Dh, D)
    (r"\['router'\]$", 2, P()),                        # tiny, replicated
    # mamba2
    (r"\['in_[zx]'\]$", 2, P(None, "model")),          # (D, d_inner)
    (r"\['in_dt'\]$", 2, P(None, "model")),            # (D, H)
    (r"\['in_[bc]'\]$", 2, P()),                       # group-shared, small
    (r"\['conv_x'\]$", 2, P(None, "model")),
    (r"\['(a_log|d_skip|dt_bias)'\]$", 1, P("model")),
    (r"\['norm_scale'\]$", 1, P("model")),
    (r"\['out_proj'\]$", 2, P("model", None)),         # (d_inner, D)
    # rg-lru
    (r"\['w_[yx]'\]$", 2, P(None, "model")),           # (D, W)
    (r"\['conv_w'\]$", 2, P(None, "model")),
    (r"\['conv_b'\]$", 1, P("model")),
    (r"\['w_[ai]'\]$", 2, P(None, "model")),           # (W, W) col-sharded
    (r"\['(b_a|b_i|lam)'\]$", 1, P("model")),
    (r"\['w_out'\]$", 2, P("model", None)),            # (W, D)
    # frontends
    (r"\['in_proj'\]$", 2, P()),
]


def _spec_for(key: str, leaf) -> P:
    """Rules match the UNSTACKED rank; each ['scan'] level adds one leading
    stacked-layer dim which gets a None prepended."""
    n_lead = key.count("['scan']")
    rank = getattr(leaf, "ndim", 0) - n_lead
    for pat, r, spec in _RULES:
        if r == rank and re.search(pat, key):
            return P(*([None] * n_lead + list(spec)))
    return P()  # norms, routers, LoRA, scalars: replicated


def _fit_spec(spec: P, shape, mesh: Optional[Mesh],
              relocate: bool = False) -> P:
    """pjit in_shardings require every sharded dim to divide the axis size
    (GSPMD implicit padding applies to intermediates, not arguments).

    For each axis whose dim does not divide: REPLICATE it by default —
    relocating a sharding onto a contraction dim (e.g. qwen2 kv weights
    (D, 4, 128) -> head_dim) turns every matmul into partial sums plus a
    giant all-reduce (§Perf H1 found 178 GB/layer of score all-reduces).
    Weights that cannot shard are small (kv heads); q-heads are padded to
    divisibility at init instead. `relocate=True` keeps the move-to-another-
    dim behaviour for KV caches, where memory capacity (not collectives)
    is the binding constraint."""
    if mesh is None:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, ax in enumerate(dims):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        if shape[i] % size == 0:
            continue
        dims[i] = None
        if relocate:
            cands = [j for j, d in enumerate(dims)
                     if dims[j] is None and j != i and shape[j] % size == 0]
            if cands:
                dims[max(cands, key=lambda j: shape[j])] = ax
    return P(*dims)


def param_specs(params, mesh: Optional[Mesh] = None) -> dict:
    """PartitionSpec pytree matching `params` (divisibility-checked when a
    mesh is given)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_fit_spec(_spec_for(jax.tree_util.keystr(path), leaf),
                       getattr(leaf, "shape", ()), mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ----------------------------- activations ----------------------------------

def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    return P(batch_axes(mesh), *([None] * extra_dims))


def input_shardings(specs: dict, mesh: Mesh):
    """Shard every model input on its batch (leading) dim (replicating when
    batch < mesh axis, e.g. long_500k's global_batch=1)."""
    return {k: NamedSharding(mesh, _fit_spec(batch_spec(mesh, v.ndim - 1),
                                             v.shape, mesh))
            for k, v in specs.items()}


def fitted(spec: P, shape, mesh: Mesh) -> NamedSharding:
    """NamedSharding for `spec` with divisibility fallback."""
    return NamedSharding(mesh, _fit_spec(spec, shape, mesh))


# ------------------------------- caches -------------------------------------

def attn_kv_spec(cfg, mesh: Mesh, lead: int = 0) -> P:
    """The ONE placement rule for a (B, L, K, Dh) attention-cache tensor:
    kv-heads over `model` when divisible, else head_dim (always 128 | 64).
    Shared by `cache_specs_tree` (the jit out_shardings pin) and
    `constrain_kv_cache` (the decode write-site pin) — the two MUST agree
    or every compiled decode step pays a cache re-layout copy."""
    kv_div = cfg.n_kv_heads and cfg.n_kv_heads % model_axis_size(mesh) == 0
    tail = (None, "model", None) if kv_div else (None, None, "model")
    return P(*([None] * lead), batch_axes(mesh), *tail)


def page_pool_spec(cfg, mesh: Mesh, lead: int = 0) -> P:
    """The ONE placement rule for an (N, page_size, K, Dh) paged KV POOL
    tensor (`runtime/pagedkv.py`): the page axis shards over the data axes
    — replica locality of page ids makes pool-shard == scheduler-replica —
    and kv-heads over `model` when divisible, else head_dim. Shared by
    `cache_specs_tree` (the jit out_shardings pin) and
    `constrain_page_pool` (the page-write pins) — they MUST agree or every
    compiled step pays a pool re-layout copy."""
    kv_div = cfg.n_kv_heads and cfg.n_kv_heads % model_axis_size(mesh) == 0
    tail = (None, "model", None) if kv_div else (None, None, "model")
    return P(*([None] * lead), batch_axes(mesh), *tail)


def kv_scale_spec(cfg, mesh: Mesh, lead: int = 0) -> P:
    """Placement for an int8-KV dequant-scale leaf (docs/quantization.md):
    ring (B, L, K) and paged (N, page_size, K) share one layout — leading
    axis over the data axes, kv-heads over `model` when divisible. The
    head_dim fallback of `attn_kv_spec`/`page_pool_spec` has no analogue
    here (scales carry no Dh axis), so the K axis replicates instead."""
    kv_div = cfg.n_kv_heads and cfg.n_kv_heads % model_axis_size(mesh) == 0
    return P(*([None] * lead), batch_axes(mesh), None,
             "model" if kv_div else None)


def constrain_kv_scale(x, cfg):
    """Pin a (B, L, K) ring-cache scale leaf at its write sites — the
    scale twin of `constrain_kv_cache`, sharing `kv_scale_spec` with the
    jit out_shardings pin. No-op outside a mesh context."""
    m = active_mesh()
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, _fit_spec(kv_scale_spec(cfg, m), x.shape, m, relocate=True))


def constrain_page_pool(x, cfg, scale: bool = False):
    """Pin a page-pool leaf at its WRITE sites (chunked-prefill page
    writes, decode per-slot appends, fork's CoW page copy) under the
    active mesh — the paged twin of `constrain_kv_cache`: the writes are
    page-indexed scatters GSPMD would otherwise resolve by replicating the
    whole pool every step. Rank >= 4 is a K/V pool (page axis at
    ndim - 4); rank 3 is an int8 dequant-scale pool (N, page_size, K);
    rank 2 is a per-lane validity pool (page axis at ndim - 2). Pass
    ``scale=True`` for a scale pool with extra leading (pattern-scan)
    dims, where rank alone cannot tell it from a K/V pool. No-op outside
    a mesh context."""
    m = active_mesh()
    if m is None:
        return x
    if scale or x.ndim == 3:
        spec = kv_scale_spec(cfg, m, lead=x.ndim - 3)
    elif x.ndim >= 4:
        spec = page_pool_spec(cfg, m, lead=x.ndim - 4)
    else:
        spec = P(*([None] * (x.ndim - 2)), batch_axes(m), None)
    return jax.lax.with_sharding_constraint(
        x, _fit_spec(spec, x.shape, m, relocate=True))


def cache_specs_tree(cache_shapes, cfg, mesh: Mesh):
    """PartitionSpecs for a cache pytree (from models.cache_specs)."""
    ba = batch_axes(mesh)

    def spec(path, leaf):
        key = jax.tree_util.keystr(path)
        nscan = key.count("['scan']")
        lead = [None] * nscan
        if key.endswith("['kp']") or key.endswith("['vp']"):
            return page_pool_spec(cfg, mesh, lead=nscan)
        if key.endswith("['pvalid']"):
            return P(*lead, ba, None)
        if key.endswith("['kscale']") or key.endswith("['vscale']"):
            # int8 dequant scales: ring (B, L, K) and paged (N, ps, K)
            # share kv_scale_spec — MUST precede the ['attn'] fallback
            # (which assumes the rank-4 K/V layout)
            return kv_scale_spec(cfg, mesh, lead=nscan)
        if "['attn']" in key or "['xattn']" in key:
            if key.endswith("['valid']") or key.endswith("['pos']"):
                return P(*lead, ba, None)
            return attn_kv_spec(cfg, mesh, lead=nscan)
        if key.endswith("['state']") and leaf.ndim - nscan == 4:   # ssm
            return P(*lead, ba, "model", None, None)
        if key.endswith("['state']"):                               # rglru
            return P(*lead, ba, "model")
        if key.endswith("['conv']"):
            return P(*lead, ba, None, None)
        return P(*lead, ba)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [_fit_spec(spec(p, l), l.shape, mesh, relocate=True)
                  for p, l in flat])


def cache_shardings(cache_shapes, cfg, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs_tree(cache_shapes, cfg, mesh))


def constrain_kv_cache(x, cfg):
    """Pin a (B, L, K, Dh) ring-cache tensor to the serving cache rules
    (kv-heads over `model` when divisible, else head_dim; batch over the
    data axes) under the active mesh. Applied at the two cache WRITE sites
    — `prefill_into_slot`'s row splice and `attn_decode`'s per-row scatter
    — where GSPMD would otherwise replicate the batch-indexed update to the
    full global cache. No-op outside a mesh context."""
    m = active_mesh()
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, _fit_spec(attn_kv_spec(cfg, m), x.shape, m, relocate=True))


def constrain_kv_mask(x, cfg):
    """Pin a (B, L) ring-cache mask leaf (``valid`` / ``pos``) at its
    decode WRITE sites — the per-layer KV-validity mask the elastic depth
    router drives: a (slot, layer) the router skips writes no KV there, so
    ``valid`` stays False and attention masks the lane branch-free. The
    write is the same batch-indexed scatter as the K/V one, so GSPMD would
    otherwise replicate the mask to the full global batch every decode
    step. Shares ``cache_specs_tree``'s P(batch_axes, None) placement.
    No-op outside a mesh context."""
    m = active_mesh()
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, _fit_spec(P(batch_axes(m), *([None] * (x.ndim - 1))),
                     x.shape, m))


def constrain_cache_tree(caches, cfg):
    """with_sharding_constraint every leaf of a serving cache pytree to its
    `cache_specs_tree` spec under the active mesh (no-op outside one) — the
    row-splice twin of `constrain_kv_cache`, covering all cache kinds
    (attn/xattn k/v rings, ssm/rglru state, valid/pos)."""
    m = active_mesh()
    if m is None:
        return caches
    specs = cache_specs_tree(caches, cfg, m)
    return jax.tree.map(jax.lax.with_sharding_constraint, caches, specs)
