"""SLO-driven graceful degradation: the feedback loop on the budget knob.

ElastiFormer makes compute a runtime knob (a traced ``ElasticPolicy`` row
per request); everything up to here sets that knob by hand via
``--budget``. ``SLOController`` closes the loop: it watches per-replica
latency percentiles (time-to-first-token and inter-token latency, sourced
from the per-token timestamps on ``RequestHandle``) plus queue depth over
a sliding window, and when an SLO is threatened degrades service in
stages — each stage strictly cheaper than the next:

1. **Degrade admission budgets** — newly admitted requests get
   ``min(requested, admission_budget)``; the roofline solver turns that
   into a sparser policy row AND a smaller scheduler cost, so the same
   FLOP budget co-schedules more requests.
2. **Degrade the depth budget** — when the spec routes depth, the engine
   caps ``ElasticPolicy.depth_capacity`` at ``depth_budget`` for new AND
   in-flight rows (a traced leaf: same compiled graphs, zero recompiles);
   whole-layer skips are the steepest FLOPs-per-quality knob after
   admission, and they compose multiplicatively with the token budget.
3. **Degrade in-flight budgets** — the engine splices degraded rows into
   the live ``(B,)`` policy via ``ElasticPolicy.set_row`` (a traced-index
   dynamic update: same ``{prefill: 1, decode: 1}`` graphs, zero
   recompiles) and re-prices the slots' scheduler costs.
4. **Shed load** — queued requests beyond what a floor-budget engine can
   drain are finished with a typed ``rejected`` terminal state and a
   ``Retry-After`` hint; expired deadlines become ``deadline_exceeded``.
5. **Escalate** — if the controller saturates at the floor budget for
   ``escalate_after`` consecutive evaluations and load is still over,
   ``should_escalate`` goes high and the serving loop may
   ``engine.reshard()`` onto a bigger mesh shape.

Restoration is **hysteretic**: budgets step back up only after the worst
violation ratio stays below ``hysteresis`` (< 1) for ``patience``
consecutive evaluations, in reverse stage order (in-flight, then depth,
then admission), so the controller cannot oscillate across the SLO
boundary.

Determinism contract: the controller NEVER reads a wall clock. Every
timestamp is injected — ``record_ttft`` / ``record_itl`` take measured
milliseconds, ``update(t, ...)`` takes the caller's clock — so a recorded
trace replays to a bit-identical budget trajectory (see
``tests/test_controller.py``).
"""
from __future__ import annotations

import logging
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

log = logging.getLogger("repro.controller")

DEFAULT_CLASS = "default"

# Budgets move on a fixed lattice so the engine's solved-row cache stays
# bounded: every controller-chosen budget is a multiple of BUDGET_QUANTUM.
BUDGET_QUANTUM = 1.0 / 16.0


def _quantize(b: float) -> float:
    return max(BUDGET_QUANTUM, round(b / BUDGET_QUANTUM) * BUDGET_QUANTUM)


def _p95(xs) -> float:
    """Deterministic p95 (linear interpolation, no numpy RNG involved)."""
    s = sorted(xs)
    if not s:
        return 0.0
    k = 0.95 * (len(s) - 1)
    lo = int(math.floor(k))
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


@dataclass(frozen=True)
class SLOTarget:
    """Per-tenant-class SLO: latency targets plus shed/deadline policy.

    ``math.inf`` targets are "don't care". ``shed_order`` breaks ties when
    the controller sheds: higher sheds first (batch traffic before
    interactive). ``deadline_ms`` is the default queue deadline applied to
    the class's requests at submit time (None = no deadline).
    """
    p95_ttft_ms: float = math.inf
    p95_itl_ms: float = math.inf
    shed_order: int = 0
    deadline_ms: Optional[float] = None


@dataclass
class SLOController:
    """Staged degrade/restore feedback controller over the elastic budget.

    All tunables are constructor fields; all state is explicit so tests
    can snapshot it. ``trajectory`` accumulates one row per evaluation —
    ``(t, ratio, admission, depth, inflight, shed, escalate)`` — and is
    the bit-reproducibility surface for the determinism test.
    """
    targets: Dict[str, SLOTarget] = field(
        default_factory=lambda: {DEFAULT_CLASS: SLOTarget()})
    floor: float = 0.25              # lowest budget any stage may impose
    step_down: float = 0.25          # degrade step per violating eval
    step_up: float = 0.125           # hysteretic restore step
    window: int = 64                 # sliding-window samples per metric
    min_samples: int = 4             # ignore windows thinner than this
    eval_interval_s: float = 0.25    # min injected-time between evals
    hysteresis: float = 0.7          # restore only while ratio < this
    patience: int = 3                # healthy evals required per restore
    queue_factor: float = 1.0        # healthy backlog = factor * capacity
    escalate_after: int = 4          # saturated evals before remesh ask
    retry_after_s: float = 1.0       # base Retry-After hint for shed
    sample_ttl_s: float = 10.0       # latency samples expire after this

    # ---- state (all deterministic; no wall-clock reads anywhere) ----
    admission_budget: float = 1.0
    depth_budget: float = 1.0
    inflight_budget: float = 1.0
    trajectory: List[Tuple[float, float, float, float, float, int,
                           bool]] = field(default_factory=list)
    events: List[Tuple[float, str, float]] = field(default_factory=list)
    shed_total: int = 0

    def __post_init__(self):
        if not (0.0 < self.floor <= 1.0):
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")
        self.floor = _quantize(self.floor)
        self._ttft: Dict[Tuple[str, int], Deque[float]] = {}
        self._itl: Dict[Tuple[str, int], Deque[float]] = {}
        self._last_eval: Optional[float] = None
        self._healthy = 0
        self._saturated = 0
        self._escalate_pending = False

    # ---- metric ingestion (engine hooks) ----
    def target_for(self, slo_class: str) -> SLOTarget:
        return self.targets.get(slo_class,
                                self.targets.get(DEFAULT_CLASS, SLOTarget()))

    def _window(self, store, slo_class: str, replica: int) -> Deque[float]:
        key = (slo_class, replica)
        w = store.get(key)
        if w is None:
            w = store[key] = deque(maxlen=self.window)
        return w

    def record_ttft(self, slo_class: str, replica: int, ms: float,
                    t: float = 0.0) -> None:
        """Admission-time hook: queue wait + prefill, in milliseconds.
        ``t`` is the sample's (injected) timestamp — samples older than
        ``sample_ttl_s`` at evaluation time are expired, so a quiet period
        cannot pin the controller to stale overload percentiles forever."""
        self._window(self._ttft, slo_class, replica).append(
            (float(t), float(ms)))

    def record_itl(self, slo_class: str, replica: int, ms: float,
                   t: float = 0.0) -> None:
        """Decode-step hook: gap between consecutive tokens of one slot."""
        self._window(self._itl, slo_class, replica).append(
            (float(t), float(ms)))

    def _expire_samples(self, t: float) -> None:
        horizon = t - self.sample_ttl_s
        for store in (self._ttft, self._itl):
            for w in store.values():
                while w and w[0][0] < horizon:
                    w.popleft()

    # ---- observability ----
    def pressure(self, queue_depth: int = 0, capacity: int = 1) -> float:
        """Worst violation ratio: max over (class, replica) windows of
        observed-p95 / target, plus the queue-backlog ratio. > 1 means an
        SLO is threatened; < ``hysteresis`` means comfortably healthy."""
        ratio = 0.0
        for store, attr in ((self._ttft, "p95_ttft_ms"),
                            (self._itl, "p95_itl_ms")):
            for (cls, _rep), w in store.items():
                if len(w) < self.min_samples:
                    continue
                tgt = getattr(self.target_for(cls), attr)
                if math.isfinite(tgt) and tgt > 0:
                    ratio = max(ratio, _p95([ms for _t, ms in w]) / tgt)
        if capacity > 0:
            ratio = max(ratio,
                        queue_depth / (self.queue_factor * capacity))
        return ratio

    @property
    def should_escalate(self) -> bool:
        """True once the controller has saturated at the floor budget for
        ``escalate_after`` evals with load still over — the serving loop
        should ``engine.reshard()`` to a bigger shape and then call
        ``notify_remeshed()``."""
        return self._escalate_pending

    def notify_remeshed(self) -> None:
        """The serving loop handled (or declined) the escalation; rearm."""
        self._escalate_pending = False
        self._saturated = 0

    def retry_after(self, ratio: float) -> float:
        """Retry-After hint (seconds) scaled by how far over SLO we are."""
        return round(self.retry_after_s * max(1.0, ratio), 3)

    def admission_cap(self) -> Optional[float]:
        """Budget cap for NEW admissions; None when not degraded."""
        return None if self.admission_budget >= 1.0 else self.admission_budget

    def depth_cap(self) -> Optional[float]:
        """Cap on ``ElasticPolicy.depth_capacity`` for all rows (new and
        in-flight); None when not degraded. Engines whose spec does not
        route depth ignore it — the ladder then behaves as if the stage
        were absent except for the extra evaluations it absorbs."""
        return None if self.depth_budget >= 1.0 else self.depth_budget

    # ---- the control step ----
    def update(self, t: float, *, queue_depth: int,
               capacity: int) -> Dict[str, object]:
        """One control evaluation at injected time ``t`` (seconds, any
        monotone origin). Rate-limited to ``eval_interval_s``. Returns
        ``{"evaluated", "ratio", "shed", "escalate"}`` — ``shed`` is how
        many queued requests the caller should reject now, ``escalate``
        is the saturation->remesh edge (also latched on
        ``should_escalate``)."""
        out = {"evaluated": False, "ratio": 0.0, "shed": 0,
               "escalate": False}
        if (self._last_eval is not None
                and t - self._last_eval < self.eval_interval_s):
            return out
        self._last_eval = t
        self._expire_samples(t)
        ratio = self.pressure(queue_depth=queue_depth, capacity=capacity)
        out["evaluated"] = True
        out["ratio"] = ratio
        shed = 0
        escalate = False
        eps = 1e-9
        if ratio > 1.0 + eps:
            self._healthy = 0
            if self.admission_budget > self.floor + eps:
                self.admission_budget = _quantize(
                    max(self.floor, self.admission_budget - self.step_down))
                self.events.append((t, "degrade_admission",
                                    self.admission_budget))
            elif self.depth_budget > self.floor + eps:
                self.depth_budget = _quantize(
                    max(self.floor, self.depth_budget - self.step_down))
                self.events.append((t, "degrade_depth", self.depth_budget))
            elif self.inflight_budget > self.floor + eps:
                self.inflight_budget = _quantize(
                    max(self.floor, self.inflight_budget - self.step_down))
                self.events.append((t, "degrade_inflight",
                                    self.inflight_budget))
            else:
                # saturated at the floor: shed what a floor-budget engine
                # cannot drain, and count down to escalation
                self._saturated += 1
                keep = int(math.ceil(self.queue_factor * capacity))
                shed = max(0, int(queue_depth) - keep)
                if shed:
                    self.shed_total += shed
                    self.events.append((t, "shed", float(shed)))
                if (self._saturated >= self.escalate_after
                        and not self._escalate_pending):
                    self._escalate_pending = True
                    escalate = True
                    self.events.append((t, "escalate", 0.0))
        else:
            self._saturated = 0
            if ratio < self.hysteresis:
                self._healthy += 1
                if (self._healthy >= self.patience
                        and (self.admission_budget < 1.0 - eps
                             or self.depth_budget < 1.0 - eps
                             or self.inflight_budget < 1.0 - eps)):
                    # restore in reverse stage order: in-flight, depth,
                    # then admission
                    if self.inflight_budget < 1.0 - eps:
                        self.inflight_budget = _quantize(min(
                            1.0, self.inflight_budget + self.step_up))
                        self.events.append((t, "restore_inflight",
                                            self.inflight_budget))
                    elif self.depth_budget < 1.0 - eps:
                        self.depth_budget = _quantize(min(
                            1.0, self.depth_budget + self.step_up))
                        self.events.append((t, "restore_depth",
                                            self.depth_budget))
                    else:
                        self.admission_budget = _quantize(min(
                            1.0, self.admission_budget + self.step_up))
                        self.events.append((t, "restore_admission",
                                            self.admission_budget))
                    self._healthy = 0
            else:
                self._healthy = 0   # inside the hysteresis band: hold
        out["shed"] = shed
        out["escalate"] = escalate
        self.trajectory.append((t, ratio, self.admission_budget,
                                self.depth_budget, self.inflight_budget,
                                shed, escalate))
        return out

    def summary(self) -> Dict[str, object]:
        """Counters for reports: events by kind + final budgets."""
        kinds: Dict[str, int] = {}
        for _t, kind, _v in self.events:
            kinds[kind] = kinds.get(kind, 0) + 1
        return {"admission_budget": self.admission_budget,
                "depth_budget": self.depth_budget,
                "inflight_budget": self.inflight_budget,
                "shed_total": self.shed_total,
                "evals": len(self.trajectory),
                "events": kinds}
