"""granite-34b [dense] — 88L d=6144 48H (MQA kv=1) ff=24576 V=49152.

Llama-style code model with multi-query attention. [arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152, d_head=128,
        act="gelu", norm="layernorm", qkv_bias=True, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=192, vocab_size=512, d_head=16,
        act="gelu", norm="layernorm", qkv_bias=True,
    )


register("granite-34b", full, smoke)
