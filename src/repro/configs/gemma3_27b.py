"""gemma3-27b [dense] — 62L d=5376 32H (GQA kv=16) ff=21504 V=262144.

5 local (sliding window 1024) : 1 global attention, 128k context.
[hf:google/gemma-3 family]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab_size=262144, d_head=128,
        act="geglu", norm="rmsnorm", rope_theta=1_000_000.0,
        window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
        max_seq_len=524_288, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=512, d_head=16,
        act="geglu", norm="rmsnorm",
        window_pattern=(16, 0), tie_embeddings=True,
    )


register("gemma3-27b", full, smoke)
