"""qwen2-7b [dense] — 28L d=3584 28H (GQA kv=4) ff=18944 V=152064, QKV bias.

[arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab_size=152064, d_head=128,
        act="swiglu", norm="rmsnorm", qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=176, vocab_size=512, d_head=16,
        act="swiglu", norm="rmsnorm", qkv_bias=True,
    )


register("qwen2-7b", full, smoke)
