"""whisper-medium [audio] — enc-dec, 24L each, d=1024 16H ff=4096 V=51865.

Conv frontend is a STUB per assignment: input_specs() provides precomputed
frame embeddings (B, encoder_seq, d_model). [arXiv:2212.04356]
"""
from repro.configs.base import ElasticConfig, ModelConfig, register


def _encoder(d, layers, heads, ff, seq):
    return ModelConfig(
        name="whisper-enc", family="encoder",
        n_layers=layers, d_model=d, n_heads=heads, n_kv_heads=heads,
        d_ff=ff, vocab_size=0, d_head=d // heads,
        act="gelu", norm="layernorm", qkv_bias=True,
        mixer_pattern=("attn",), encoder_seq=seq,
    )


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865, d_head=64,
        act="gelu", norm="layernorm", qkv_bias=True,
        mixer_pattern=("xattn",),          # every decoder layer cross-attends
        encoder=_encoder(1024, 24, 16, 4096, 1500),
        encoder_seq=1500,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=192, vocab_size=512, d_head=16,
        act="gelu", norm="layernorm", qkv_bias=True,
        mixer_pattern=("xattn",),
        encoder=_encoder(64, 2, 4, 192, 24),
        encoder_seq=24,
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    # encoder-output token selection before cross-attn == the paper's VLM
    # image-token selection scheme applied to audio frames.
    return ElasticConfig(
        mlp_token_capacity=0.8, mha_token_capacity=0.8,
        mha_head_topk=cfg.n_heads // 2, mlp_n_experts=16, mlp_expert_topk=9,
        vlm_token_capacity=0.6, lora_rank=1,
    )


register("whisper-medium", full, smoke, elastic)
