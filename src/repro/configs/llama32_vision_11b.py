"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (GQA kv=8) ff=14336 V=128256.

Cross-attention image layers every 5th layer. Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ElasticConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, d_head=128,
        act="swiglu", norm="rmsnorm", rope_theta=500_000.0,
        mixer_pattern=("attn", "attn", "attn", "attn", "xattn"),
        n_image_tokens=1601, d_frontend=1280,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke", family="vlm",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=512, d_head=16,
        act="swiglu", norm="rmsnorm",
        mixer_pattern=("attn", "attn", "attn", "attn", "xattn"),
        n_image_tokens=16, d_frontend=32,
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    # paper §5.3: image-token subset selection before the language decoder.
    return ElasticConfig(
        mlp_token_capacity=0.8, mha_token_capacity=0.8,
        mha_head_topk=cfg.n_heads // 2, mlp_n_experts=16, mlp_expert_topk=9,
        vlm_token_capacity=0.6, vlm_router="linear", lora_rank=1,
    )


register("llama-3.2-vision-11b", full, smoke, elastic)
