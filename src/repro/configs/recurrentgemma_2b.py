"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) ff=7680 V=256000.

RG-LRU + local attention, pattern (rglru, rglru, attn). [arXiv:2402.19427]
"""
from repro.configs.base import ElasticConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab_size=256000, d_head=256,
        act="geglu", norm="rmsnorm",
        mixer_pattern=("rglru", "rglru", "attn"),
        window_pattern=(0, 0, 2048),   # attention layers use local window 2048
        lru_width=2560, conv_kernel=4,
        tie_embeddings=True, max_seq_len=1_048_576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=192, vocab_size=512, d_head=16,
        act="geglu", norm="rmsnorm",
        mixer_pattern=("rglru", "rglru", "attn"),
        window_pattern=(0, 0, 16),
        lru_width=64, conv_kernel=4, tie_embeddings=True,
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    return ElasticConfig(
        mlp_token_capacity=0.8, mha_token_capacity=0.8,
        mha_head_topk=cfg.n_heads // 2, mlp_n_experts=16, mlp_expert_topk=9,
        lora_rank=1,
    )


register("recurrentgemma-2b", full, smoke, elastic)
