"""Config registry: importing this package registers all architectures."""
from repro.configs.base import (
    REGISTRY, SHAPES, ElasticConfig, ModelConfig, MoEConfig, ShapeConfig,
    default_elastic, get_config, get_elastic, list_archs, shape_applicable,
)

# Assigned architectures (registration side effects).
from repro.configs import (  # noqa: F401
    phi3_medium_14b, gemma3_27b, qwen2_7b, granite_34b, mamba2_780m,
    qwen2_moe_a2p7b, grok1_314b, recurrentgemma_2b, whisper_medium,
    llama32_vision_11b, elasti_toy,
)

ASSIGNED = [
    "phi3-medium-14b", "gemma3-27b", "qwen2-7b", "granite-34b",
    "mamba2-780m", "qwen2-moe-a2.7b", "grok-1-314b", "recurrentgemma-2b",
    "whisper-medium", "llama-3.2-vision-11b",
]

__all__ = [
    "REGISTRY", "SHAPES", "ASSIGNED", "ElasticConfig", "ModelConfig",
    "MoEConfig", "ShapeConfig", "default_elastic", "get_config",
    "get_elastic", "list_archs", "shape_applicable",
]
