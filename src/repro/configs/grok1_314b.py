"""grok-1-314b [moe] — 64L d=6144 48H (GQA kv=8) V=131072, 8 experts top-2,
d_expert=32768. [hf:xai-org/grok-1]
"""
from repro.configs.base import ElasticConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab_size=131072, d_head=128,
        act="geglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768, seq_chunk=1024),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=512, d_head=16,
        act="geglu", norm="rmsnorm",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96, seq_chunk=32),
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    return ElasticConfig(
        mlp_token_capacity=0.8, mha_token_capacity=0.8,
        mha_head_topk=cfg.n_heads // 2,
        mlp_n_experts=None, mlp_expert_topk=cfg.moe.top_k,
        lora_rank=1,
    )


register("grok-1-314b", full, smoke, elastic)
