"""Config system for repro: model configs, elastic (ElastiFormer) configs, shapes.

Plain dataclasses, no external deps. Every assigned architecture provides a
``full()`` (exact published config) and a ``smoke()`` (reduced same-family
config for CPU tests) in its module, and registers itself in REGISTRY.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Native mixture-of-experts MLP config (qwen2-moe, grok-1)."""
    n_experts: int
    top_k: int
    d_expert: int                  # ffn dim per expert
    n_shared_experts: int = 0      # qwen2-moe: shared (always-on) experts
    d_shared: int = 0              # ffn dim of the shared expert path
    capacity_factor: float = 1.25  # dispatch buffer slack (training)
    seq_chunk: int = 2048          # dispatch seq chunking to bound buffers


@dataclass(frozen=True)
class ModelConfig:
    """Backbone architecture description.

    ``mixer_pattern`` is the repeating period of temporal-mixer kinds:
      'attn'   - (windowed) self attention
      'ssm'    - Mamba2 SSD block
      'rglru'  - RecurrentGemma RG-LRU block
      'xattn'  - self attention + cross attention (enc-dec decoder / VLM layer)
    Layers beyond the last full period reuse the pattern prefix.
    """
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    eos_id: Optional[int] = None    # stop token; serving default for requests
    rope_theta: float = 10_000.0
    max_seq_len: int = 131_072
    # attention locality: per-pattern-position window size; 0 = global.
    # e.g. gemma3: (1024,1024,1024,1024,1024,0) -> 5 local : 1 global.
    window_pattern: Tuple[int, ...] = (0,)
    mixer_pattern: Tuple[str, ...] = ("attn",)
    # MoE
    moe: Optional[MoEConfig] = None
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    # encoder (whisper) -- a nested encoder stack
    encoder: Optional["ModelConfig"] = None
    encoder_seq: int = 0            # frames after the (stubbed) conv frontend
    # vlm
    n_image_tokens: int = 0         # patch tokens from the (stubbed) frontend
    d_frontend: int = 0             # frontend embedding dim (projected to d_model)
    dtype: str = "bfloat16"
    # TP head padding: q-heads are zero-padded (exact — wo pad rows are 0) to
    # a multiple of this so the head dim divides the `model` mesh axis.
    # full configs use 16 (set centrally in get_config); smoke/toy keep 1.
    head_pad: int = 1

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def n_heads_p(self) -> int:
        """q-heads padded for TP divisibility (zero heads, exact)."""
        return _round_up(self.n_heads, self.head_pad)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.mixer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def layer_windows(self) -> Tuple[int, ...]:
        w = self.window_pattern
        return tuple(w[i % len(w)] for i in range(self.n_layers))

    @property
    def d_inner(self) -> int:       # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        n = V * D                                   # embed
        if not self.tie_embeddings:
            n += D * V                              # lm_head
        per_kind = {}
        qo = D * self.n_heads * self.d_head + self.n_heads * self.d_head * D
        kv = 2 * D * self.n_kv_heads * self.d_head
        per_kind["attn"] = qo + kv
        per_kind["xattn"] = 2 * (qo + kv)
        if self.ssm_state:
            di = self.d_inner
            per_kind["ssm"] = D * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) \
                + di * D + self.conv_kernel * (di + 2 * self.ssm_state)
        if self.lru_width:
            w = self.lru_width
            per_kind["rglru"] = D * 2 * w + w * D + 2 * w * w + self.conv_kernel * w
        if self.moe is not None:
            m = self.moe
            n_mlp = m.n_experts * 3 * D * m.d_expert + D * m.n_experts
            if m.n_shared_experts:
                n_mlp += 3 * D * m.d_shared
        else:
            n_mlp = (3 if self.act in ("swiglu", "geglu") else 2) * D * F
        for k in self.layer_kinds:
            n += per_kind.get(k, per_kind.get("attn", 0)) + (n_mlp if k != "ssm" else 0)
            n += 2 * D  # norms
        if self.encoder is not None:
            n += self.encoder.n_params() - self.encoder.padded_vocab * self.encoder.d_model * 2
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        D = self.d_model
        full_moe = m.n_experts * 3 * D * m.d_expert
        act_moe = m.top_k * 3 * D * m.d_expert
        return self.n_params() - len(self.layer_kinds) * (full_moe - act_moe)


@dataclass(frozen=True)
class ElasticConfig:
    """ElastiFormer routing configuration (the paper's contribution).

    capacities are fractions in (0, 1]; None disables that router.

    DEPRECATED for new code: this bakes every capacity/top-k into the trace
    (one compile per budget). Prefer the split API in ``repro.core.policy``
    — a static ``ElasticSpec`` (what routers exist) plus a runtime
    ``ElasticPolicy`` pytree passed as a traced argument, so one compiled
    model serves every compute budget. Every entry point still accepts
    ``ElasticConfig`` through a shim; ``to_spec_policy()`` converts
    explicitly (see docs/elastic_policy.md for the migration table).
    """
    mlp_token_capacity: Optional[float] = 0.8    # input subset sel. around MLP
    mha_token_capacity: Optional[float] = None   # input subset sel. around MHA/mixer
    depth_capacity: Optional[float] = None       # whole-layer (depth) token sel.
    mha_head_topk: Optional[int] = None          # param subset sel.: active heads
    mlp_n_experts: Optional[int] = None          # moefy dense MLP into M experts
    mlp_expert_topk: Optional[int] = None        # active experts (<= mlp_n_experts)
    vlm_token_capacity: Optional[float] = None   # image-token sel. before decoder
    vlm_router: str = "linear"                   # linear | mlp
    vlm_router_hidden: int = 0                   # hidden dim for mlp router (0 -> d)
    lora_rank: int = 0                           # LoRA on q/v projections
    layers: str = "all"                          # all | even  (paper §5.2)
    router_dtype: str = "float32"
    distill_loss: str = "topk_kl"                # topk_kl|fwd_kl|rev_kl|cosine
    distill_topk: int = 50
    distill_temp: float = 1.0
    lambda_load: float = 1.0
    lambda_topk: float = 1.0
    routing_impl: str = "ragged"                 # ragged | gather | dense_mask
    kernel_backend: str = "auto"                 # auto | pallas | interpret | ref
    kv_dtype: str = "fp32"                       # fp32 | bf16 | int8 (KV cache storage)
    weight_dtype: str = "fp32"                   # fp32 | bf16 | int8 (base weights)

    def applies_to_layer(self, idx: int) -> bool:
        return self.layers == "all" or idx % 2 == 0

    def to_spec_policy(self):
        """Split into the new (ElasticSpec, ElasticPolicy) pair."""
        from repro.core.policy import policy_from_config, spec_from_config
        return spec_from_config(self), policy_from_config(self)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k applies (sub-quadratic / local-attention mixers)
LONG_CONTEXT_ARCHS = {"mamba2-780m", "recurrentgemma-2b", "gemma3-27b"}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


REGISTRY: dict = {}


def register(name: str, full_fn, smoke_fn, elastic_fn=None):
    REGISTRY[name] = {"full": full_fn, "smoke": smoke_fn,
                      "elastic": elastic_fn or default_elastic}


def default_elastic(cfg: ModelConfig) -> ElasticConfig:
    """Paper-default ElastiFormer setting for a backbone."""
    has_attn = any(k in ("attn", "xattn") for k in cfg.layer_kinds)
    native_moe = cfg.moe is not None
    return ElasticConfig(
        mlp_token_capacity=0.8,
        mha_token_capacity=0.8 if has_attn else None,
        mha_head_topk=max(1, cfg.n_heads // 2) if has_attn else None,
        mlp_n_experts=None if (native_moe or cfg.family == "ssm") else 16,
        mlp_expert_topk=(cfg.moe.top_k if native_moe else 9),
        vlm_token_capacity=0.6 if cfg.family in ("vlm", "encdec") else None,
        lora_rank=1 if has_attn else 0,
    )


TP_HEAD_PAD = 16   # production `model` mesh axis size


def get_config(name: str, variant: str = "full") -> ModelConfig:
    cfg = REGISTRY[name][variant]()
    if variant == "full" and not name.startswith("toy") and cfg.head_pad == 1:
        cfg = dataclasses.replace(cfg, head_pad=TP_HEAD_PAD)
        if cfg.encoder is not None:
            cfg = dataclasses.replace(
                cfg, encoder=dataclasses.replace(cfg.encoder,
                                                 head_pad=TP_HEAD_PAD))
    return cfg


def get_elastic(name: str, cfg: Optional[ModelConfig] = None) -> ElasticConfig:
    cfg = cfg or get_config(name)
    return REGISTRY[name]["elastic"](cfg)


def list_archs():
    return sorted(REGISTRY)
