"""mamba2-780m [ssm] — 48L d=1536 attn-free, SSD state=128. [arXiv:2405.21060]

ElastiFormer head/expert routing is inapplicable to the SSD mixer (documented
in DESIGN.md §Arch-applicability); token routing around blocks applies.
"""
from repro.configs.base import ElasticConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280, d_head=0,
        norm="rmsnorm", mixer_pattern=("ssm",),
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        tie_embeddings=True, max_seq_len=1_048_576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=512, d_head=0,
        norm="rmsnorm", mixer_pattern=("ssm",),
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
        tie_embeddings=True,
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    # attn-free: only input-subset selection applies (around SSD mixer blocks).
    return ElasticConfig(
        mlp_token_capacity=None, mha_token_capacity=0.8,
        mha_head_topk=None, mlp_n_experts=None, mlp_expert_topk=None,
        lora_rank=0,
    )


register("mamba2-780m", full, smoke, elastic)
