"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (GQA kv=16) V=151936.

60 routed experts (top-4, d_expert=1408) + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ElasticConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936, d_head=128,
        act="swiglu", norm="rmsnorm", qkv_bias=True,
        moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                      n_shared_experts=4, d_shared=5632),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=48, vocab_size=512, d_head=16,
        act="swiglu", norm="rmsnorm", qkv_bias=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=48,
                      n_shared_experts=1, d_shared=96, seq_chunk=32),
    )


def elastic(cfg: ModelConfig) -> ElasticConfig:
    # native MoE: ElastiFormer's param-subset router drives the existing
    # experts (elastic top-k); no moefy needed.
    return ElasticConfig(
        mlp_token_capacity=0.8, mha_token_capacity=0.8,
        mha_head_topk=cfg.n_heads // 2,
        mlp_n_experts=None, mlp_expert_topk=cfg.moe.top_k,
        lora_rank=1,
    )


register("qwen2-moe-a2.7b", full, smoke, elastic)
