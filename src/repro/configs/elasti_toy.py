"""Paper-scale toy configs used by benchmarks/examples (trainable on CPU).

These stand in for the paper's teachers (Phi-3.5-mini / Gemma-2-2b /
ViT-MAE-L / LLaVA-1.5): we pretrain them from scratch on a synthetic corpus,
freeze them, and apply ElastiFormer exactly as the paper does.
"""
from repro.configs.base import ElasticConfig, ModelConfig, register


def toy_lm(n_layers=4, d_model=128, n_heads=4, d_ff=352, vocab=2048) -> ModelConfig:
    return ModelConfig(
        name="toy-lm", family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        d_ff=d_ff, vocab_size=vocab, d_head=d_model // n_heads,
        act="swiglu", norm="rmsnorm", tie_embeddings=True,
    )


def toy_vit(n_layers=4, d_model=128, n_heads=4, d_ff=352, n_patches=64) -> ModelConfig:
    # bidirectional encoder ("ViT-MAE encoder"): vocab unused, patch stub input
    return ModelConfig(
        name="toy-vit", family="encoder",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        d_ff=d_ff, vocab_size=0, d_head=d_model // n_heads,
        act="gelu", norm="layernorm",
        n_image_tokens=n_patches, d_frontend=d_model,
    )


def toy_vlm(n_layers=4, d_model=128, n_heads=4, d_ff=352, vocab=2048,
            n_image_tokens=32) -> ModelConfig:
    return ModelConfig(
        name="toy-vlm", family="vlm",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        d_ff=d_ff, vocab_size=vocab, d_head=d_model // n_heads,
        act="swiglu", norm="rmsnorm", tie_embeddings=True,
        mixer_pattern=("attn", "xattn"),
        n_image_tokens=n_image_tokens, d_frontend=64,
    )


register("toy-lm", toy_lm, toy_lm)
register("toy-vit", toy_vit, toy_vit)
register("toy-vlm", toy_vlm, toy_vlm)
