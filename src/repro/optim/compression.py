"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 512+ chips the `pod` axis rides the slower DCN/optical links; router
gradients are tiny but LoRA (and the optional full-finetune escape hatch)
benefit from 4x wire-size reduction. Classic EF-SGD: quantization residual
is carried in f32 client state and re-added next step, so the compression
is unbiased over time (property-tested in tests/test_property.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict      # same tree as grads, f32


def ef_init(grads_like) -> EFState:
    return EFState(jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), grads_like))


def quantize_int8(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState, axis_name: str | None = None):
    """EF-compress each leaf; if axis_name given, psum the int8 payload's
    dequantized value across that axis (what crosses the pod links is the
    int8 tensor + f32 scale). Returns (grads_out, new_ef)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        new_r = gf - deq
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        return deq.astype(g.dtype), new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(td, [o[0] for o in outs]),
            EFState(jax.tree.unflatten(td, [o[1] for o in outs])))
