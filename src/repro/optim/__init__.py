from repro.optim.optimizer import (AdamWState, adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   global_norm)
from repro.optim.compression import (EFState, compress_grads, dequantize_int8,
                                     ef_init, quantize_int8)
