"""Pure-JAX AdamW with the paper's schedule (cosine decay, 3% warmup) plus
global-norm clipping — no optax dependency.

Only the ElastiFormer router (+LoRA) tree is trainable, so optimizer state
is tiny and replicated; the frozen base model carries no optimizer memory.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def cosine_schedule(base_lr: float, total_steps: int, warmup_frac: float = 0.03,
                    final_frac: float = 0.0):
    warmup = max(1, int(total_steps * warmup_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup
        prog = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def adamw_init(params) -> AdamWState:
    z = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), z(params), z(params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, max_grad_norm=1.0):
    """Returns (new_params, new_state, metrics). `lr` is a schedule fn or
    scalar; decoupled weight decay."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_p = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm,
                                                   "lr": lr_t}
