"""Deterministic, shardable synthetic data pipeline.

The LM corpus is a Zipf-Markov process: every token has `branching`
successors with Zipfian weights derived from a hashed seed — low entropy
(learnable by a small teacher) but non-trivial. Image/VLM benches use
procedural "images": smooth random fields whose patch embeddings are
deterministic functions of (seed, index).

Determinism contract (fault tolerance): batch(step, shard) depends only on
(seed, step, shard) — after restart-from-checkpoint the pipeline resumes
bitwise-identically from the recorded step, and each data-parallel shard
draws a disjoint stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _rng(*keys: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=np.uint64(
        hash(tuple(keys)) & 0xFFFFFFFFFFFFFFFF)))


@dataclasses.dataclass
class ZipfMarkov:
    vocab: int
    branching: int = 16
    alpha: float = 1.2
    seed: int = 0

    def __post_init__(self):
        g = _rng(self.seed, 0xC0FFEE)
        self.succ = g.integers(0, self.vocab, (self.vocab, self.branching),
                               dtype=np.int32)
        w = (np.arange(1, self.branching + 1, dtype=np.float64) ** -self.alpha)
        self.probs = w / w.sum()

    def sample(self, n: int, length: int, stream_seed: int) -> np.ndarray:
        g = _rng(self.seed, stream_seed)
        out = np.empty((n, length), np.int32)
        tok = g.integers(0, self.vocab, n, dtype=np.int32)
        for t in range(length):
            out[:, t] = tok
            choice = g.choice(self.branching, size=n, p=self.probs)
            tok = self.succ[tok, choice]
        return out


@dataclasses.dataclass
class LMDataPipeline:
    """Sharded LM token pipeline with explicit, checkpointable state.

    ``chain_seed`` fixes the LANGUAGE (the Markov transition table);
    ``seed`` only offsets the sample streams. Train and eval pipelines over
    the same corpus must share chain_seed and differ only in seed."""
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    shard: int = 0
    seed: int = 0
    step: int = 0
    chain_seed: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0
        self.chain = ZipfMarkov(self.vocab, seed=self.chain_seed)
        self.local_batch = self.global_batch // self.n_shards

    def batch_at(self, step: int) -> np.ndarray:
        return self.chain.sample(
            self.local_batch, self.seq_len,
            stream_seed=(self.seed << 24)
            + (step * self.n_shards + self.shard) + 1)

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # --- checkpointable state ---
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard}

    def restore(self, state: dict):
        assert state["seed"] == self.seed and state["shard"] == self.shard, \
            "pipeline identity mismatch on restore"
        self.step = int(state["step"])


def procedural_images(n: int, n_patches: int, dim: int, seed: int,
                      n_classes: int = 10, class_id: int | None = None):
    """Procedural patch embeddings (B, n_patches, dim) + class labels.
    Each class has a fixed low-rank structure + smooth noise — stands in for
    the ImageNet subsets of paper §5.2 (router-robustness experiments).

    A class-INDEPENDENT per-patch informativeness profile scales the signal
    (noise is uniform): natural-image categories share saliency statistics,
    which is the premise of the paper's Fig. 8 router-robustness result —
    without shared structure across classes, cross-class router agreement
    has no reason to exist."""
    g = _rng(seed, 0x1A4E)
    gp = _rng(0xBEEF)  # fixed across seeds/classes
    basis = gp.normal(size=(n_classes, 4, n_patches, dim)).astype(np.float32)
    profile = (0.15 + 1.85 * gp.random(n_patches)).astype(np.float32)
    labels = (np.full(n, class_id, np.int32) if class_id is not None
              else g.integers(0, n_classes, n, dtype=np.int32))
    coef = g.normal(size=(n, 4, 1, 1)).astype(np.float32)
    emb = (basis[labels] * coef).sum(1) / 2.0
    emb *= profile[None, :, None]
    emb += 0.35 * g.normal(size=emb.shape).astype(np.float32)
    return emb, labels
