from repro.data.pipeline import LMDataPipeline, ZipfMarkov, procedural_images
