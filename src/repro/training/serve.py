"""Batched serving loop with elastic (threshold-routed) decode and
per-request compute budgets.

prefill_fn / decode_fn are jitted once per (batch, prompt_len) bucket; the
engine pads requests into fixed buckets so recompilation is bounded. The
runtime ``ElasticPolicy`` is passed as a *traced argument*, so budgets never
recompile: a batch may mix requests at different budgets (policy leaves are
(B,) arrays; all routing is row-independent) and a request at budget 1.0
runs the exact frozen teacher. Decode runs the ElastiFormer threshold path
(§B.1): per token, each router decides with theta whether the token enters
each module — variable inference compute on a static graph.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ElasticPolicy, as_spec_policy, solve_budget
from repro.models import cache_init, decode_step, prefill


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 32
    budget: Optional[float] = None   # compute budget in (0, 1]; None = engine default


class ServingEngine:
    """Greedy batched generation over a frozen base model + routers.

    ``elastic``: legacy ElasticConfig or new ElasticSpec. Budgets are
    resolved to per-request policies by the roofline budget solver and
    batched into (B,)-leaf ElasticPolicy pytrees.
    """

    def __init__(self, params, router_params, cfg, elastic=None,
                 mode: str = "infer", batch_size: int = 8,
                 max_seq: int = 256, default_budget: Optional[float] = None,
                 theta: float = 0.5):
        self.params, self.rp = params, router_params
        self.cfg, self.mode = cfg, mode
        # base policy = the elastic config's own knobs (threshold routing
        # with its head/expert top-k); explicit budgets go through the
        # roofline solver instead. default_budget=None keeps legacy behavior.
        self.spec, self._base_policy = as_spec_policy(elastic)
        if self._base_policy is not None:
            self._base_policy = self._base_policy.replace(theta=theta)
        self.B, self.max_seq = batch_size, max_seq
        self.default_budget, self.theta = default_budget, theta
        self._policy_cache: dict = {}
        self._prefill = jax.jit(partial(
            prefill, cfg=cfg, ecfg=self.spec, mode=mode,
            max_cache_len=max_seq))
        self._decode = jax.jit(partial(
            decode_step, cfg=cfg, ecfg=self.spec, mode=mode))

    # ---- budgets -> batched policy ----
    def _policy_for(self, budget: Optional[float]) -> ElasticPolicy:
        if budget is None:
            return self._base_policy
        key = round(float(budget), 6)
        if key not in self._policy_cache:
            self._policy_cache[key] = solve_budget(
                self.cfg, self.spec, key, theta=self.theta, static=True)
        return self._policy_cache[key]

    def _batch_policy(self, reqs, budget: Optional[float]):
        if self.spec is None or self.mode == "base":
            return None
        budgets = [(budget if budget is not None else
                    (r.budget if r.budget is not None else
                     self.default_budget)) for r in reqs]
        budgets += [None] * (self.B - len(reqs))         # padding rows
        return ElasticPolicy.stack([self._policy_for(b) for b in budgets])

    def compile_counts(self) -> dict:
        """Jit-cache sizes — budgets must NOT add entries (asserted by
        tests and benchmarks/fig5)."""
        return {"prefill": self._prefill._cache_size(),
                "decode": self._decode._cache_size()}

    # ---- generation ----
    def generate(self, requests: List[GenRequest],
                 extra_inputs: Optional[dict] = None,
                 budget: Optional[float] = None) -> List[np.ndarray]:
        """``budget`` overrides every request's budget for this call."""
        out: List[np.ndarray] = []
        for i in range(0, len(requests), self.B):
            out += self._generate_batch(requests[i:i + self.B], extra_inputs,
                                        budget)
        return out

    def _generate_batch(self, reqs, extra_inputs, budget):
        B = self.B
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if extra_inputs:
            batch.update(extra_inputs)
        policy = self._batch_policy(reqs, budget)
        logits, caches = self._prefill(self.params, self.rp, batch,
                                       policy=policy)
        max_new = max(r.max_new_tokens for r in reqs)
        gen = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for t in range(max_new):
            gen[:, t] = np.asarray(tok)[:, 0]
            logits, caches = self._decode(self.params, self.rp, tok, caches,
                                          jnp.int32(plen + t), policy=policy)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return [gen[j, :reqs[j].max_new_tokens] for j in range(len(reqs))]
