"""Batched serving loop with elastic (threshold-routed) decode.

prefill_fn / decode_fn are jitted once per (batch, prompt_len) bucket; the
engine pads requests into fixed buckets so recompilation is bounded. Decode
runs the ElastiFormer threshold path (§B.1): per token, each router decides
with theta=0.5 whether the token enters each module — variable inference
compute on a static graph.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cache_init, decode_step, prefill


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 32


class ServingEngine:
    """Greedy batched generation over a frozen base model + routers."""

    def __init__(self, params, router_params, cfg, ecfg=None,
                 mode: str = "infer", batch_size: int = 8,
                 max_seq: int = 256):
        self.params, self.rp = params, router_params
        self.cfg, self.ecfg, self.mode = cfg, ecfg, mode
        self.B, self.max_seq = batch_size, max_seq
        self._prefill = jax.jit(partial(
            prefill, cfg=cfg, ecfg=ecfg, mode=mode, max_cache_len=max_seq))
        self._decode = jax.jit(partial(
            decode_step, cfg=cfg, ecfg=ecfg, mode=mode))

    def generate(self, requests: List[GenRequest],
                 extra_inputs: Optional[dict] = None) -> List[np.ndarray]:
        out: List[np.ndarray] = []
        for i in range(0, len(requests), self.B):
            out += self._generate_batch(requests[i:i + self.B], extra_inputs)
        return out

    def _generate_batch(self, reqs, extra_inputs):
        B = self.B
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, caches = self._prefill(self.params, self.rp, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        gen = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for t in range(max_new):
            gen[:, t] = np.asarray(tok)[:, 0]
            logits, caches = self._decode(self.params, self.rp, tok, caches,
                                          jnp.int32(plen + t))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return [gen[j, :reqs[j].max_new_tokens] for j in range(len(reqs))]
