"""Continuous-batching serving engine over ONE compiled elastic decode.

Request lifecycle API (the serving contract the paper's input-dependent
compute implies — per-request budgets are a *scheduling* signal):

    engine = ServingEngine(params, rp, cfg, spec, mode="infer")
    h = engine.submit(GenRequest(prompt, 64, budget=0.5))
    for tok in h.tokens():         # streams; drives engine.step()
        ...
    engine.cancel(h)               # frees the slot mid-flight

``engine.step()`` runs ONE compiled decode over a fixed array of B slots:
finished/empty slots are masked, newly admitted requests are prefilled into
their slot (``models.prefill_into_slot``: single-request prefill + traced
cache-row insert), and each admission splices its solved per-request policy
row into the live (B,)-leaf ``ElasticPolicy`` (``ElasticPolicy.set_row``) —
all inside two jitted entry points whose cache sizes ``compile_counts()``
reports, so admissions at any mix of budgets never recompile. Admission is
packed by ``runtime.scheduler.SlotScheduler`` against a per-step FLOP budget
(roofline cost = the request's budget fraction), so low-budget requests
co-schedule more densely.

Decode runs the ElastiFormer threshold path (§B.1): per token, each router
decides with theta whether the token enters each module — variable inference
compute on a static graph. Sampling (per-request temperature / top-k /
PRNG seed) is traced inside the compiled step; the default temperature 0.0
is exact greedy argmax and bit-matches the legacy lockstep engine.

``generate(List[GenRequest])`` remains as a thin synchronous wrapper over
submit/step (legacy API).

SPMD serving: pass ``mesh=`` to run the same two compiled entry points
across a `(data, model)` mesh — params by the name-based TP rules, KV
caches kv-head-sharded, slots data-sharded into replicas the scheduler
packs independently — and ``engine.reshard(new_mesh)`` to scale the
replica axis up/down live (in-flight requests resume bitwise).
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import nullcontext
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import (ElasticPolicy, as_spec_policy, ragged_bucket,
                               solve_budget)
from repro.models import cache_init, decode_step, prefill_into_slot
from repro.runtime.scheduler import RequestHandle, SlotScheduler


class EntryPoint(NamedTuple):
    """One jitted serving graph + representative traced args, as handed to
    ``repro.analysis`` (retrace/sharding/host-sync/donation passes lower
    and inspect exactly what the engine runs)."""
    fn: object           # the jitted callable
    args: tuple          # traced example args (shapes/dtypes of a live call)
    static: dict         # static kwargs (e.g. the ragged bucket)
    donated: tuple = ()  # argnums whose buffers each call consumes


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 32
    budget: Optional[float] = None   # compute budget in (0, 1]; None = engine default
    eos_id: Optional[int] = None     # stop token; None = engine/config default
    temperature: float = 0.0         # 0.0 = greedy (bit-matches legacy argmax)
    top_k: int = 0                   # sample from the top-k logits; 0 = all
    seed: int = 0                    # per-request PRNG seed (traced)


# ------------------------------ sampling -------------------------------------

def sample_tokens(logits, temperature, top_k, seeds, positions):
    """Per-row sampling inside the compiled step — everything is traced, so
    one compilation serves every (temperature, top_k, seed) mix.

    logits: (B, V); temperature/top_k/seeds/positions: (B,). Rows with
    temperature <= 0 take the exact greedy argmax. Sampling is gumbel-max
    over the top-k logits (rank masking, traced k) at the given temperature;
    the PRNG key is fold_in(PRNGKey(seed), position-of-the-new-token), so a
    request's sample stream depends only on its own seed and positions —
    staggered admission reproduces a solo run exactly.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32)
    V = lg.shape[-1]

    def sample_branch():
        # value-threshold top-k (one sort; ties all kept — fine for sampling)
        k = jnp.clip(jnp.where(top_k <= 0, V, top_k), 1, V)
        srt = jnp.sort(lg, axis=-1)                      # ascending
        kth = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
        mask = lg >= kth
        keys = jax.vmap(
            lambda s, t: jax.random.fold_in(jax.random.PRNGKey(s), t)
        )(seeds.astype(jnp.uint32), positions.astype(jnp.int32))
        g = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(keys)
        z = jnp.where(mask, lg / jnp.maximum(temperature, 1e-6)[..., None] + g,
                      -jnp.inf)
        sampled = jnp.argmax(z, axis=-1).astype(jnp.int32)
        return jnp.where(temperature > 0, sampled, greedy)

    # all-greedy steps (the default) skip the sort + gumbel work at runtime
    return jax.lax.cond(jnp.any(temperature > 0), sample_branch,
                        lambda: greedy)


def _make_admit_fn(cfg, spec, mode, max_seq):
    """Admission graph: single-request prefill -> traced cache-row insert ->
    policy row splice -> sample the first token. One compile per (prompt
    length, capacity bucket); slot index, budgets, and sampling knobs are
    all traced. ``bucket`` is static and only non-None for top-k (train
    mode) prefill under ragged routing, where it caps the compile count at
    routing.RAGGED_N_BUCKETS per prompt length while the prefill FLOPs
    track the budget."""
    def admit(params, rp, batch, caches, slot, policy, live_policy,
              temperature, top_k, seed, t0, bucket=None):
        logits, caches, live_policy = prefill_into_slot(
            params, rp, batch, caches, slot, cfg, spec, mode=mode,
            max_cache_len=max_seq, policy=policy, live_policy=live_policy,
            bucket=bucket)
        tok = sample_tokens(logits, temperature[None], top_k[None],
                            seed[None], t0[None])[0]
        return tok, caches, live_policy
    return admit


def _make_step_fn(cfg, spec, mode):
    """One decode step over the whole slot array. ``t`` is the (B,) vector
    of per-slot positions; inactive rows are masked to token 0."""
    def step(params, rp, tok, caches, t, policy, active,
             temperature, top_k, seeds):
        logits, caches = decode_step(params, rp, tok[:, None], caches, t,
                                     cfg, spec, mode=mode, policy=policy)
        nxt = sample_tokens(logits, temperature, top_k, seeds, t + 1)
        return jnp.where(active, nxt, 0).astype(jnp.int32), caches
    return step


class ServingEngine:
    """Continuous-batching generation over a frozen base model + routers.

    ``elastic``: legacy ElasticConfig or new ElasticSpec. Budgets are
    resolved to per-request policies by the roofline budget solver and
    spliced into the live (B,)-leaf ElasticPolicy at admission.

    ``step_flop_budget``: per-replica, per-step FLOP budget for admission
    packing, in units of full-budget rows (None = slots-per-replica:
    limited by slots only).
    ``eos_id``: default stop token (falls back to ``cfg.eos_id``).

    ``mesh``: optional ``jax.sharding.Mesh`` with a `model` axis (TP) and
    data axes (`data`/`pod`, the replica axis). The engine then runs SPMD:
    base params follow the Megatron-style name rules in
    ``runtime/sharding.py``, routers replicate, the ring KV caches shard
    kv-heads over `model` and slots over the data axes, and the slot array
    gains a data-parallel replica axis for the scheduler (flat slot i lives
    on data shard i // slots_per_replica). The compiled admission/decode
    graphs are the same two jitted entry points — budgets, slots, and
    sampling knobs still never recompile — and their outputs are
    token-for-token identical to the single-device engine.
    ``n_replicas`` overrides the scheduler's replica count without a mesh
    (placement-policy testing); with a mesh it must match the data axes.
    """

    def __init__(self, params, router_params, cfg, elastic=None,
                 mode: str = "infer", batch_size: int = 8,
                 max_seq: int = 256, default_budget: Optional[float] = None,
                 theta: float = 0.5, eos_id: Optional[int] = None,
                 step_flop_budget: Optional[float] = None, mesh=None,
                 n_replicas: Optional[int] = None):
        self.params, self.rp = params, router_params
        self.cfg, self.mode = cfg, mode
        # base policy = the elastic config's own knobs (threshold routing
        # with its head/expert top-k); explicit budgets go through the
        # roofline solver instead. default_budget=None keeps legacy behavior.
        self.spec, self._base_policy = as_spec_policy(elastic)
        if self._base_policy is not None:
            self._base_policy = self._base_policy.replace(theta=theta)
        self.B, self.max_seq = batch_size, max_seq
        self.default_budget, self.theta = default_budget, theta
        self.eos_id = eos_id if eos_id is not None else cfg.eos_id
        self._policy_cache: dict = {}
        self._use_policy = self.spec is not None and mode != "base"

        # ---- live slot-array state ----
        B = batch_size
        self.scheduler = SlotScheduler(
            B, step_flop_budget, self._replicas_for(mesh, n_replicas))
        self._caches = cache_init(cfg, B, max_seq)
        self._live_policy = (self._base_policy.broadcast_rows(B)
                             if self._use_policy else None)
        self._tok = jnp.zeros((B,), jnp.int32)
        self._t = np.zeros((B,), np.int32)        # per-slot decode position
        self._active = np.zeros((B,), bool)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._seeds = np.zeros((B,), np.uint32)
        self._ngen = np.zeros((B,), np.int64)
        self._extras: dict = {}                   # handle.id -> extra inputs

        # shard state + build the jitted entry points (compile_counts)
        self.mesh = None
        self.remeshed_at: Optional[float] = None  # last reshard() wall time
        self._install_mesh(mesh)

    # ------------------------------ SPMD mesh --------------------------------

    def _replicas_for(self, mesh, n_replicas: Optional[int]) -> int:
        """Replica count = product of the mesh's data axes (`pod`, `data`);
        explicit ``n_replicas`` must agree with the mesh when both given."""
        from repro.runtime import sharding as SH
        r = SH.data_axis_size(mesh)
        if n_replicas is not None:
            if mesh is not None and n_replicas != r:
                raise ValueError(f"n_replicas={n_replicas} does not match "
                                 f"the mesh's data axes (= {r})")
            r = n_replicas
        if self.B % r:
            raise ValueError(f"batch_size={self.B} must be a multiple of "
                             f"the replica count {r}")
        return r

    def _install_mesh(self, mesh) -> None:
        """device_put all live state onto ``mesh`` (None = default single
        device) and rebuild the two jitted entry points against it."""
        from repro.runtime import sharding as SH
        from repro.runtime.elastic import rescale_serving_state
        prev, self.mesh = self.mesh, mesh
        if mesh is not None or prev is not None:   # mesh-less init: no move
            self.params, self.rp, self._caches = rescale_serving_state(
                self.params, self.rp, self._caches, self.cfg, mesh)
            rep = ((lambda t: jax.tree.map(
                        lambda x: jax.device_put(x, SH.replicated(mesh)), t))
                   if mesh is not None else
                   (lambda t: jax.tree.map(
                        lambda x: jax.device_put(x, jax.devices()[0]), t)))
            self._tok = rep(self._tok)
            if self._live_policy is not None:
                self._live_policy = rep(self._live_policy)
        # fresh jit wrappers: compile_counts tracks the CURRENT mesh only.
        # Under a mesh the slot-state OUTPUTS (caches, next token, live
        # policy) are pinned to the same shardings the next call's inputs
        # carry — without this the compiler picks its own output layout and
        # the second admit/decode call recompiles against it, breaking the
        # {prefill: 1, decode: 1} contract.
        # Donation: each call consumes the slot-state buffers it replaces —
        # admit donates (caches, live_policy), decode donates (tok, caches)
        # — so XLA aliases the ring caches in place instead of copying the
        # whole slot array every step (the analysis `donation` pass gates
        # on these aliases). The per-request policy ROW (admit arg 5) is
        # NOT donated: solved rows are cached in `_policy_cache` and reused
        # across admissions.
        admit_raw = _make_admit_fn(self.cfg, self.spec, self.mode,
                                   self.max_seq)
        step_raw = _make_step_fn(self.cfg, self.spec, self.mode)
        if mesh is None:
            self._admit_fn = jax.jit(admit_raw, static_argnames=("bucket",),
                                     donate_argnums=(3, 6))
            self._step_fn = jax.jit(step_raw, donate_argnums=(2, 3))
        else:
            rsh = SH.replicated(mesh)
            cache_sh = SH.cache_shardings(self._caches, self.cfg, mesh)
            pol_sh = (jax.tree.map(lambda _: rsh, self._live_policy)
                      if self._live_policy is not None else None)
            self._admit_fn = jax.jit(admit_raw, static_argnames=("bucket",),
                                     donate_argnums=(3, 6),
                                     out_shardings=(rsh, cache_sh, pol_sh))
            self._step_fn = jax.jit(step_raw, donate_argnums=(2, 3),
                                    out_shardings=(rsh, cache_sh))

    def _mesh_ctx(self):
        """Trace/execute under the mesh so `active_mesh()`-gated sharding
        constraints inside the model apply."""
        return self.mesh if self.mesh is not None else nullcontext()

    def reshard(self, mesh) -> None:
        """LIVE re-mesh: move the engine — base params, routers, the slot
        caches holding every in-flight request, live policy rows — onto a
        new mesh shape (None = back to one device) without a restart.
        In-flight requests resume with identical (bitwise, greedy) tokens:
        the compiled math is the same, only its partitioning changes.
        The queue and slot assignments survive; the scheduler re-derives
        its replica axis from the new data axes (see
        ``SlotScheduler.set_replicas``). The two entry points recompile
        once against the new shardings (``compile_counts`` restarts)."""
        jax.block_until_ready(self._caches)       # drain the in-flight step
        self.scheduler.set_replicas(self._replicas_for(mesh, None))
        self._install_mesh(mesh)
        self.remeshed_at = time.perf_counter()    # stats-window boundary

    # ---- budgets -> per-request policy rows ----
    def _policy_for(self, budget: Optional[float]) -> Optional[ElasticPolicy]:
        if not self._use_policy:
            return None
        if budget is None:
            pol = self._base_policy
        else:
            key = round(float(budget), 6)
            if key not in self._policy_cache:
                self._policy_cache[key] = solve_budget(
                    self.cfg, self.spec, key, theta=self.theta, static=True)
            pol = self._policy_cache[key]
        # f32 leaves: stable jit avals (no weak-type retraces)
        return jax.tree.map(lambda v: jnp.asarray(v, jnp.float32), pol)

    def compile_counts(self) -> dict:
        """Jit-cache sizes — admissions at any mix of budgets, slots,
        temperatures, or seeds must NOT add entries (asserted by tests and
        benchmarks); only a new prompt length compiles (and, for top-k
        train-mode prefill under ragged routing, a new capacity bucket —
        at most routing.RAGGED_N_BUCKETS per length)."""
        return {"prefill": self._admit_fn._cache_size(),
                "decode": self._step_fn._cache_size()}

    def entry_points(self, plen: int = 8,
                     budget: Optional[float] = 0.5) -> dict:
        """The two jitted serving graphs with example args shaped exactly
        like a live admission/decode call — the contract surface
        ``repro.analysis`` lints (a pass that lowers these sees the same
        jaxpr/HLO a production call compiles). Args are built by the same
        code paths ``_admit_one``/``step`` use, so the lint can never
        drift from the real call signature."""
        prompt = np.arange(1, plen + 1, dtype=np.int32) \
            % max(2, self.cfg.vocab_size)
        batch = {"tokens": jnp.asarray(prompt[None])}
        pol_row = self._policy_for(budget if self._use_policy else None)
        bucket = None
        if (self._use_policy and self.mode == "train"
                and self.spec.routing_impl == "ragged"):
            bucket = ragged_bucket(pol_row, plen)
        admit = EntryPoint(
            self._admit_fn,
            (self.params, self.rp, batch, self._caches, jnp.int32(0),
             pol_row, self._live_policy, jnp.float32(0.0), jnp.int32(0),
             jnp.uint32(0), jnp.int32(plen)),
            {"bucket": bucket}, donated=(3, 6))
        step = EntryPoint(
            self._step_fn,
            (self.params, self.rp, self._tok, self._caches,
             jnp.asarray(self._t), self._live_policy,
             jnp.asarray(self._active), jnp.asarray(self._temp),
             jnp.asarray(self._topk), jnp.asarray(self._seeds)),
            {}, donated=(2, 3))
        return {"admit": admit, "decode": step}

    # ------------------------- request lifecycle -----------------------------

    def submit(self, request: GenRequest,
               extra_inputs: Optional[dict] = None) -> RequestHandle:
        """Queue a request; returns its lifecycle handle. ``extra_inputs``:
        per-request model inputs with a leading dim of 1 (e.g. one image's
        ``image_embeds`` row for a VLM)."""
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq={self.max_seq}")
        b = request.budget
        if b is not None and not 0.0 < b <= 1.0:
            raise ValueError(f"budget must be in (0, 1], got {b}")
        handle = RequestHandle(request, engine=self)
        if extra_inputs:
            self._extras[handle.id] = {
                k: jnp.asarray(v) for k, v in extra_inputs.items()}
        cost = b if b is not None else (self.default_budget or 1.0)
        self.scheduler.enqueue(handle, cost=min(1.0, float(cost)))
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a queued or running request; frees its slot immediately.
        Returns False if the request had already finished."""
        if handle.done:
            return False
        if handle.status == "running" and handle.slot is not None:
            self.scheduler.free(handle.slot)
            self._active[handle.slot] = False
        else:
            self.scheduler.drop_queued(handle)
        self._extras.pop(handle.id, None)
        handle.finish("cancelled")
        return True

    @property
    def has_work(self) -> bool:
        return self.scheduler.active > 0 or self.scheduler.pending > 0

    @property
    def occupancy(self) -> float:
        return self.scheduler.occupancy

    @property
    def replica_occupancy(self) -> List[float]:
        """Per-replica mean active-slot fraction (trivially [occupancy]
        when running unsharded)."""
        return self.scheduler.replica_occupancy

    # ------------------------------ stepping ---------------------------------

    def _admit_one(self, slot: int, handle: RequestHandle):
        req = handle.request
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        plen = prompt.size
        batch = {"tokens": jnp.asarray(prompt[None])}
        batch.update(self._extras.pop(handle.id, {}))
        pol_row = self._policy_for(req.budget if req.budget is not None
                                   else self.default_budget)
        # ragged capacity bucket: static, resolved per admission from the
        # (host-concrete) policy row. Only top-k routing (train mode) uses
        # it — threshold (infer) prefill stays dense, so infer engines keep
        # exactly one prefill compile per prompt length. Full-budget rows
        # resolve the IDENTITY sentinel bucket: their prefill
        # compiles the no-routing teacher graph instead of paying the
        # rank-masking sorts.
        bucket = None
        if (self._use_policy and self.mode == "train"
                and self.spec.routing_impl == "ragged"):
            bucket = ragged_bucket(pol_row, plen)
        seed = int(req.seed) & 0xFFFFFFFF        # any python int -> uint32
        with self._mesh_ctx():
            tok0, self._caches, self._live_policy = self._admit_fn(
                self.params, self.rp, batch, self._caches, jnp.int32(slot),
                pol_row, self._live_policy,
                jnp.float32(req.temperature), jnp.int32(req.top_k),
                jnp.uint32(seed), jnp.int32(plen), bucket=bucket)
        self._tok = self._tok.at[slot].set(tok0)
        self._t[slot] = plen
        self._active[slot] = True
        self._temp[slot] = req.temperature
        self._topk[slot] = req.top_k
        self._seeds[slot] = seed
        self._ngen[slot] = 0
        self._append(slot, handle, int(tok0))

    def _append(self, slot: int, handle: RequestHandle, tok: int):
        handle.append(tok)
        self._ngen[slot] += 1
        eos = (handle.request.eos_id if handle.request.eos_id is not None
               else self.eos_id)
        if self._ngen[slot] >= handle.request.max_new_tokens:
            self._finish(slot, handle, "length")
        elif eos is not None and tok == int(eos):
            self._finish(slot, handle, "eos")

    def _finish(self, slot: int, handle: RequestHandle, reason: str):
        handle.finish(reason)
        self.scheduler.free(slot)
        self._active[slot] = False

    def step(self) -> int:
        """Admit queued requests into free slots, then run ONE compiled
        decode over the slot array. Returns the number of progress events
        (admissions + slots that advanced) — admissions count, so a
        request finishing on its very first (prefill) token is not
        mistaken for an idle engine. 0 = the engine is truly idle."""
        admitted = self.scheduler.admit()
        for slot, handle in admitted:
            self._admit_one(slot, handle)
        if not self._active.any():
            return len(admitted)
        live = [(s, h) for s, h in enumerate(self.scheduler.slots)
                if h is not None and self._active[s]]
        with self._mesh_ctx():
            self._tok, self._caches = self._step_fn(
                self.params, self.rp, self._tok, self._caches,
                jnp.asarray(self._t), self._live_policy,
                jnp.asarray(self._active), jnp.asarray(self._temp),
                jnp.asarray(self._topk), jnp.asarray(self._seeds))
        toks = np.asarray(self._tok)
        self.scheduler.tick()
        for slot, handle in live:
            self._t[slot] += 1
            self._append(slot, handle, int(toks[slot]))
        return len(admitted) + len(live)

    # --------------------------- legacy wrapper ------------------------------

    def generate(self, requests: List[GenRequest],
                 extra_inputs: Optional[dict] = None,
                 budget: Optional[float] = None) -> List[np.ndarray]:
        """Synchronous batch API (legacy): submit everything, step until
        done. ``budget`` overrides every request's budget for this call.
        ``extra_inputs`` leaves carry a leading dim indexed per request."""
        handles = []
        for i, r in enumerate(requests):
            if budget is not None:
                r = dataclasses.replace(r, budget=budget)
            extra = None
            if extra_inputs:
                extra = {k: np.asarray(v)[i:i + 1]
                         for k, v in extra_inputs.items()}
            handles.append(self.submit(r, extra_inputs=extra))
        while not all(h.done for h in handles):
            if self.step() == 0 and not all(h.done for h in handles):
                raise RuntimeError("serving engine stalled")  # pragma: no cover
        return [np.asarray(h.output, np.int32) for h in handles]
