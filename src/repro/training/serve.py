"""Continuous-batching serving engine over ONE compiled elastic decode.

Request lifecycle API (the serving contract the paper's input-dependent
compute implies — per-request budgets are a *scheduling* signal):

    engine = ServingEngine(params, rp, cfg, spec, mode="infer")
    h = engine.submit(GenRequest(prompt, 64, budget=0.5))
    for tok in h.tokens():         # streams; drives engine.step()
        ...
    engine.cancel(h)               # frees the slot mid-flight

``engine.step()`` runs ONE compiled decode over a fixed array of B slots:
finished/empty slots are masked, newly admitted requests are prefilled into
their slot (``models.prefill_into_slot``: single-request prefill + traced
cache-row insert), and each admission splices its solved per-request policy
row into the live (B,)-leaf ``ElasticPolicy`` (``ElasticPolicy.set_row``) —
all inside two jitted entry points whose cache sizes ``compile_counts()``
reports, so admissions at any mix of budgets never recompile. Admission is
packed by ``runtime.scheduler.SlotScheduler`` against a per-step FLOP budget
(roofline cost = the request's budget fraction), so low-budget requests
co-schedule more densely.

Decode runs the ElastiFormer threshold path (§B.1): per token, each router
decides with theta whether the token enters each module — variable inference
compute on a static graph. Sampling (per-request temperature / top-k /
PRNG seed) is traced inside the compiled step; the default temperature 0.0
is exact greedy argmax and bit-matches the legacy lockstep engine.

``generate(List[GenRequest])`` remains as a thin synchronous wrapper over
submit/step (legacy API).

SPMD serving: pass ``mesh=`` to run the same two compiled entry points
across a `(data, model)` mesh — params by the name-based TP rules, KV
caches kv-head-sharded, slots data-sharded into replicas the scheduler
packs independently — and ``engine.reshard(new_mesh)`` to scale the
replica axis up/down live (in-flight requests resume bitwise).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from contextlib import nullcontext
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import (ElasticPolicy, ElasticSpec, as_spec_policy,
                               ragged_bucket, solve_budget)
from repro.models import (cache_init, decode_step, paged_cache_init,
                          prefill_chunk_step, prefill_into_slot)
from repro.models.quant import (check_kv_dtype, check_weight_dtype,
                                quantize_params_tree)
from repro.runtime.pagedkv import (PagePool, copy_page_in_tree, n_pages_for,
                                   prefix_keys)
from repro.runtime.scheduler import RequestHandle, SlotScheduler


class EntryPoint(NamedTuple):
    """One jitted serving graph + representative traced args, as handed to
    ``repro.analysis`` (retrace/sharding/host-sync/donation passes lower
    and inspect exactly what the engine runs)."""
    fn: object           # the jitted callable
    args: tuple          # traced example args (shapes/dtypes of a live call)
    static: dict         # static kwargs (e.g. the ragged bucket)
    donated: tuple = ()  # argnums whose buffers each call consumes


@dataclasses.dataclass
class GenRequest:
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 32
    budget: Optional[float] = None   # compute budget in (0, 1]; None = engine default
    eos_id: Optional[int] = None     # stop token; None = engine/config default
    temperature: float = 0.0         # 0.0 = greedy (bit-matches legacy argmax)
    top_k: int = 0                   # sample from the top-k logits; 0 = all
    seed: int = 0                    # per-request PRNG seed (traced)
    slo_class: str = "default"       # tenant SLO class (see runtime/controller.py)
    deadline_ms: Optional[float] = None  # queue deadline; None = class default


# ------------------------------ sampling -------------------------------------

def sample_tokens(logits, temperature, top_k, seeds, positions):
    """Per-row sampling inside the compiled step — everything is traced, so
    one compilation serves every (temperature, top_k, seed) mix.

    logits: (B, V); temperature/top_k/seeds/positions: (B,). Rows with
    temperature <= 0 take the exact greedy argmax. Sampling is gumbel-max
    over the top-k logits (rank masking, traced k) at the given temperature;
    the PRNG key is fold_in(PRNGKey(seed), position-of-the-new-token), so a
    request's sample stream depends only on its own seed and positions —
    staggered admission reproduces a solo run exactly.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32)
    V = lg.shape[-1]

    def sample_branch():
        # value-threshold top-k (one sort; ties all kept — fine for sampling)
        k = jnp.clip(jnp.where(top_k <= 0, V, top_k), 1, V)
        srt = jnp.sort(lg, axis=-1)                      # ascending
        kth = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
        mask = lg >= kth
        keys = jax.vmap(
            lambda s, t: jax.random.fold_in(jax.random.PRNGKey(s), t)
        )(seeds.astype(jnp.uint32), positions.astype(jnp.int32))
        g = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(keys)
        z = jnp.where(mask, lg / jnp.maximum(temperature, 1e-6)[..., None] + g,
                      -jnp.inf)
        sampled = jnp.argmax(z, axis=-1).astype(jnp.int32)
        return jnp.where(temperature > 0, sampled, greedy)

    # all-greedy steps (the default) skip the sort + gumbel work at runtime
    return jax.lax.cond(jnp.any(temperature > 0), sample_branch,
                        lambda: greedy)


def _make_admit_fn(cfg, spec, mode, max_seq):
    """Admission graph: single-request prefill -> traced cache-row insert ->
    policy row splice -> sample the first token. One compile per (prompt
    length, capacity bucket); slot index, budgets, and sampling knobs are
    all traced. ``bucket`` is static and only non-None for top-k (train
    mode) prefill under ragged routing, where it caps the compile count at
    routing.RAGGED_N_BUCKETS per prompt length while the prefill FLOPs
    track the budget."""
    def admit(params, rp, batch, caches, slot, policy, live_policy,
              temperature, top_k, seed, t0, bucket=None):
        logits, caches, live_policy = prefill_into_slot(
            params, rp, batch, caches, slot, cfg, spec, mode=mode,
            max_cache_len=max_seq, policy=policy, live_policy=live_policy,
            bucket=bucket)
        tok = sample_tokens(logits, temperature[None], top_k[None],
                            seed[None], t0[None])[0]
        return tok, caches, live_policy
    return admit


def _make_step_fn(cfg, spec, mode):
    """One decode step over the whole slot array. ``t`` is the (B,) vector
    of per-slot positions; inactive rows are masked to token 0."""
    def step(params, rp, tok, caches, t, policy, active,
             temperature, top_k, seeds):
        logits, caches = decode_step(params, rp, tok[:, None], caches, t,
                                     cfg, spec, mode=mode, policy=policy)
        nxt = sample_tokens(logits, temperature, top_k, seeds, t + 1)
        return jnp.where(active, nxt, 0).astype(jnp.int32), caches
    return step


def _make_chunk_admit_fn(cfg, spec, mode):
    """Paged admission graph: ONE chunk of a chunked prefill (see
    ``models.prefill_chunk_step``) + policy-row splice + sampling. Every
    operand that varies per admission — the chunk tokens, page-table row,
    write page, chunk offset, prompt length, slot, budgets, sampling knobs
    — is traced, so this compiles EXACTLY ONCE for any mix of prompt
    lengths (the per-length prefill buckets of the ring engine collapse to
    one graph). The sampled token is only meaningful on the final chunk."""
    def admit(params, rp, tokens, caches, table_row, write_page, pos0, plen,
              slot, policy, live_policy, temperature, top_k, seed):
        logits, caches = prefill_chunk_step(
            params, rp, tokens, caches, write_page, table_row, pos0, plen,
            cfg, spec, mode=mode, policy=policy)
        if live_policy is not None and policy is not None:
            live_policy = live_policy.set_row(slot, policy)
        tok = sample_tokens(logits, temperature[None], top_k[None],
                            seed[None], jnp.asarray(plen)[None])[0]
        return tok, caches, live_policy
    return admit


def _make_paged_step_fn(cfg, spec, mode):
    """Paged decode step: same as ``_make_step_fn`` plus the (B, P) page
    table and (B,) per-slot trash-page ids (host-authoritative, passed as
    traced operands — table updates never recompile)."""
    def step(params, rp, tok, caches, t, policy, active,
             temperature, top_k, seeds, table, trash):
        logits, caches = decode_step(params, rp, tok[:, None], caches, t,
                                     cfg, spec, mode=mode, policy=policy,
                                     table=table, trash=trash)
        nxt = sample_tokens(logits, temperature, top_k, seeds, t + 1)
        return jnp.where(active, nxt, 0).astype(jnp.int32), caches
    return step


class ServingEngine:
    """Continuous-batching generation over a frozen base model + routers.

    ``elastic``: legacy ElasticConfig or new ElasticSpec. Budgets are
    resolved to per-request policies by the roofline budget solver and
    spliced into the live (B,)-leaf ElasticPolicy at admission.

    ``step_flop_budget``: per-replica, per-step FLOP budget for admission
    packing, in units of full-budget rows (None = slots-per-replica:
    limited by slots only).
    ``eos_id``: default stop token (falls back to ``cfg.eos_id``).

    ``mesh``: optional ``jax.sharding.Mesh`` with a `model` axis (TP) and
    data axes (`data`/`pod`, the replica axis). The engine then runs SPMD:
    base params follow the Megatron-style name rules in
    ``runtime/sharding.py``, routers replicate, the ring KV caches shard
    kv-heads over `model` and slots over the data axes, and the slot array
    gains a data-parallel replica axis for the scheduler (flat slot i lives
    on data shard i // slots_per_replica). The compiled admission/decode
    graphs are the same two jitted entry points — budgets, slots, and
    sampling knobs still never recompile — and their outputs are
    token-for-token identical to the single-device engine.
    ``n_replicas`` overrides the scheduler's replica count without a mesh
    (placement-policy testing); with a mesh it must match the data axes.
    """

    def __init__(self, params, router_params, cfg, elastic=None,
                 mode: str = "infer", batch_size: int = 8,
                 max_seq: int = 256, default_budget: Optional[float] = None,
                 theta: float = 0.5, eos_id: Optional[int] = None,
                 step_flop_budget: Optional[float] = None, mesh=None,
                 n_replicas: Optional[int] = None, kv_layout: str = "ring",
                 page_size: int = 16, n_pages: Optional[int] = None,
                 kv_dtype: str = "fp32", weight_dtype: str = "fp32",
                 controller=None, clock=None):
        # SLO controller (runtime/controller.py) + injectable clock: every
        # engine timestamp (handle t_submit/t_tokens, controller evals)
        # reads this one clock, so tests drive a fully deterministic time.
        self.controller = controller
        self._clock = clock if clock is not None else time.perf_counter
        self.kv_dtype = check_kv_dtype(kv_dtype)
        self.weight_dtype = check_weight_dtype(weight_dtype)
        # quantize base weights ONCE, before any sharding/jit sees the tree
        # (scale leaves must exist when param specs are derived)
        params = quantize_params_tree(params, self.weight_dtype)
        self.params, self.rp = params, router_params
        self.cfg, self.mode = cfg, mode
        # base policy = the elastic config's own knobs (threshold routing
        # with its head/expert top-k); explicit budgets go through the
        # roofline solver instead. default_budget=None keeps legacy behavior.
        self.spec, self._base_policy = as_spec_policy(elastic)
        if self._base_policy is not None:
            self._base_policy = self._base_policy.replace(theta=theta)
        if (self.kv_dtype, self.weight_dtype) != ("fp32", "fp32"):
            # the spec is what the traced graphs consult for cache writes,
            # so it must carry the dtypes even when no elastic config was
            # given (plain dense serving of a quantized model)
            base_spec = self.spec if self.spec is not None else ElasticSpec()
            self.spec = dataclasses.replace(
                base_spec, kv_dtype=self.kv_dtype,
                weight_dtype=self.weight_dtype)
            if self._base_policy is None:   # keep spec => policy invariant
                self._base_policy = ElasticPolicy.uniform(1.0, static=True)
        self.B, self.max_seq = batch_size, max_seq
        self.default_budget, self.theta = default_budget, theta
        self.eos_id = eos_id if eos_id is not None else cfg.eos_id
        self._policy_cache: dict = {}
        self._use_policy = self.spec is not None and mode != "base"

        # ---- live slot-array state ----
        B = batch_size
        self.scheduler = SlotScheduler(
            B, step_flop_budget, self._replicas_for(mesh, n_replicas))
        if kv_layout not in ("ring", "paged"):
            raise ValueError(f"kv_layout must be 'ring' or 'paged', "
                             f"got {kv_layout!r}")
        self.kv_layout, self.page_size = kv_layout, int(page_size)
        self.pool: Optional[PagePool] = None
        if kv_layout == "paged":
            self._validate_paged(mode)
            R_ = self.scheduler.n_replicas
            self.pages_per_slot = n_pages_for(max_seq, self.page_size)
            if n_pages is None:
                # ring-equivalent HBM: usable pages = B slots * full-length
                # rows, plus one trash page per replica for masked writes
                n_pages = B * self.pages_per_slot + R_
            self.pool = PagePool(n_pages, self.page_size, n_replicas=R_)
            self._caches = paged_cache_init(cfg, n_pages, self.page_size,
                                            kv_dtype=self.kv_dtype)
            # host-authoritative page table, mirrored into every compiled
            # call as a traced operand (same precedent as self._t)
            self._table = np.full((B, self.pages_per_slot), -1, np.int32)
            self._trash = np.array(
                [self.pool.trash_page(self.scheduler.replica_of(s))
                 for s in range(B)], np.int32)
            self._admit_counter = itertools.count()
            self._admit_seq = np.full((B,), -1, np.int64)
        else:
            self._caches = cache_init(cfg, B, max_seq,
                                      kv_dtype=self.kv_dtype)
        self._live_policy = (self._base_policy.broadcast_rows(B)
                             if self._use_policy else None)
        self._tok = jnp.zeros((B,), jnp.int32)
        self._t = np.zeros((B,), np.int32)        # per-slot decode position
        self._active = np.zeros((B,), bool)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._seeds = np.zeros((B,), np.uint32)
        self._ngen = np.zeros((B,), np.int64)
        self._extras: dict = {}                   # handle.id -> extra inputs
        # per-slot budget bookkeeping for in-flight degradation: the budget
        # the slot was ADMITTED at (None = engine default / base policy),
        # the budget currently APPLIED to its live policy row, and the
        # controller depth cap applied to it (None = undegraded)
        self._slot_budget_key: list = [None] * B
        self._slot_applied_key: list = [None] * B
        self._slot_applied_depth: list = [None] * B
        self.n_rejected = 0                       # shed under overload
        self.n_expired = 0                        # queue deadline passed

        # shard state + build the jitted entry points (compile_counts)
        self.mesh = None
        self.remeshed_at: Optional[float] = None  # last reshard() wall time
        self._install_mesh(mesh)

    # ---------------------------- paged KV mode ------------------------------

    def _validate_paged(self, mode: str) -> None:
        """The paged subsystem serves the elastic decoder hot path: global
        self-attention layers with dense MLPs. Windows would need
        page-eviction semantics, recurrent mixers have no paged state, and
        MoE/moefied expert dispatch sizes its capacity buffers by the
        sequence chunking — the one sub-block whose chunked and one-shot
        prefills can drop different tokens, which would break the paged ==
        ring token-parity contract."""
        if mode not in ("infer", "base"):
            raise ValueError(f"kv_layout='paged' serves infer/base modes, "
                             f"got mode={mode!r}")
        bad = [k for k in self.cfg.layer_kinds if k != "attn"]
        if bad:
            raise ValueError(f"kv_layout='paged' requires all-'attn' layer "
                             f"kinds, got {sorted(set(bad))}")
        if any(w and w > 0 for w in self.cfg.layer_windows):
            raise ValueError("kv_layout='paged' does not support sliding-"
                             "window layers")
        if self.cfg.encoder is not None or self.cfg.family in ("vlm",
                                                               "encoder"):
            raise ValueError("kv_layout='paged' serves decoder-only LMs")
        if self.cfg.moe is not None or (self.spec is not None
                                        and self.spec.mlp_n_experts):
            raise ValueError("kv_layout='paged' requires a dense MLP (no "
                             "MoE / moefied experts): expert-capacity "
                             "buffers depend on the prefill chunking")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")

    def _prefix_namespace(self, req: GenRequest) -> tuple:
        """Prefix-sharing hash namespace: pages hold post-gate K/V, so two
        requests may share a page only when every knob that shapes the
        written values agrees — mode, solved budget, theta, and the KV
        storage dtype (sampling knobs don't touch K/V)."""
        b = self._effective_budget(req)
        d = self._depth_cap()
        return (self.mode, None if b is None else round(float(b), 6),
                round(float(self.theta), 6), self.kv_dtype,
                None if d is None else round(float(d), 6))

    def paged_stats(self) -> dict:
        """Pool stats plus live-token page efficiency (host-side only)."""
        st = self.pool.stats()
        live_tok = int(self._t[self._active].sum())
        held = int(sum((self._table[s] >= 0).sum()
                       for s in range(self.B) if self._active[s]))
        st["live_tokens"] = live_tok
        st["pages_held_by_active"] = held
        st["pages_per_token"] = (held / live_tok) if live_tok else 0.0
        return st

    # ------------------------------ SPMD mesh --------------------------------

    def _replicas_for(self, mesh, n_replicas: Optional[int]) -> int:
        """Replica count = product of the mesh's data axes (`pod`, `data`);
        explicit ``n_replicas`` must agree with the mesh when both given."""
        from repro.runtime import sharding as SH
        r = SH.data_axis_size(mesh)
        if n_replicas is not None:
            if mesh is not None and n_replicas != r:
                raise ValueError(f"n_replicas={n_replicas} does not match "
                                 f"the mesh's data axes (= {r})")
            r = n_replicas
        if self.B % r:
            raise ValueError(f"batch_size={self.B} must be a multiple of "
                             f"the replica count {r}")
        return r

    def _install_mesh(self, mesh) -> None:
        """device_put all live state onto ``mesh`` (None = default single
        device) and rebuild the two jitted entry points against it."""
        from repro.runtime import sharding as SH
        from repro.runtime.elastic import rescale_serving_state
        prev, self.mesh = self.mesh, mesh
        if mesh is not None or prev is not None:   # mesh-less init: no move
            self.params, self.rp, self._caches = rescale_serving_state(
                self.params, self.rp, self._caches, self.cfg, mesh)
            rep = ((lambda t: jax.tree.map(
                        lambda x: jax.device_put(x, SH.replicated(mesh)), t))
                   if mesh is not None else
                   (lambda t: jax.tree.map(
                        lambda x: jax.device_put(x, jax.devices()[0]), t)))
            self._tok = rep(self._tok)
            if self._live_policy is not None:
                self._live_policy = rep(self._live_policy)
        # fresh jit wrappers: compile_counts tracks the CURRENT mesh only.
        # Under a mesh the slot-state OUTPUTS (caches, next token, live
        # policy) are pinned to the same shardings the next call's inputs
        # carry — without this the compiler picks its own output layout and
        # the second admit/decode call recompiles against it, breaking the
        # {prefill: 1, decode: 1} contract.
        # Donation: each call consumes the slot-state buffers it replaces —
        # admit donates (caches, live_policy), decode donates (tok, caches)
        # — so XLA aliases the ring caches in place instead of copying the
        # whole slot array every step (the analysis `donation` pass gates
        # on these aliases). The per-request policy ROW (admit arg 5) is
        # NOT donated: solved rows are cached in `_policy_cache` and reused
        # across admissions.
        paged = self.kv_layout == "paged"
        if paged:
            admit_raw = _make_chunk_admit_fn(self.cfg, self.spec, self.mode)
            step_raw = _make_paged_step_fn(self.cfg, self.spec, self.mode)
            admit_static, admit_donate = (), (3, 10)
            fork_raw = lambda caches, src, dst, n_keep: copy_page_in_tree(
                caches, src, dst, n_keep, page_size=self.page_size,
                cfg=self.cfg)
        else:
            admit_raw = _make_admit_fn(self.cfg, self.spec, self.mode,
                                       self.max_seq)
            step_raw = _make_step_fn(self.cfg, self.spec, self.mode)
            admit_static, admit_donate = ("bucket",), (3, 6)
        if mesh is None:
            self._admit_fn = jax.jit(admit_raw, static_argnames=admit_static,
                                     donate_argnums=admit_donate)
            self._step_fn = jax.jit(step_raw, donate_argnums=(2, 3))
            if paged:
                self._fork_fn = jax.jit(fork_raw, donate_argnums=(0,))
        else:
            rsh = SH.replicated(mesh)
            cache_sh = SH.cache_shardings(self._caches, self.cfg, mesh)
            pol_sh = (jax.tree.map(lambda _: rsh, self._live_policy)
                      if self._live_policy is not None else None)
            self._admit_fn = jax.jit(admit_raw, static_argnames=admit_static,
                                     donate_argnums=admit_donate,
                                     out_shardings=(rsh, cache_sh, pol_sh))
            self._step_fn = jax.jit(step_raw, donate_argnums=(2, 3),
                                    out_shardings=(rsh, cache_sh))
            if paged:
                self._fork_fn = jax.jit(fork_raw, donate_argnums=(0,),
                                        out_shardings=cache_sh)

    def _mesh_ctx(self):
        """Trace/execute under the mesh so `active_mesh()`-gated sharding
        constraints inside the model apply."""
        return self.mesh if self.mesh is not None else nullcontext()

    def reshard(self, mesh) -> None:
        """LIVE re-mesh: move the engine — base params, routers, the slot
        caches holding every in-flight request, live policy rows — onto a
        new mesh shape (None = back to one device) without a restart.
        In-flight requests resume with identical (bitwise, greedy) tokens:
        the compiled math is the same, only its partitioning changes.
        The queue and slot assignments survive; the scheduler re-derives
        its replica axis from the new data axes (see
        ``SlotScheduler.set_replicas``). The two entry points recompile
        once against the new shardings (``compile_counts`` restarts)."""
        if self.kv_layout == "paged":
            raise NotImplementedError(
                "live reshard of a paged engine is not supported: page ids "
                "are replica-local (the pool freelists and trash pages are "
                "derived from the data-axis size at construction)")
        jax.block_until_ready(self._caches)       # drain the in-flight step
        self.scheduler.set_replicas(self._replicas_for(mesh, None))
        self._install_mesh(mesh)
        self.remeshed_at = self._clock()          # stats-window boundary

    # ---- budgets -> per-request policy rows ----
    def _effective_budget(self, req: GenRequest) -> Optional[float]:
        """Resolve a request's serving budget: its own (or the engine
        default), capped by the controller's degraded admission budget
        (stage-1 graceful degradation). A user-requested budget BELOW the
        controller cap is honored as-is — the cap only degrades, never
        upgrades."""
        b = req.budget if req.budget is not None else self.default_budget
        if self.controller is not None:
            cap = self.controller.admission_cap()
            if cap is not None:
                b = cap if b is None else min(float(b), cap)
        return b

    def _depth_cap(self) -> Optional[float]:
        """The controller's depth-stage cap (stage-2 graceful degradation:
        whole-layer skips), honored only when the spec routes depth —
        otherwise the knob has nothing to act on and is ignored."""
        if (self.controller is None or self.spec is None
                or not self.spec.depth_routed):
            return None
        return self.controller.depth_cap()

    def _policy_for(self, budget: Optional[float],
                    depth: Optional[float] = None) -> Optional[ElasticPolicy]:
        """Solved policy row for (budget, depth-cap). ``depth`` further
        caps ``depth_capacity`` below what the roofline solver chose for
        the budget (the controller's depth degrade stage); rows are cached
        per (budget, depth) key so repeat admissions never re-solve."""
        if not self._use_policy:
            return None
        if budget is None and depth is None:
            pol = self._base_policy
        else:
            key = (None if budget is None else round(float(budget), 6),
                   None if depth is None else round(float(depth), 6))
            if key not in self._policy_cache:
                pol = (self._base_policy if budget is None else solve_budget(
                    self.cfg, self.spec, key[0], theta=self.theta,
                    static=True))
                if depth is not None:
                    cur = pol.depth_capacity
                    dc = (min(float(cur), float(depth))
                          if isinstance(cur, (int, float))
                          else jnp.minimum(jnp.asarray(cur, jnp.float32),
                                           jnp.float32(depth)))
                    pol = pol.replace(depth_capacity=dc)
                self._policy_cache[key] = pol
            pol = self._policy_cache[key]
        # f32 leaves: stable jit avals (no weak-type retraces)
        return jax.tree.map(lambda v: jnp.asarray(v, jnp.float32), pol)

    @staticmethod
    def _composed_cost(budget: Optional[float],
                       depth: Optional[float]) -> float:
        """Scheduler cost of a (budget, depth-cap) pair: the budget
        fraction times the depth fraction — depth skips whole layers, so
        the two compose multiplicatively, exactly like the roofline
        solver's active-FLOP model."""
        return min(1.0, (1.0 if budget is None else float(budget))
                   * (1.0 if depth is None else float(depth)))

    def compile_counts(self) -> dict:
        """Jit-cache sizes — admissions at any mix of budgets, slots,
        temperatures, or seeds must NOT add entries (asserted by tests and
        benchmarks); only a new prompt length compiles (and, for top-k
        train-mode prefill under ragged routing, a new capacity bucket —
        at most routing.RAGGED_N_BUCKETS per length)."""
        return {"prefill": self._admit_fn._cache_size(),
                "decode": self._step_fn._cache_size()}

    def entry_points(self, plen: int = 8,
                     budget: Optional[float] = 0.5,
                     depth: Optional[float] = None) -> dict:
        """The two jitted serving graphs with example args shaped exactly
        like a live admission/decode call — the contract surface
        ``repro.analysis`` lints (a pass that lowers these sees the same
        jaxpr/HLO a production call compiles). Args are built by the same
        code paths ``_admit_one``/``step`` use, so the lint can never
        drift from the real call signature."""
        prompt = np.arange(1, plen + 1, dtype=np.int32) \
            % max(2, self.cfg.vocab_size)
        pol_row = self._policy_for(budget if self._use_policy else None,
                                   depth=depth)
        if self.kv_layout == "paged":
            ck = np.zeros((self.page_size,), np.int32)
            ck[:min(plen, self.page_size)] = prompt[:self.page_size]
            admit = EntryPoint(
                self._admit_fn,
                (self.params, self.rp, jnp.asarray(ck[None]), self._caches,
                 jnp.asarray(self._table[0]), jnp.int32(0), jnp.int32(0),
                 jnp.int32(min(plen, self.page_size)), jnp.int32(0),
                 pol_row, self._live_policy, jnp.float32(0.0), jnp.int32(0),
                 jnp.uint32(0)),
                {}, donated=(3, 10))
            step = EntryPoint(
                self._step_fn,
                (self.params, self.rp, self._tok, self._caches,
                 jnp.asarray(self._t), self._live_policy,
                 jnp.asarray(self._active), jnp.asarray(self._temp),
                 jnp.asarray(self._topk), jnp.asarray(self._seeds),
                 jnp.asarray(self._table), jnp.asarray(self._trash)),
                {}, donated=(2, 3))
            return {"admit": admit, "decode": step}
        batch = {"tokens": jnp.asarray(prompt[None])}
        bucket = None
        if (self._use_policy and self.mode == "train"
                and self.spec.routing_impl == "ragged"):
            bucket = ragged_bucket(pol_row, plen, spec=self.spec)
        admit = EntryPoint(
            self._admit_fn,
            (self.params, self.rp, batch, self._caches, jnp.int32(0),
             pol_row, self._live_policy, jnp.float32(0.0), jnp.int32(0),
             jnp.uint32(0), jnp.int32(plen)),
            {"bucket": bucket}, donated=(3, 6))
        step = EntryPoint(
            self._step_fn,
            (self.params, self.rp, self._tok, self._caches,
             jnp.asarray(self._t), self._live_policy,
             jnp.asarray(self._active), jnp.asarray(self._temp),
             jnp.asarray(self._topk), jnp.asarray(self._seeds)),
            {}, donated=(2, 3))
        return {"admit": admit, "decode": step}

    # ------------------------- request lifecycle -----------------------------

    def submit(self, request: GenRequest,
               extra_inputs: Optional[dict] = None) -> RequestHandle:
        """Queue a request; returns its lifecycle handle. ``extra_inputs``:
        per-request model inputs with a leading dim of 1 (e.g. one image's
        ``image_embeds`` row for a VLM)."""
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + request.max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_seq={self.max_seq}")
        b = request.budget
        if b is not None and not 0.0 < b <= 1.0:
            raise ValueError(f"budget must be in (0, 1], got {b}")
        if self.kv_layout == "paged":
            need = n_pages_for(prompt.size + request.max_new_tokens,
                               self.page_size)
            if need > self.pool.usable_per_replica:
                raise ValueError(
                    f"request needs {need} pages but a replica only has "
                    f"{self.pool.usable_per_replica} usable pages")
        handle = RequestHandle(request, engine=self, clock=self._clock)
        handle.tenant = getattr(request, "slo_class", None) or "default"
        dl_ms = getattr(request, "deadline_ms", None)
        if dl_ms is None and self.controller is not None:
            dl_ms = self.controller.target_for(handle.tenant).deadline_ms
        if dl_ms is not None:
            handle.deadline = handle.t_submit + float(dl_ms) / 1e3
        if extra_inputs:
            self._extras[handle.id] = {
                k: jnp.asarray(v) for k, v in extra_inputs.items()}
        cost = b if b is not None else (self.default_budget or 1.0)
        self.scheduler.enqueue(handle, cost=min(1.0, float(cost)))
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a queued or running request; frees its slot immediately.
        Returns False if the request had already finished."""
        if handle.done:
            return False
        if handle.status == "running" and handle.slot is not None:
            if self.kv_layout == "paged":
                self._free_slot_pages(handle.slot)
            self.scheduler.free(handle.slot)
            self._active[handle.slot] = False
        else:
            self.scheduler.drop_queued(handle)
        self._extras.pop(handle.id, None)
        handle.finish("cancelled")
        return True

    @property
    def has_work(self) -> bool:
        return self.scheduler.active > 0 or self.scheduler.pending > 0

    @property
    def occupancy(self) -> float:
        return self.scheduler.occupancy

    @property
    def replica_occupancy(self) -> List[float]:
        """Per-replica mean active-slot fraction (trivially [occupancy]
        when running unsharded)."""
        return self.scheduler.replica_occupancy

    # ------------------------------ stepping ---------------------------------

    def _admit_one(self, slot: int, handle: RequestHandle):
        req = handle.request
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        plen = prompt.size
        batch = {"tokens": jnp.asarray(prompt[None])}
        batch.update(self._extras.pop(handle.id, {}))
        b_eff = self._effective_budget(req)
        d_eff = self._depth_cap()
        pol_row = self._policy_for(b_eff, depth=d_eff)
        # ragged capacity bucket: static, resolved per admission from the
        # (host-concrete) policy row. Only top-k routing (train mode) uses
        # it — threshold (infer) prefill stays dense, so infer engines keep
        # exactly one prefill compile per prompt length. Full-budget rows
        # resolve the IDENTITY sentinel bucket: their prefill
        # compiles the no-routing teacher graph instead of paying the
        # rank-masking sorts.
        bucket = None
        if (self._use_policy and self.mode == "train"
                and self.spec.routing_impl == "ragged"):
            bucket = ragged_bucket(pol_row, plen, spec=self.spec)
        seed = int(req.seed) & 0xFFFFFFFF        # any python int -> uint32
        with self._mesh_ctx():
            tok0, self._caches, self._live_policy = self._admit_fn(
                self.params, self.rp, batch, self._caches, jnp.int32(slot),
                pol_row, self._live_policy,
                jnp.float32(req.temperature), jnp.int32(req.top_k),
                jnp.uint32(seed), jnp.int32(plen), bucket=bucket)
        self._tok = self._tok.at[slot].set(tok0)
        self._t[slot] = plen
        self._active[slot] = True
        self._temp[slot] = req.temperature
        self._topk[slot] = req.top_k
        self._seeds[slot] = seed
        self._ngen[slot] = 0
        self._append(slot, handle, int(tok0))
        self._note_admitted(slot, handle, b_eff, d_eff)

    def _note_admitted(self, slot: int, handle: RequestHandle,
                       b_eff: Optional[float],
                       d_eff: Optional[float] = None) -> None:
        """Record the admitted budget (and depth cap) for in-flight
        degradation/restore, the served-budget weight for goodput
        accounting, and the TTFT sample for the controller. The slot's
        scheduler cost is re-priced to the COMPOSED budget x depth
        fraction, so a depth-degraded replica's admission headroom grows
        to match the FLOPs it actually spends."""
        self._slot_budget_key[slot] = b_eff
        self._slot_applied_key[slot] = b_eff
        self._slot_applied_depth[slot] = d_eff
        cost = self._composed_cost(b_eff, d_eff)
        handle.budget_served = cost
        if d_eff is not None:
            self.scheduler.reprice(slot, cost)
        if self.controller is not None and handle.ttft is not None:
            self.controller.record_ttft(
                handle.tenant, self.scheduler.replica_of(slot),
                handle.ttft * 1e3, t=handle.t_first)

    # ----------------------- paged admission / decode ------------------------

    def _page_check(self, handle: RequestHandle, replica: int) -> bool:
        """Joint-packing hook for ``SlotScheduler.admit``: a replica is an
        admission candidate only when its freelist covers the prompt's full
        page count (conservative: prefix sharing can only reduce it)."""
        plen = np.asarray(handle.request.prompt).size
        return self.pool.can_alloc(replica, n_pages_for(plen, self.page_size))

    def _free_slot_pages(self, slot: int) -> None:
        """Return a slot's page-table row to the pool (refcounted — shared
        prefix pages survive until their last holder frees) and clear it."""
        pages = [int(p) for p in self._table[slot] if p >= 0]
        if pages:
            self.pool.free(pages)
        self._table[slot] = -1

    def _admit_one_paged(self, slot: int, handle: RequestHandle) -> bool:
        """Paged admission: match shared prefix pages, allocate the rest,
        then stream the prompt through the ONE compiled chunk graph
        (page_size tokens per call). Fully-shared chunks are skipped —
        except the FINAL chunk, which always runs (its activations feed the
        first sampled token); when that chunk's page is shared the write is
        aimed at the replica's trash page while attention gathers the real
        shared page. Returns False when the pool cannot back the prompt
        right now (caller re-queues; never raises mid-admission)."""
        req = handle.request
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        plen, ps = prompt.size, self.page_size
        n_chunks = n_pages_for(plen, ps)
        n_full = plen // ps                  # full pages eligible to share
        r = self.scheduler.replica_of(slot)
        keys = prefix_keys(tuple(int(x) for x in prompt), ps,
                           namespace=self._prefix_namespace(req))
        row = np.full(self.pages_per_slot, -1, np.int32)
        matched = 0
        for i in range(n_full):
            pg = self.pool.lookup_prefix(keys[i], r)
            if pg is None:
                break
            self.pool.incref(pg)
            row[i] = pg
            matched += 1
        fresh = self.pool.alloc(r, n_chunks - matched) \
            if n_chunks > matched else []
        if fresh is None:                    # raced out inside this batch
            shared = [int(p) for p in row[:matched]]
            if shared:
                self.pool.free(shared)
            return False
        for j, pg in enumerate(fresh):
            row[matched + j] = pg
        self._table[slot] = row
        b_eff = self._effective_budget(req)
        d_eff = self._depth_cap()
        pol_row = self._policy_for(b_eff, depth=d_eff)
        seed = int(req.seed) & 0xFFFFFFFF
        trash = self.pool.trash_page(r)
        chunk_ids = list(range(matched, n_chunks)) or [n_chunks - 1]
        with self._mesh_ctx():
            for c in chunk_ids:
                lo = c * ps
                ck = np.zeros((ps,), np.int32)
                ck[:min(ps, plen - lo)] = prompt[lo:lo + min(ps, plen - lo)]
                wp = int(row[c]) if c >= matched else trash
                tok0, self._caches, self._live_policy = self._admit_fn(
                    self.params, self.rp, jnp.asarray(ck[None]),
                    self._caches, jnp.asarray(row), jnp.int32(wp),
                    jnp.int32(lo), jnp.int32(plen), jnp.int32(slot),
                    pol_row, self._live_policy, jnp.float32(req.temperature),
                    jnp.int32(req.top_k), jnp.uint32(seed))
        for i in range(matched, n_full):     # freshly written full pages
            self.pool.register_prefix(keys[i], int(row[i]))
        self._tok = self._tok.at[slot].set(tok0)
        self._t[slot] = plen
        self._active[slot] = True
        self._temp[slot] = req.temperature
        self._topk[slot] = req.top_k
        self._seeds[slot] = seed
        self._ngen[slot] = 0
        self._admit_seq[slot] = next(self._admit_counter)
        self._append(slot, handle, int(tok0))
        self._note_admitted(slot, handle, b_eff, d_eff)
        return True

    def _pick_victim(self, replica: int) -> Optional[int]:
        """Preemption order: the LATEST-admitted active slot of the replica
        (FIFO priority — the request that has waited longest keeps its
        pages)."""
        spr = self.scheduler.slots_per_replica
        cands = [s for s in range(replica * spr, (replica + 1) * spr)
                 if self._active[s]]
        return max(cands, key=lambda s: self._admit_seq[s]) if cands else None

    def _preempt(self, slot: int) -> None:
        """Evict a running request under page pressure: recycle its pages,
        free the slot, and re-queue it AT THE FRONT as a continuation
        (prompt := original + generated so far). Sampling is keyed by
        fold_in(seed, absolute position), so the re-admitted run continues
        token-for-token as if never interrupted."""
        handle = self.scheduler.slots[slot]
        cost = self.scheduler.costs[slot]
        self._free_slot_pages(slot)
        self._active[slot] = False
        self.scheduler.free(slot)
        req = handle.request
        prompt = np.concatenate([
            np.asarray(req.prompt, np.int32).reshape(-1),
            np.asarray(handle.output, np.int32)])
        handle.request = dataclasses.replace(
            req, prompt=prompt,
            max_new_tokens=req.max_new_tokens - len(handle.output))
        self.scheduler.requeue_front(handle, cost)

    def _ensure_decode_pages(self) -> None:
        """Host-side pre-alloc before the compiled decode step: every
        active slot whose next write position crosses into an unbacked
        page-table entry gets a fresh page — preempting the lowest-priority
        slot of the SAME replica when the freelist is dry (possibly the
        requester itself)."""
        for slot in np.nonzero(self._active)[0]:
            if not self._active[slot]:    # preempted by an earlier iteration
                continue
            pi = int(self._t[slot]) // self.page_size
            if pi >= self.pages_per_slot or self._table[slot, pi] >= 0:
                continue
            r = self.scheduler.replica_of(int(slot))
            while True:
                pg = self.pool.alloc(r, 1)
                if pg is not None:
                    self._table[slot, pi] = pg[0]
                    break
                victim = self._pick_victim(r)
                if victim is None:           # pragma: no cover - can't happen
                    raise RuntimeError("page pool exhausted with no "
                                       "preemptible slot")
                self._preempt(victim)
                if victim == slot:           # requester evicted itself
                    break

    def _append(self, slot: int, handle: RequestHandle, tok: int):
        handle.append(tok)
        self._ngen[slot] += 1
        eos = (handle.request.eos_id if handle.request.eos_id is not None
               else self.eos_id)
        if self._ngen[slot] >= handle.request.max_new_tokens:
            self._finish(slot, handle, "length")
        elif eos is not None and tok == int(eos):
            self._finish(slot, handle, "eos")

    def _finish(self, slot: int, handle: RequestHandle, reason: str):
        handle.finish(reason)
        if self.kv_layout == "paged":
            self._free_slot_pages(slot)
        self.scheduler.free(slot)
        self._active[slot] = False

    def _expire(self) -> int:
        """Drop queued requests whose deadline has passed — BEFORE they
        burn a prefill (scheduler sweep; reason ``deadline_exceeded``)."""
        expired = self.scheduler.expire_deadlines(self._clock())
        for h in expired:
            self._extras.pop(h.id, None)
        self.n_expired += len(expired)
        return len(expired)

    def _apply_inflight(self) -> None:
        """Stage-2/3 degradation: splice the controller's depth cap and
        in-flight budget into every active slot's live policy row
        (``set_row`` at a traced index — the SAME compiled graphs, zero
        recompiles, floored by the controller's floor) and re-price the
        slot's scheduler cost to the composed budget x depth fraction so
        the freed FLOP headroom admits more requests. Restores splice the
        ADMITTED row back when the controller releases."""
        c = self.controller
        if c is None or self._live_policy is None:
            return
        tgt = c.inflight_budget
        dcap = self._depth_cap()
        for s in np.nonzero(self._active)[0]:
            s = int(s)
            adm = self._slot_budget_key[s]
            if tgt < 1.0:
                want = tgt if adm is None else min(float(adm), tgt)
            else:
                want = adm
            if (want == self._slot_applied_key[s]
                    and dcap == self._slot_applied_depth[s]):
                continue
            row = self._policy_for(want, depth=dcap)
            with self._mesh_ctx():
                self._live_policy = self._live_policy.set_row(
                    jnp.int32(s), row, floor=c.floor)
            self._slot_applied_key[s] = want
            self._slot_applied_depth[s] = dcap
            cost = self._composed_cost(want, dcap)
            self.scheduler.reprice(s, cost)
            handle = self.scheduler.slots[s]
            if handle is not None:
                handle.budget_served = min(handle.budget_served, cost)

    def _control(self) -> int:
        """One controller evaluation (rate-limited inside ``update``):
        apply in-flight budget moves and shed queued requests with a
        Retry-After hint. Returns the number of shed requests (they are
        terminally resolved — progress events)."""
        c = self.controller
        if c is None:
            return 0
        dec = c.update(self._clock(), queue_depth=self.scheduler.pending,
                       capacity=self.B)
        if not dec["evaluated"]:
            return 0
        self._apply_inflight()
        if not dec["shed"]:
            return 0
        victims = self.scheduler.shed(
            dec["shed"],
            priority=lambda h: c.target_for(h.tenant).shed_order)
        for h in victims:
            h.retry_after = c.retry_after(dec["ratio"])
            self._extras.pop(h.id, None)
        self.n_rejected += len(victims)
        return len(victims)

    def step(self) -> int:
        """Admit queued requests into free slots, then run ONE compiled
        decode over the slot array. Returns the number of progress events
        (admissions + slots that advanced + expired/shed resolutions) —
        admissions count, so a request finishing on its very first
        (prefill) token is not mistaken for an idle engine. 0 = the
        engine is truly idle.

        Paged mode: admission packs jointly on free pages AND the FLOP
        budget (``_page_check``); an admission that races out of pages
        inside the batch is re-queued at the front; decode pre-allocates
        crossing-page slots, preempting by page pressure when dry.

        With an ``SLOController``: expired queue deadlines are dropped
        before admission, admissions are capped at the degraded budget
        (cost AND policy row), and the control loop evaluates at the end
        of the step — see ``runtime/controller.py``."""
        paged = self.kv_layout == "paged"
        expired = self._expire()
        cap = (self.controller.admission_cap()
               if self.controller is not None else None)
        dcap = self._depth_cap()
        if paged:
            admitted = []
            for slot, handle in self.scheduler.admit(
                    page_check=self._page_check, cost_cap=cap,
                    cost_scale=dcap):
                if self._admit_one_paged(slot, handle):
                    admitted.append((slot, handle))
                else:
                    cost = self.scheduler.costs[slot]
                    self.scheduler.free(slot)
                    self.scheduler.requeue_front(handle, cost)
        else:
            admitted = self.scheduler.admit(cost_cap=cap, cost_scale=dcap)
            for slot, handle in admitted:
                self._admit_one(slot, handle)
        if paged:
            self._ensure_decode_pages()       # may preempt: before `live`
        if not self._active.any():
            return len(admitted) + expired + self._control()
        live = [(s, h) for s, h in enumerate(self.scheduler.slots)
                if h is not None and self._active[s]]
        with self._mesh_ctx():
            if paged:
                self._tok, self._caches = self._step_fn(
                    self.params, self.rp, self._tok, self._caches,
                    jnp.asarray(self._t), self._live_policy,
                    jnp.asarray(self._active), jnp.asarray(self._temp),
                    jnp.asarray(self._topk), jnp.asarray(self._seeds),
                    jnp.asarray(self._table), jnp.asarray(self._trash))
            else:
                self._tok, self._caches = self._step_fn(
                    self.params, self.rp, self._tok, self._caches,
                    jnp.asarray(self._t), self._live_policy,
                    jnp.asarray(self._active), jnp.asarray(self._temp),
                    jnp.asarray(self._topk), jnp.asarray(self._seeds))
        toks = np.asarray(self._tok)
        self.scheduler.tick()
        for slot, handle in live:
            self._t[slot] += 1
            self._append(slot, handle, int(toks[slot]))
        if self.controller is not None:
            for slot, handle in live:
                if len(handle.t_tokens) >= 2:
                    self.controller.record_itl(
                        handle.tenant, self.scheduler.replica_of(slot),
                        (handle.t_tokens[-1] - handle.t_tokens[-2]) * 1e3,
                        t=handle.t_tokens[-1])
        return len(admitted) + len(live) + expired + self._control()

    # ------------------------------- fork ------------------------------------

    def fork(self, handle: RequestHandle,
             max_new_tokens: Optional[int] = None,
             seed: Optional[int] = None) -> RequestHandle:
        """Copy-on-write fork of a RUNNING paged request: the child claims
        a free slot on the parent's replica, shares every FULL page of the
        parent's history by refcount, and deep-copies only the partial tail
        page (one compiled ``copy_page_in_tree`` call — n_keep lanes kept).
        The child continues from the parent's exact decode state: with the
        same seed and greedy sampling its tokens bit-match an independent
        run fed prompt + parent-output-so-far. Parent and child then
        diverge freely — each appends into its OWN tail page."""
        if self.kv_layout != "paged":
            raise ValueError("fork() requires kv_layout='paged'")
        if handle.status != "running" or handle.slot is None:
            raise ValueError("fork() requires a running request")
        s = handle.slot
        r = self.scheduler.replica_of(s)
        free = self.scheduler.free_slots_in(r)
        if not free:
            raise RuntimeError(f"no free slot on replica {r} to fork into")
        req = handle.request
        remaining = (req.max_new_tokens - len(handle.output)
                     if max_new_tokens is None else int(max_new_tokens))
        if remaining <= 0:
            raise ValueError("nothing left to generate for the fork")
        dst = self.pool.alloc(r, 1)
        if dst is None:
            raise RuntimeError(f"no free page on replica {r} to fork")
        dst = dst[0]
        cs = free[0]
        t = int(self._t[s])
        n_full, rem = t // self.page_size, t % self.page_size
        row = np.full(self.pages_per_slot, -1, np.int32)
        for i in range(n_full):
            row[i] = self._table[s, i]
            self.pool.incref(int(row[i]))
        # the child's tail/append page: a copy of the parent's partial tail
        # (rem lanes kept), or a blank pre-alloc when the tail is page-
        # aligned (n_keep=0 masks every lane; src=dst is a no-op copy)
        row[n_full] = dst
        src = int(self._table[s, n_full]) if rem else dst
        with self._mesh_ctx():
            self._caches = self._fork_fn(self._caches, jnp.int32(src),
                                         jnp.int32(dst), jnp.int32(rem))
        self._table[cs] = row
        prompt = np.concatenate([np.asarray(req.prompt, np.int32).reshape(-1),
                                 np.asarray(handle.output, np.int32)])
        creq = dataclasses.replace(
            req, prompt=prompt, max_new_tokens=remaining,
            seed=req.seed if seed is None else seed)
        child = RequestHandle(creq, engine=self, clock=self._clock)
        child.tenant = handle.tenant
        child.slot, child.status = cs, "running"
        self.scheduler.slots[cs] = child
        self.scheduler.costs[cs] = self.scheduler.costs[s]
        self._tok = self._tok.at[cs].set(self._tok[s])
        self._t[cs] = t
        self._active[cs] = True
        self._temp[cs] = creq.temperature
        self._topk[cs] = creq.top_k
        self._seeds[cs] = int(creq.seed) & 0xFFFFFFFF
        self._ngen[cs] = 0
        self._admit_seq[cs] = next(self._admit_counter)
        if self._live_policy is not None:
            pol_row = self._policy_for(req.budget if req.budget is not None
                                       else self.default_budget)
            with self._mesh_ctx():
                self._live_policy = self._live_policy.set_row(
                    jnp.int32(cs), pol_row)
        return child

    # --------------------------- legacy wrapper ------------------------------

    def generate(self, requests: List[GenRequest],
                 extra_inputs: Optional[dict] = None,
                 budget: Optional[float] = None) -> List[np.ndarray]:
        """Synchronous batch API (legacy): submit everything, step until
        done. ``budget`` overrides every request's budget for this call.
        ``extra_inputs`` leaves carry a leading dim indexed per request."""
        handles = []
        for i, r in enumerate(requests):
            if budget is not None:
                r = dataclasses.replace(r, budget=budget)
            extra = None
            if extra_inputs:
                extra = {k: np.asarray(v)[i:i + 1]
                         for k, v in extra_inputs.items()}
            handles.append(self.submit(r, extra_inputs=extra))
        while not all(h.done for h in handles):
            if self.step() == 0 and not all(h.done for h in handles):
                raise RuntimeError("serving engine stalled")  # pragma: no cover
        return [np.asarray(h.output, np.int32) for h in handles]
