from repro.training.train_step import (TrainState, chunked_topk_kl,
                                       init_train_state, lm_loss,
                                       make_loss_fn, make_train_step)
from repro.training.serve import GenRequest, ServingEngine, sample_tokens
