"""Self-distillation training step (paper §4.2):

    L = L_distill + lambda_load * L_load + lambda_topk * L_topk

Teacher = frozen base model (mode='base'); student = same frozen weights +
trainable routers (+LoRA) (mode='train'). Gradients flow ONLY into the
router tree, so optimizer state is tiny.

Distributed top-50 KL (the TPU adaptation of the paper's loss): the naive
path would `top_k` over a vocab-sharded (B,S,V) logits tensor, forcing a
13 GB/device all-gather at phi3/train_4k scale. Instead:
  * the final hidden states (B,S,D) of teacher & student are produced once;
  * a lax.scan over sequence chunks computes logits chunk-by-chunk so the
    full (B,S,V) tensor never exists;
  * inside a shard_map over the `model` (vocab) axis, each shard top-50s its
    local vocab slice, all-gathers only (B,chunk,16*50) candidates + local
    logsumexp, and reduces to the exact global top-50 (the global top-k is
    a subset of the union of shard-local top-ks).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.distill import (cosine_distance, distill_loss,
                                topk_kl_from_gathered)
from repro.core.policy import as_spec_policy
from repro.models import forward
from repro.optim import (AdamWState, EFState, adamw_init, adamw_update,
                         compress_grads, ef_init)
from repro.runtime.sharding import batch_axes


class TrainState(NamedTuple):
    router_params: dict
    opt: AdamWState
    ef: Optional[EFState]


def init_train_state(router_params, use_compression: bool = False):
    return TrainState(router_params, adamw_init(router_params),
                      ef_init(router_params) if use_compression else None)


# ----------------------- distributed chunked top-k KL -----------------------

def _head_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _mask_padded(logits_local, vocab: int, v_local: int, axis: str):
    shard = jax.lax.axis_index(axis)
    gidx = shard * v_local + jnp.arange(v_local)
    return jnp.where(gidx < vocab, logits_local, -1e30)


def chunked_topk_kl(h_student, h_teacher, head, *, k: int, vocab: int,
                    mesh: Optional[Mesh], seq_chunk: int = 512,
                    direction: str = "fwd", temp: float = 1.0,
                    full: bool = False):
    """h_*: (B,S,D); head: (D,V) (vocab-sharded over `model` when mesh).

    full=False: exact global top-k KL with residual bucket (paper default).
    full=True : exact full-vocab KL (the paper's fwd_kl/rev_kl variants) —
    decomposes over vocab shards given the global logsumexp, so it needs
    only a scalar-per-token collective."""
    B, S, D = h_student.shape
    c = min(seq_chunk, S)
    while S % c:
        c -= 1
    nC = S // c

    def _kl_terms(ls, lt):
        """Per-token partial KL sums from shard-local log-probs."""
        if direction == "fwd":
            return jnp.sum(jnp.exp(ls) * (ls - lt), axis=-1)
        return jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1)

    if mesh is None or "model" not in mesh.axis_names:
        def body(_, hc):
            hs, ht = hc
            lt = (ht @ head).astype(jnp.float32) / temp
            ls = (hs @ head).astype(jnp.float32) / temp
            v = jnp.arange(head.shape[-1]) < vocab
            lt = jnp.where(v, lt, -1e30)
            ls = jnp.where(v, ls, -1e30)
            lt = jax.nn.log_softmax(lt, axis=-1)
            ls = jax.nn.log_softmax(ls, axis=-1)
            if full:
                return None, jnp.mean(_kl_terms(ls, lt))
            t_top, idx = jax.lax.top_k(lt, k)
            s_top = jnp.take_along_axis(ls, idx, axis=-1)
            return None, topk_kl_from_gathered(s_top, t_top, direction)
        hs = h_student.reshape(B, nC, c, D).transpose(1, 0, 2, 3)
        ht = h_teacher.reshape(B, nC, c, D).transpose(1, 0, 2, 3)
        _, kls = jax.lax.scan(body, None, (hs, ht))
        return jnp.mean(kls) * temp * temp

    ba = batch_axes(mesh)

    def sharded(hs_all, ht_all, head_loc):
        v_local = head_loc.shape[-1]

        def body(_, hc):
            hs, ht = hc                                   # (b, c, D) local
            lt = (ht @ head_loc).astype(jnp.float32) / temp   # (b, c, Vl)
            ls = (hs @ head_loc).astype(jnp.float32) / temp
            lt = _mask_padded(lt, vocab, v_local, "model")
            ls = _mask_padded(ls, vocab, v_local, "model")
            lse_t = jax.nn.logsumexp(lt, axis=-1)         # (b, c)
            lse_s = jax.nn.logsumexp(ls, axis=-1)
            # global logsumexp across vocab shards
            lse_t = jax.nn.logsumexp(
                jax.lax.all_gather(lse_t, "model", axis=0), axis=0)
            lse_s = jax.nn.logsumexp(
                jax.lax.all_gather(lse_s, "model", axis=0), axis=0)
            if full:
                # shard-local partial KL sums + psum over vocab shards
                kl = _kl_terms(ls - lse_s[..., None], lt - lse_t[..., None])
                return None, jnp.mean(jax.lax.psum(kl, "model"))
            kk = min(k, v_local)
            t_loc, idx = jax.lax.top_k(lt, kk)
            s_loc = jnp.take_along_axis(ls, idx, axis=-1)
            cand_t = jax.lax.all_gather(t_loc, "model", axis=2, tiled=True)
            cand_s = jax.lax.all_gather(s_loc, "model", axis=2, tiled=True)
            t_vals, pos = jax.lax.top_k(cand_t, k)        # exact global top-k
            s_vals = jnp.take_along_axis(cand_s, pos, axis=-1)
            kl = topk_kl_from_gathered(s_vals - lse_s[..., None],
                                       t_vals - lse_t[..., None], direction)
            return None, kl

        b = hs_all.shape[0]
        hs = hs_all.reshape(b, nC, c, D).transpose(1, 0, 2, 3)
        ht = ht_all.reshape(b, nC, c, D).transpose(1, 0, 2, 3)
        _, kls = jax.lax.scan(body, None, (hs, ht))
        # mean over chunks locally; mean over batch shards
        out = jnp.mean(kls) * temp * temp
        return jax.lax.pmean(out, ba) if ba else out

    f = shard_map(
        sharded, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None, None), P(None, "model")),
        out_specs=P(), check_rep=False)
    return f(h_student, h_teacher, head)


def lm_loss(logits, tokens):
    """Next-token cross entropy (evaluation metric, matches paper's LM Loss)."""
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ------------------------------- train step ---------------------------------

def make_loss_fn(cfg, ecfg, *, mesh: Optional[Mesh] = None, remat: bool = False,
                 chunked: bool = True, seq_chunk: int = 512):
    """``ecfg``: legacy ElasticConfig or new ElasticSpec. The returned
    loss_fn takes an optional ``policy`` (ElasticPolicy pytree) — pass it as
    a traced argument to anneal capacities during distillation with zero
    re-jits; omitted, the spec's default (static) policy applies — and an
    optional ``bucket`` (python int, STATIC: jit with
    static_argnames=("bucket",)): the ragged capacity-bucket size covering
    the policy's token budgets (core/policy.ragged_bucket), so the student
    forward lowers FLOPs proportional to the bucket. One compile per bucket,
    <= routing.RAGGED_N_BUCKETS (+ the identity graph that full-budget
    anneal starts resolve to — it skips routing work entirely while keeping
    the routers' BCE/load aux, so the anneal's early steps run at teacher
    speed with live router gradients) across a whole schedule."""
    use_hidden = chunked and cfg.family != "encoder" and cfg.vocab_size > 0
    spec, default_pol = as_spec_policy(ecfg)

    def loss_fn(router_params, params, batch, policy=None, bucket=None):
        pol = policy if policy is not None else default_pol
        if cfg.family == "encoder":
            t_out, _ = forward(params, None, batch, cfg, spec, mode="base")
            s_out, aux = forward(params, router_params, batch, cfg, spec,
                                 mode="train", remat=remat, policy=pol,
                                 bucket=bucket)
            dist = cosine_distance(s_out, jax.lax.stop_gradient(t_out))
        elif use_hidden:
            h_t, _ = forward(params, None, batch, cfg, spec, mode="base",
                             return_hidden=True)
            h_s, aux = forward(params, router_params, batch, cfg, spec,
                               mode="train", return_hidden=True, remat=remat,
                               policy=pol, bucket=bucket)
            direction = "rev" if "rev" in spec.distill_loss else "fwd"
            dist = chunked_topk_kl(
                h_s, jax.lax.stop_gradient(h_t), _head_matrix(params, cfg),
                k=spec.distill_topk, vocab=cfg.vocab_size, mesh=mesh,
                seq_chunk=seq_chunk, direction=direction,
                temp=spec.distill_temp,
                full=spec.distill_loss in ("fwd_kl", "rev_kl"))
        else:
            t_out, _ = forward(params, None, batch, cfg, spec, mode="base")
            s_out, aux = forward(params, router_params, batch, cfg, spec,
                                 mode="train", remat=remat, policy=pol,
                                 bucket=bucket)
            dist = distill_loss(s_out, jax.lax.stop_gradient(t_out), spec)
        loss = (dist + spec.lambda_load * aux.load
                + spec.lambda_topk * aux.topk)
        return loss, {"loss": loss, "distill": dist, "aux_load": aux.load,
                      "aux_topk": aux.topk, "sel_rate": aux.sel_rate}
    return loss_fn


def make_train_step(cfg, ecfg, *, lr, weight_decay: float = 0.0,
                    max_grad_norm: float = 1.0, mesh: Optional[Mesh] = None,
                    remat: bool = False, chunked: bool = True,
                    compress_axis: Optional[str] = None,
                    microbatch: Optional[int] = None):
    """Returns train_step(state, params, batch, policy=None, bucket=None)
    -> (state, metrics). `params` (frozen base model) is passed per-call so
    it can live donated/sharded outside the state. `policy` (ElasticPolicy)
    is likewise per-call and traced: capacity-annealing schedules re-use one
    compile. `bucket` is the STATIC ragged capacity-bucket hint (jit the
    step with static_argnames=("bucket",)): mixed-budget / annealed training
    stays at one graph per bucket while lowered FLOPs track the budget.

    microbatch=M: gradient accumulation over M sequential slices of the
    global batch (lax.scan). Activation live-set scales 1/M; the router
    gradient tree is tiny (<=0.3% of params) so accumulation is ~free —
    the §Perf HBM-fit lever for the train cells."""
    loss_fn = make_loss_fn(cfg, ecfg, mesh=mesh, remat=remat, chunked=chunked)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def grads_of(rp, params, batch, policy, bucket):
        if not microbatch or microbatch <= 1:
            (_, metrics), grads = vg(rp, params, batch, policy, bucket)
            return grads, metrics

        def slice_mb(t, i):
            m = t.shape[0] // microbatch
            return jax.lax.dynamic_slice_in_dim(t, i * m, m, axis=0)

        def body(carry, i):
            g_acc, m_acc = carry
            mb = {k: slice_mb(v, i) for k, v in batch.items()}
            # NOTE: per-request (B,) policy leaves are not sliced here —
            # use scalar/per-layer policies with gradient accumulation
            (_, metrics), g = vg(rp, params, mb, policy, bucket)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            m_acc = jax.tree.map(jnp.add, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(jnp.zeros_like, rp)
        m0 = {k: jnp.zeros((), jnp.float32)
              for k in ("loss", "distill", "aux_load", "aux_topk",
                        "sel_rate")}
        from repro.models import flags as _flags
        (g, m), _ = jax.lax.scan(body, (g0, m0), jnp.arange(microbatch),
                                 unroll=_flags.unroll())
        inv = 1.0 / microbatch
        return (jax.tree.map(lambda x: x * inv, g),
                {k: v * inv for k, v in m.items()})

    def train_step(state: TrainState, params, batch, policy=None,
                   bucket=None):
        grads, metrics = grads_of(state.router_params, params, batch, policy,
                                  bucket)
        ef = state.ef
        if ef is not None:
            grads, ef = compress_grads(grads, ef, axis_name=compress_axis)
        new_rp, opt, om = adamw_update(
            grads, state.opt, state.router_params, lr=lr,
            weight_decay=weight_decay, max_grad_norm=max_grad_norm)
        metrics.update(om)
        return TrainState(new_rp, opt, ef), metrics

    return train_step
