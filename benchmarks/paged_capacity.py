"""Paged-KV capacity: block-paged pool vs ring slots at EQUAL KV HBM.

The ring layout reserves a full ``max_seq`` KV row per slot, so a batch-B
engine holds exactly B requests no matter how short they are. The paged
layout (src/repro/runtime/pagedkv.py, docs/paged_kv.md) spends the same
HBM on a shared page pool and holds whatever fits — short prompts pack
many-to-a-row-equivalent, long prompts degrade gracefully toward ring.

Three capacity scenarios replay the same request list through both
layouts sized to the same KV token budget (ring: B*max_seq tokens;
paged: the identical pool + one trash page) and record the SUSTAINED
peak of concurrently running slots plus page efficiency at that peak.
A fourth scenario submits five distinct prompt lengths and records
compile counts: the ring engine pays one prefill compile per length,
chunked prefill keeps the paged engine at exactly {prefill: 1,
decode: 1}. A fifth ("quant", docs/quantization.md) replays a short-
prompt workload through an fp32-paged and an int8-paged engine sized to
the SAME KV byte budget: int8 pages cost ~0.28x the bytes (int8 K/V +
f32 scale leaves), so the equal-byte pool holds ~3.5x the pages and the
extra pages must become held slots.

Emits ``BENCH_paged.json`` rows {mode, scenario, plen_mean_frac,
kv_tokens, slots_at_capacity, capacity_ratio, pages_per_token,
prefill_compiles, decode_compiles, tok_s, kv_dtype, bytes_read} plus
the harness `name,us_per_call,derived` lines (us_per_call =
microseconds per generated token). ``bytes_read`` is the decode step's
per-call KV-cache HBM read cost (``hloprof.cache_read_bytes`` over the
compiled decode graph's entry params).

Hard gates (CI runs this with --smoke):
  * scenarios whose prompts average <= 50% of max_seq must show
    >= 2x slots-at-capacity over ring at equal HBM;
  * the mixed-length scenario's paged engine must report exactly
    {prefill: 1, decode: 1};
  * every paged pool must drain to zero allocated pages at the end;
  * quant: int8 decode ``bytes_read`` <= 0.55x fp32's at equal KV token
    capacity (both layouts), slots-at-capacity >= 1.8x fp32's at equal
    KV HBM, and budget-1.0 greedy tokens match the fp32 engine exactly.

Run: PYTHONPATH=src python benchmarks/paged_capacity.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import ElasticConfig, get_config
from repro.launch.hloprof import cache_read_bytes
from repro.models import model_init, router_init
from repro.training import GenRequest, ServingEngine

# paged serving requires a dense MLP (expert-capacity buffers depend on
# the prefill chunking) — same elastic config as tests/test_pagedkv.py
ELASTIC = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                        mha_head_topk=2, lora_rank=1)

MAX_SEQ, PAGE_SIZE, B_RING, B_PAGED = 64, 8, 4, 16
MAX_NEW = 4

# Prompt-length cycles chosen so decode fits the tail page's slack
# (plen mod PAGE_SIZE in 1..PAGE_SIZE-MAX_NEW): sustained concurrency is
# then set by admission packing alone, not by decode-time page growth.
SCENARIOS = [
    # (name, lengths cycle, capacity-gated)
    ("short", (9, 12, 20, 20), True),     # mean 15.25 = 24% of max_seq
    ("mid", (12, 20, 36, 36), True),      # mean 26    = 41% of max_seq
    ("long", (49, 52, 60, 60), False),    # mean 55.25 = 86% of max_seq
]
MIXED_LENS = (5, 11, 19, 27, 35)          # one prefill compile each (ring)
QUANT_CYCLE = (9, 12, 20, 20)             # short prompts: page-limited fp32


def kv_page_bytes(cfg, kv_dtype: str, page_size: int) -> int:
    """HBM bytes of ONE page of one layer's K+V (+ scale leaves for
    int8) — the unit the equal-byte quant comparison sizes pools in."""
    K, Dh = cfg.n_kv_heads, cfg.d_head
    per_tok = 2 * K * Dh * (1 if kv_dtype == "int8" else 4)
    if kv_dtype == "int8":
        per_tok += 2 * K * 4              # f32 kscale/vscale rows
    return page_size * per_tok


def decode_bytes_read(eng) -> int:
    """Per-call KV-cache HBM read bytes of the engine's compiled decode
    step (cache leaves matched among the entry params)."""
    ep = eng.entry_points()["decode"]
    hlo = ep.fn.lower(*ep.args, **ep.static).compile().as_text()
    return cache_read_bytes(hlo, eng._caches)


def make_requests(cfg, lengths, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [GenRequest(rng.integers(0, cfg.vocab_size, L, dtype=np.int32),
                       max_new, budget=(0.5, 0.75, 1.0)[i % 3], seed=i)
            for i, L in enumerate(lengths)]


def run_engine(engine, reqs):
    """Submit everything up front, step to completion; return
    (peak running slots, pages_per_token at that peak, elapsed, tokens)."""
    handles = [engine.submit(r) for r in reqs]
    peak, ppt = 0, 0.0
    t0 = time.perf_counter()
    for _ in range(600):
        if not engine.has_work:
            break
        engine.step()
        running = [h for h in handles if h.status == "running"]
        if len(running) > peak:
            peak = len(running)
            if engine.kv_layout == "paged":
                ppt = engine.paged_stats()["pages_per_token"]
            else:
                live = sum(len(np.asarray(h.request.prompt)) + len(h.output)
                           for h in running)
                ppt = (len(running) * engine.max_seq / PAGE_SIZE) \
                    / max(live, 1)
    dt = time.perf_counter() - t0
    assert all(h.done for h in handles), "workload did not complete"
    return peak, ppt, dt, sum(len(h.output) for h in handles)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, no long scenario)")
    ap.add_argument("--out", default="BENCH_paged.json")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("toy-lm", "smoke"), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ELASTIC)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ELASTIC)

    # equal KV HBM: the paged pool gets exactly the ring engine's
    # B_RING * MAX_SEQ KV token-slots, plus the one mandatory trash page
    n_pages = B_RING * (MAX_SEQ // PAGE_SIZE) + 1
    kv_tokens = {"ring": B_RING * MAX_SEQ, "paged": n_pages * PAGE_SIZE}

    def engines():
        ring = ServingEngine(params, rp, cfg, ELASTIC, mode="infer",
                             batch_size=B_RING, max_seq=MAX_SEQ)
        paged = ServingEngine(params, rp, cfg, ELASTIC, mode="infer",
                              batch_size=B_PAGED, max_seq=MAX_SEQ,
                              kv_layout="paged", page_size=PAGE_SIZE,
                              n_pages=n_pages)
        return {"ring": ring, "paged": paged}

    n_reqs = 8 if args.smoke else 16
    scenarios = [s for s in SCENARIOS
                 if not (args.smoke and s[0] == "long")]
    # decode-graph KV read bytes are shape-determined, identical across
    # scenarios — measure once per layout on throwaway engines
    br = {mode: decode_bytes_read(eng) for mode, eng in engines().items()}
    # bandwidth gate at EQUAL TOKEN CAPACITY (same cache geometry, int8
    # storage): the int8 pools + f32 scale leaves must read <= 0.55x the
    # fp32 bytes per decode call — the ~0.28x the format promises, with
    # headroom for the scale rows
    br8 = {
        "ring": decode_bytes_read(ServingEngine(
            params, rp, cfg, ELASTIC, mode="infer", batch_size=B_RING,
            max_seq=MAX_SEQ, kv_dtype="int8", weight_dtype="int8")),
        "paged": decode_bytes_read(ServingEngine(
            params, rp, cfg, ELASTIC, mode="infer", batch_size=B_PAGED,
            max_seq=MAX_SEQ, kv_layout="paged", page_size=PAGE_SIZE,
            n_pages=n_pages, kv_dtype="int8", weight_dtype="int8")),
    }
    for mode in sorted(br):
        assert br8[mode] <= 0.55 * br[mode], (
            f"{mode}: int8 decode reads {br8[mode]}B vs fp32 {br[mode]}B "
            f"at equal KV capacity — above the 0.55x bytes_read gate "
            f"(dequant leaking out of the kernels?)")
        print(f"[quant] {mode} decode KV bytes_read: int8 {br8[mode]}B = "
              f"{br8[mode] / br[mode]:.2f}x fp32 {br[mode]}B")
    rows = []
    for si, (name, cycle, gated) in enumerate(scenarios):
        lengths = [cycle[i % len(cycle)] for i in range(n_reqs)]
        frac = float(np.mean(lengths)) / MAX_SEQ
        engs = engines()
        # pay ring's per-length prefill compiles outside the timed window
        for L in sorted(set(lengths)):
            engs["ring"].generate(make_requests(cfg, [L], 2, seed=99))
        engs["paged"].generate(make_requests(cfg, [lengths[0]], 2, seed=99))
        peaks = {}
        for mode, eng in engs.items():
            reqs = make_requests(cfg, lengths, MAX_NEW, seed=17 + si)
            peak, ppt, dt, n_tok = run_engine(eng, reqs)
            peaks[mode] = peak
            ratio = (peak / peaks["ring"]) if mode == "paged" else None
            cc = eng.compile_counts()
            rows.append({"mode": mode, "scenario": name,
                         "plen_mean_frac": frac,
                         "kv_tokens": kv_tokens[mode],
                         "slots_at_capacity": peak,
                         "capacity_ratio": ratio,
                         "pages_per_token": ppt,
                         "prefill_compiles": cc["prefill"],
                         "decode_compiles": cc["decode"],
                         "tok_s": n_tok / dt,
                         "kv_dtype": "fp32", "bytes_read": br[mode]})
            emit(f"paged_cap_{name}_{mode}", dt / max(n_tok, 1) * 1e6,
                 f"{peak}slots" + (f"@{ratio:.2f}x" if ratio else ""))
            if mode == "paged":
                st = eng.pool.stats()
                assert st["allocated"] == 0, \
                    f"{name}: pool leaked {st['allocated']} pages"
        if gated:
            assert peaks["paged"] >= 2 * peaks["ring"], (
                f"{name} (mean prompt {frac:.0%} of max_seq): paged holds "
                f"{peaks['paged']} slots vs ring {peaks['ring']} at equal "
                f"HBM — below the 2x capacity gate")

    # mixed prompt lengths: ring pays one prefill compile per length,
    # chunked prefill keeps the paged engine at exactly one
    engs = engines()
    for mode, eng in engs.items():
        reqs = make_requests(cfg, MIXED_LENS, 2, seed=5)
        peak, ppt, dt, n_tok = run_engine(eng, reqs)
        cc = eng.compile_counts()
        rows.append({"mode": mode, "scenario": "mixed_lengths",
                     "plen_mean_frac": float(np.mean(MIXED_LENS)) / MAX_SEQ,
                     "kv_tokens": kv_tokens[mode],
                     "slots_at_capacity": peak, "capacity_ratio": None,
                     "pages_per_token": ppt,
                     "prefill_compiles": cc["prefill"],
                     "decode_compiles": cc["decode"],
                     "tok_s": n_tok / dt,
                     "kv_dtype": "fp32", "bytes_read": br[mode]})
        emit(f"paged_compile_{mode}", dt / max(n_tok, 1) * 1e6,
             f"prefill_compiles={cc['prefill']}")
    assert engs["ring"].compile_counts()["prefill"] == len(MIXED_LENS)
    assert engs["paged"].compile_counts() == {"prefill": 1, "decode": 1}, \
        engs["paged"].compile_counts()

    # ---- quant: fp32-paged vs int8-paged at EQUAL KV HBM --------------
    # the byte budget is 16 fp32 pages; int8 pages cost ~0.28x, so the
    # int8 engine gets ~3.5x the page count for the same bytes
    budget_bytes = 16 * kv_page_bytes(cfg, "fp32", PAGE_SIZE)
    qpeaks, qbytes, qtok = {}, {}, {}
    for kvd in ("fp32", "int8"):
        n_pg = budget_bytes // kv_page_bytes(cfg, kvd, PAGE_SIZE) + 1
        eng = ServingEngine(params, rp, cfg, ELASTIC, mode="infer",
                            batch_size=B_PAGED, max_seq=MAX_SEQ,
                            kv_layout="paged", page_size=PAGE_SIZE,
                            n_pages=int(n_pg), kv_dtype=kvd,
                            weight_dtype=kvd)
        qbytes[kvd] = decode_bytes_read(eng)
        # budget-1.0 greedy parity vs the fp32 reference engine
        par = [GenRequest(np.random.default_rng(40 + i).integers(
                   0, cfg.vocab_size, 12, dtype=np.int32), MAX_NEW,
                   budget=1.0, seed=i) for i in range(4)]
        qtok[kvd] = [np.asarray(o).tolist() for o in eng.generate(par)]
        lengths = [QUANT_CYCLE[i % len(QUANT_CYCLE)] for i in range(24)]
        reqs = make_requests(cfg, lengths, MAX_NEW, seed=23)
        peak, ppt, dt, n_tok = run_engine(eng, reqs)
        qpeaks[kvd] = peak
        ratio = (peak / qpeaks["fp32"]) if kvd == "int8" else None
        cc = eng.compile_counts()
        rows.append({"mode": "paged", "scenario": "quant",
                     "plen_mean_frac": float(np.mean(lengths)) / MAX_SEQ,
                     "kv_tokens": int(n_pg - 1) * PAGE_SIZE,
                     "slots_at_capacity": peak, "capacity_ratio": ratio,
                     "pages_per_token": ppt,
                     "prefill_compiles": cc["prefill"],
                     "decode_compiles": cc["decode"],
                     "tok_s": n_tok / dt,
                     "kv_dtype": kvd, "bytes_read": qbytes[kvd]})
        emit(f"paged_cap_quant_{kvd}", dt / max(n_tok, 1) * 1e6,
             f"{peak}slots_{qbytes[kvd]}B")
        st = eng.pool.stats()
        assert st["allocated"] == 0, \
            f"quant/{kvd}: pool leaked {st['allocated']} pages"
    assert qtok["int8"] == qtok["fp32"], \
        "int8 budget-1.0 greedy tokens diverge from the fp32 engine"
    assert qpeaks["int8"] >= 1.8 * qpeaks["fp32"], (
        f"int8-paged holds {qpeaks['int8']} slots vs fp32 "
        f"{qpeaks['fp32']} at equal KV HBM — below the 1.8x gate")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    gains = [r["capacity_ratio"] for r in rows
             if r["mode"] == "paged" and r["capacity_ratio"]]
    print(f"\nwrote {args.out}: paged capacity gains "
          f"{[f'{g:.2f}x' for g in gains]} at equal HBM; mixed-length "
          f"prefill compiles ring={len(MIXED_LENS)} paged=1")


if __name__ == "__main__":
    main()
