"""Paper Fig. 2: static pruning redundancy analysis.

Progressively remove random attention heads / skip MLP layers from the
frozen pretrained teacher (NO additional trainable parameters, §A) and
measure Delta-LM-loss and top-1 token-prediction agreement vs the base
model. Expected qualitative result (paper §3): heads degrade slower than
MLP layers; small removals are nearly free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BATCH, SEQ, emit, pretrained_teacher
from repro.data import LMDataPipeline
from repro.models import forward
from repro.training import lm_loss


def _eval(params, cfg, tokens):
    logits, _ = forward(params, None, {"tokens": tokens}, cfg, None,
                        mode="base")
    return lm_loss(logits, tokens), jnp.argmax(logits[:, :-1], -1)


def drop_heads(params, cfg, idxs):
    """Remove head h of layer l by zeroing its wo slice (its context never
    reaches the residual stream) — paper §A head removal.
    Stacked scan params: ['scan'][j]['attn']['wo'] has shape (P,H,Dh,D)."""
    p = jax.tree.map(lambda x: x, params)
    for j, stack in enumerate(p["scan"]):
        if "attn" not in stack:
            continue
        wo = stack["attn"]["wo"]
        P, H = wo.shape[0], wo.shape[1]
        mask = np.ones((P, H), np.float32)
        for (layer, h) in idxs:
            pj, rem = divmod(layer, len(p["scan"]))
            if rem == j and pj < P:
                mask[pj, h] = 0.0
        stack["attn"]["wo"] = wo * mask[:, :, None, None]
    return p


def skip_mlp_layers(params, cfg, layers):
    p = jax.tree.map(lambda x: x, params)
    for j, stack in enumerate(p["scan"]):
        if "mlp" not in stack:
            continue
        P = stack["mlp"]["wo"].shape[0]
        mask = np.ones((P,), np.float32)
        for layer in layers:
            pj, rem = divmod(layer, len(p["scan"]))
            if rem == j and pj < P:
                mask[pj] = 0.0
        stack["mlp"]["wo"] = stack["mlp"]["wo"] * mask[:, None, None]
    return p


def main(fast: bool = False):
    cfg, params = pretrained_teacher()
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                          global_batch=BATCH, seed=99)
    tokens = jnp.asarray(pipe.batch_at(0))
    base_loss, base_pred = jax.jit(lambda p: _eval(p, cfg, tokens))(params)
    rng = np.random.default_rng(0)
    H, L = cfg.n_heads, cfg.n_layers
    rows = []
    for n_drop in (1, 2, 4, 8):
        # --- heads ---
        dl, agree = [], []
        for trial in range(3):
            choices = rng.choice(L * H, size=min(n_drop * 2, L * H),
                                 replace=False)
            idxs = [(c // H, c % H) for c in choices[:n_drop * 2]]
            pp = drop_heads(params, cfg, idxs)
            loss, pred = _eval(pp, cfg, tokens)
            dl.append(float(loss - base_loss))
            agree.append(float(jnp.mean(pred == base_pred)))
        rows.append(("fig2_drop_heads", n_drop * 2, np.mean(dl),
                     np.mean(agree)))
        # --- mlp layers ---
        dl, agree = [], []
        for trial in range(3):
            layers = rng.choice(L, size=min(n_drop, L - 1), replace=False)
            pp = skip_mlp_layers(params, cfg, list(layers))
            loss, pred = _eval(pp, cfg, tokens)
            dl.append(float(loss - base_loss))
            agree.append(float(jnp.mean(pred == base_pred)))
        rows.append(("fig2_skip_mlp", int(min(n_drop, L - 1)), np.mean(dl),
                     np.mean(agree)))
    for name, n, dloss, agr in rows:
        emit(name, 0.0, f"n={n};dloss={dloss:.4f};top1match={agr:.3f}")
    # qualitative check (paper §3): dropping a few heads hurts less than
    # skipping the same number of MLP layers
    head_small = [r for r in rows if r[0] == "fig2_drop_heads"][0][2]
    mlp_large = [r for r in rows if r[0] == "fig2_skip_mlp"][-1][2]
    emit("fig2_redundancy_ordering", 0.0,
         f"heads_small_dloss={head_small:.4f};mlp_large_dloss={mlp_large:.4f}")


if __name__ == "__main__":
    main()
