"""Paper Fig. 4: comparison of distillation objectives (forward vs reverse
KL, top-K truncation, temperature scaling) for the language modality.

Student = frozen teacher + routers (+rank-4 LoRA); trained with each loss
variant for the same budget; reported metric = eval LM loss (paper's
expectation: forward KL on top-50 tokens converges best)."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import (distill_routers, emit, eval_lm_loss,
                               pretrained_teacher)
from repro.configs import ElasticConfig

VARIANTS = [
    ("fwd_kl_top50", dict(distill_loss="topk_kl", distill_topk=50)),
    ("rev_kl_top50", dict(distill_loss="topk_kl_rev", distill_topk=50)),
    ("fwd_kl_full", dict(distill_loss="fwd_kl")),
    ("rev_kl_full", dict(distill_loss="rev_kl")),
    ("fwd_kl_top50_T2", dict(distill_loss="topk_kl", distill_topk=50,
                             distill_temp=2.0)),
]


def main(steps: int = 50):
    cfg, params = pretrained_teacher()
    teacher_loss = eval_lm_loss(params, None, cfg, None, "base")
    emit("fig4_teacher", 0.0, f"lm_loss={teacher_loss:.4f}")
    base_e = ElasticConfig(
        mlp_token_capacity=0.7, mha_token_capacity=0.7,
        mha_head_topk=cfg.n_heads // 2, mlp_n_experts=8, mlp_expert_topk=5,
        lora_rank=4)
    results = {}
    for name, kw in VARIANTS:
        ecfg = dataclasses.replace(base_e, **kw)
        t0 = time.perf_counter()
        rp, m = distill_routers(params, cfg, ecfg, steps=steps)
        dt = (time.perf_counter() - t0) / steps * 1e6
        loss = eval_lm_loss(params, rp, cfg, ecfg, "train")
        results[name] = loss
        emit(f"fig4_{name}", dt,
             f"eval_lm_loss={loss:.4f};train_distill={m['distill']:.4f}")
    best = min(results, key=results.get)
    emit("fig4_best_variant", 0.0, f"{best}(paper_expects=fwd_kl_top50)")


if __name__ == "__main__":
    main()
