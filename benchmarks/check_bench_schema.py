"""Schema-drift gate for the checked-in benchmark trajectories.

`BENCH_serving.json` / `BENCH_ragged.json` are TRACKED: the committed rows
are the performance trajectory reviewers diff against. This gate keeps that
trajectory honest — CI runs the fresh `--smoke` bench to a scratch path and
fails if the checked-in file no longer speaks the same schema (a column was
added/renamed/dropped, or a value domain like the backend/mode axis grew
without the committed file being refreshed).

Checked:
  * both files are non-empty JSON lists of row objects;
  * the union of row keys matches exactly (missing AND stale columns fail);
  * categorical axes (`mode`, `backend`, `budget`) present in the fresh run
    are covered by the checked-in rows.

Usage: python benchmarks/check_bench_schema.py TRACKED.json FRESH.json
"""
from __future__ import annotations

import json
import sys


def _rows(path: str):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows \
            or not all(isinstance(r, dict) for r in rows):
        raise SystemExit(f"{path}: expected a non-empty JSON list of rows")
    return rows


def check(tracked_path: str, fresh_path: str) -> list:
    tracked, fresh = _rows(tracked_path), _rows(fresh_path)
    tkeys = set().union(*(r.keys() for r in tracked))
    fkeys = set().union(*(r.keys() for r in fresh))
    problems = []
    if fkeys - tkeys:
        problems.append(f"columns missing from {tracked_path}: "
                        f"{sorted(fkeys - tkeys)} — the bench grew a column;"
                        f" refresh the checked-in file")
    if tkeys - fkeys:
        problems.append(f"stale columns in {tracked_path}: "
                        f"{sorted(tkeys - fkeys)} — the bench no longer "
                        f"emits them")
    for col in ("mode", "backend", "budget"):
        fv = {r[col] for r in fresh if col in r}
        tv = {r[col] for r in tracked if col in r}
        if fv and not fv <= tv:
            problems.append(f"{col} values {sorted(fv - tv, key=str)} in the"
                            f" fresh run are absent from {tracked_path}")
    return problems


def main(argv):
    if len(argv) != 3:
        raise SystemExit(__doc__)
    problems = check(argv[1], argv[2])
    if problems:
        for p in problems:
            print(f"[bench-schema] FAIL: {p}")
        return 1
    print(f"[bench-schema] OK: {argv[1]} matches the fresh run's schema")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
