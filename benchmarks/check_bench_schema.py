"""Schema-drift gate for the checked-in benchmark trajectories.

`BENCH_serving.json` / `BENCH_ragged.json` / `BENCH_autoscale.json` are
TRACKED: the committed rows
are the performance trajectory reviewers diff against. This gate keeps that
trajectory honest — CI runs the fresh `--smoke` bench to a scratch path and
fails if the checked-in file no longer speaks the same schema (a column was
added/renamed/dropped, or a value domain like the backend/mode axis grew
without the committed file being refreshed).

Checked:
  * both files are non-empty JSON lists of row objects;
  * the union of row keys matches exactly (missing AND stale columns fail);
  * categorical axes (`mode`, `backend`, `budget`, `kv_dtype`, `policy`,
    `trace`) present in the fresh run are covered by the checked-in rows.

Findings are reported through ``repro.analysis``'s Finding/Report types, so
this gate's ``--json`` artifact diffs cleanly against the lint-graphs job's
(one schema for every static gate in CI).

Usage: python benchmarks/check_bench_schema.py TRACKED.json FRESH.json
       [--json OUT.json]
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis import Finding, Report  # noqa: E402

PASS_NAME = "bench_schema"


def _rows(path: str):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows \
            or not all(isinstance(r, dict) for r in rows):
        raise SystemExit(f"{path}: expected a non-empty JSON list of rows")
    return rows


def check(tracked_path: str, fresh_path: str) -> list:
    """-> list[Finding] (rule BENCH-SCHEMA-*) against the tracked file."""
    tracked, fresh = _rows(tracked_path), _rows(fresh_path)
    tkeys = set().union(*(r.keys() for r in tracked))
    fkeys = set().union(*(r.keys() for r in fresh))
    target = os.path.basename(tracked_path)
    finds = []
    if fkeys - tkeys:
        finds.append(Finding(
            "BENCH-SCHEMA-MISSING-COL", target,
            f"columns missing from the tracked file: "
            f"{sorted(fkeys - tkeys)} — the bench grew a column; refresh "
            f"the checked-in file"))
    if tkeys - fkeys:
        finds.append(Finding(
            "BENCH-SCHEMA-STALE-COL", target,
            f"stale columns in the tracked file: {sorted(tkeys - fkeys)} — "
            f"the bench no longer emits them"))
    for col in ("mode", "backend", "budget", "kv_dtype", "policy", "trace"):
        fv = {r[col] for r in fresh if col in r}
        tv = {r[col] for r in tracked if col in r}
        if fv and not fv <= tv:
            finds.append(Finding(
                "BENCH-SCHEMA-AXIS", target,
                f"{col} values {sorted(fv - tv, key=str)} in the fresh run "
                f"are absent from the tracked rows"))
    return finds


def main(argv):
    json_out = None
    if "--json" in argv:
        i = argv.index("--json")
        json_out = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    if len(argv) != 3:
        raise SystemExit(__doc__)
    report = Report(meta={"tracked": argv[1], "fresh": argv[2]})
    report.extend(PASS_NAME, check(argv[1], argv[2]))
    if json_out:
        with open(json_out, "w") as f:
            f.write(report.to_json())
    if not report.ok:
        for f in report.findings:
            print(f"[bench-schema] FAIL: {f}")
        return 1
    print(f"[bench-schema] OK: {argv[1]} matches the fresh run's schema")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
