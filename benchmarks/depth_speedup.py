"""Elastic depth (whole-layer skip) benchmark -> BENCH_depth.json.

Grid over (depth budget x token budget): lowers the toy-config train-mode
forward under the ragged capacity-bucket path with the DEPTH router live —
selected tokens gather through the whole block, unselected tokens ride the
residual untouched — and records per-step lowered FLOPs (XLA cost analysis),
the compiled step's ``bytes_read`` (``hloprof.bytes_moved``), and wall-clock
of the jitted forward. The dense baseline column is the rank-masked
reference at budget 1.0 (budget-independent full compute — the pre-depth
cost of every row).

CI regression fences (ref backend, seq 512 — the ISSUE acceptance gate):

  * FLOPs are monotone in the depth budget at fixed token budget, and the
    depth x token composition is multiplicative (composed cells sit below
    either single-knob cell);
  * depth 0.5 (token 1.0) lowers <= 0.6x the dense FLOPs AND runs
    < 0.85x the dense wall-clock — whole-layer savings must reach the
    clock, not just the cost model;
  * depth 1.0 (token 1.0) rides the IDENTITY graph: within 1.15x of the
    dense teacher forward (budget 1.0 stays the bit-exact teacher).

Timing methodology is ``ragged_speedup``'s: explicit warmup, every timed
iteration bracketed by block_until_ready, all cells sampled ROUND-ROBIN
(``common.timed_median_grid``) so machine noise hits each cell equally;
min-of-N is the robust cost estimate on shared CI hosts, median documents
typical latency; on a gate miss the grid re-times (compiles are cached)
and keeps each cell's best min — contention only ever adds time.

Usage:
    python benchmarks/depth_speedup.py [--smoke] [--out BENCH_depth.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")
from common import emit, timed_median_grid  # noqa: E402

from repro.configs.elasti_toy import toy_lm  # noqa: E402
from repro.core.policy import ElasticPolicy, ElasticSpec, ragged_bucket  # noqa: E402
from repro.kernels.ops import resolve_backend  # noqa: E402
from repro.launch.hloprof import bytes_moved, lowered_flops  # noqa: E402
from repro.models import forward, model_init, router_init  # noqa: E402

DEPTHS = (1.0, 0.75, 0.5)
TOKENS = (1.0, 0.5)


def build(seq: int, batch: int, vocab: int, d_model: int, n_layers: int):
    cfg = dataclasses.replace(
        toy_lm(n_layers=n_layers, d_model=d_model, vocab=vocab),
        dtype="float32")
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True,
                       depth_routed=True)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    rng = np.random.default_rng(0)
    tokens = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32))}
    return cfg, spec, params, rp, tokens


def _policy(depth: float, token: float) -> ElasticPolicy:
    pol = ElasticPolicy.uniform(token)
    return pol.replace(depth_capacity=depth)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timed iterations for CI (the seq-512 gates "
                         "still run — they ARE the acceptance criterion)")
    ap.add_argument("--out", default="BENCH_depth.json")
    ap.add_argument("--seq", type=int, default=512,
                    help="sequence length (the CI gates are specified at "
                         "512; below ~384 per-op XLA-CPU overheads drown "
                         "the layer compute and the clock gates get noisy)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--iters", type=int, default=None,
                    help="timed iterations (default 5 smoke / 7 full)")
    ap.add_argument("--attempts", type=int, default=4,
                    help="re-time passes on a wall-clock gate miss "
                         "(contention only inflates; best min kept)")
    args = ap.parse_args()
    iters = args.iters or (5 if args.smoke else 7)
    seq = args.seq
    cfg, spec, params, rp, batch = build(
        seq, args.batch, vocab=256, d_model=128, n_layers=4)
    dense = dataclasses.replace(spec, routing_impl="dense_mask")
    backend = resolve_backend(spec.kernel_backend)

    def make_fwd(sp):
        def f(rp, batch, policy, bucket=None):
            return forward(params, rp, batch, cfg, sp, mode="train",
                           policy=policy, bucket=bucket)[0]
        return f

    f_ragged = make_fwd(spec)
    f_dense = make_fwd(dense)
    jit_ragged = jax.jit(f_ragged, static_argnames=("bucket",))
    jit_dense = jax.jit(f_dense, static_argnames=("bucket",))

    # dense baseline: budget-independent full compute (one cell, sampled in
    # the same round-robin grid as every depth cell it gates against)
    pol_full = jax.tree.map(jnp.asarray, _policy(1.0, 1.0))
    fl_dense = lowered_flops(f_dense, rp, batch, pol_full,
                             static_argnames=("bucket",))

    cells = {"dense": lambda: jit_dense(rp, batch, pol_full)}
    meta = {}
    for d in DEPTHS:
        for tk in TOKENS:
            pol = jax.tree.map(jnp.asarray, _policy(d, tk))
            bkt = ragged_bucket(pol, seq, spec=spec)
            meta[(d, tk)] = (
                bkt,
                lowered_flops(f_ragged, rp, batch, pol, bucket=bkt,
                              static_argnames=("bucket",)),
                bytes_moved(jit_ragged.lower(
                    rp, batch, pol, bucket=bkt).compile().as_text()))
            cells[(d, tk)] = (
                lambda pol=pol, bkt=bkt: jit_ragged(rp, batch, pol,
                                                    bucket=bkt))

    def gates_pass(us):
        d_us = us["dense"][0]
        return (us[(1.0, 1.0)][0] <= 1.15 * d_us
                and us[(0.5, 1.0)][0] < 0.85 * d_us)

    us = timed_median_grid(cells, iters=iters)
    for _ in range(args.attempts - 1):
        # the retries only serve the ref-backend CI gates asserted below
        if backend != "ref" or gates_pass(us):
            break
        again = timed_median_grid(cells, iters=iters, warmup=1)
        us = {k: (min(us[k][0], again[k][0]), min(us[k][1], again[k][1]))
              for k in us}

    rows = []
    for d in DEPTHS:
        for tk in TOKENS:
            bkt, fl, br = meta[(d, tk)]
            rows.append({"depth_budget": d, "token_budget": tk,
                         "bucket": bkt, "seq": seq, "backend": backend,
                         "flops": fl, "flops_dense": fl_dense,
                         "bytes_read": br,
                         "us": us[(d, tk)][0],
                         "us_dense": us["dense"][0],
                         "us_med": us[(d, tk)][1],
                         "us_dense_med": us["dense"][1]})
            emit(f"depth_fwd_d{d:g}_t{tk:g}", us[(d, tk)][0],
                 f"{fl / 1e6:.1f}MF_vs_{fl_dense / 1e6:.1f}MF_dense")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)

    cell = {(r["depth_budget"], r["token_budget"]): r for r in rows}
    # ---- FLOP gates: monotone in depth, multiplicative composition ----
    for tk in TOKENS:
        fl = [cell[(d, tk)]["flops"] for d in DEPTHS]
        assert fl == sorted(fl, reverse=True), \
            f"depth FLOPs must decrease with the depth budget (token {tk}): {fl}"
    # composed cells drop strictly once the depth x token product crosses a
    # bucket boundary (FLOPs are proportional to the rounded-up bucket, so
    # same-bucket cells tie; 0.5 x 0.5 = 0.25 always lands a bucket lower)
    for d in DEPTHS[1:]:
        assert cell[(d, 0.5)]["flops"] < cell[(d, 1.0)]["flops"], \
            f"depth x token must compose: {d}"
    assert cell[(0.5, 0.5)]["flops"] < cell[(1.0, 0.5)]["flops"]
    half = cell[(0.5, 1.0)]
    ratio = half["flops"] / max(fl_dense, 1.0)
    assert ratio <= 0.6, \
        f"depth-0.5 FLOP ratio {ratio:.3f} > 0.6x dense (acceptance gate)"
    # ---- wall-clock gates (the FLOPs -> latency fence, ref backend) ----
    if backend == "ref":
        ident = cell[(1.0, 1.0)]
        assert ident["us"] <= 1.15 * ident["us_dense"], (
            f"identity path regressed: depth(1.0) {ident['us']:.0f}us > "
            f"1.15x dense {ident['us_dense']:.0f}us")
        assert half["us"] < 0.85 * half["us_dense"], (
            f"depth savings not reaching the clock: depth(0.5) "
            f"{half['us']:.0f}us >= 0.85x dense {half['us_dense']:.0f}us")
        detail = ", ".join(
            f"d{d:g}/t{tk:g}: {cell[(d, tk)]['us']:.0f}"
            for d in DEPTHS for tk in TOKENS)
        print("wall-clock by (depth, token) (us): " + detail)
    print(f"\nwrote {args.out}: depth-0.5 lowers {ratio:.2f}x the dense "
          f"FLOPs; depth(0.5) {half['us']:.0f}us vs dense "
          f"{half['us_dense']:.0f}us "
          f"({half['us'] / max(half['us_dense'], 1e-9):.2f}x) [{backend}]")


if __name__ == "__main__":
    main()
