"""Aggregate dry-run JSON records into the EXPERIMENTS.md SSRoofline table.

Reads experiments/dryrun/<mesh>/<arch>__<shape>__<variant>.json produced by
``python -m repro.launch.dryrun`` and prints a markdown table of the three
roofline terms per (arch x shape), the dominant term, MODEL_FLOPS/HLO_FLOPs
ratio, and the roofline fraction.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
       [--mesh pod16x16] [--variant baseline] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def load(dirname: str, mesh: str, variant: str, recompute: bool = False):
    recs = []
    for path in sorted(glob.glob(
            os.path.join(dirname, mesh, f"*__{variant}.json"))):
        r = json.load(open(path))
        if recompute and r.get("roofline"):
            # refresh analytic useful-FLOPs with the current model_flops
            # (e.g. after adding the quadratic attention term)
            from repro.configs import SHAPES, get_config
            from repro.launch.dryrun import PEAK_FLOPS, model_flops
            rf = r["roofline"]
            n_chips = 512 if "2x16" in mesh else 256
            mf = model_flops(get_config(r["arch"]), SHAPES[r["shape"]],
                             r["kind"])
            rf["model_flops_total"] = mf
            rf["model_flops_per_dev"] = mf / n_chips
            rf["useful_flop_ratio"] = (mf / n_chips) / max(
                rf["hlo_flops_per_dev"], 1.0)
            rf["roofline_fraction"] = min(1.0, (mf / n_chips / PEAK_FLOPS)
                / max(rf["t_compute_s"], rf["t_memory_s"],
                      rf["t_collective_s"], 1e-12))
        recs.append(r)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--recompute-useful", action="store_true",
                    help="recompute model_flops/useful ratio/fraction with "
                    "the current analytic formula")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.variant,
                recompute=args.recompute_useful)
    if not recs:
        raise SystemExit(f"no records in {args.dir}/{args.mesh}")

    if args.csv:
        print("arch,shape,status,t_compute_s,t_memory_s,t_collective_s,"
              "dominant,useful_flop_ratio,roofline_fraction,mem_gb_dev")
    else:
        print(f"### Roofline — mesh {args.mesh}, variant {args.variant}\n")
        print("| arch | shape | status | t_comp | t_mem | t_coll | dominant "
              "| useful FLOP ratio | roofline frac | GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_err = 0
    for r in recs:
        arch, shape, st = r["arch"], r["shape"], r["status"]
        if st == "skipped":
            n_skip += 1
            if not args.csv:
                print(f"| {arch} | {shape} | SKIP (full-attn @500k) "
                      f"| — | — | — | — | — | — | — |")
            continue
        if st != "ok":
            n_err += 1
            err = r.get("error", "?")[:60]
            print(f"| {arch} | {shape} | ERROR {err} | | | | | | | |"
                  if not args.csv else f"{arch},{shape},error,,,,,,,")
            continue
        n_ok += 1
        rf = r.get("roofline", {})
        mem = r.get("memory", {}).get("total_gb", 0.0)
        if not rf:
            if not args.csv:
                print(f"| {arch} | {shape} | ok (no roofline) | | | | | | "
                      f"| {mem:.2f} |")
            continue
        row = (arch, shape, "ok", rf["t_compute_s"], rf["t_memory_s"],
               rf["t_collective_s"], rf["dominant"],
               rf["useful_flop_ratio"], rf["roofline_fraction"], mem)
        if args.csv:
            print(",".join(str(x) for x in row))
        else:
            print(f"| {arch} | {shape} | ok | {_fmt_s(rf['t_compute_s'])} "
                  f"| {_fmt_s(rf['t_memory_s'])} "
                  f"| {_fmt_s(rf['t_collective_s'])} | **{rf['dominant']}** "
                  f"| {rf['useful_flop_ratio']:.2f} "
                  f"| {rf['roofline_fraction']:.3f} | {mem:.2f} |")
    if not args.csv:
        print(f"\nok={n_ok} skipped={n_skip} errors={n_err} "
              f"total={len(recs)}")


if __name__ == "__main__":
    main()
