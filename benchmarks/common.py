"""Shared benchmark harness: tiny-teacher pretraining (stands in for the
paper's downloaded checkpoints), router distillation, timing, CSV output.

Every bench prints `name,us_per_call,derived` rows (harness contract).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ElasticConfig, get_config
from repro.data import LMDataPipeline, procedural_images
from repro.models import forward, model_init, router_init
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.training import init_train_state, lm_loss, make_loss_fn, make_train_step

CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
CACHE_VERSION = 3   # bump when model/init code changes to invalidate pickles
SEQ, BATCH = 64, 8


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn, *args, iters: int = 3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters * 1e6


def timed_median_grid(cells, iters: int = 7, warmup: int = 2):
    """Paired wall-clock comparison of several thunks: every cell is warmed
    up (untimed), then the timed iterations run ROUND-ROBIN across cells —
    cell A's i-th sample and cell B's i-th sample are adjacent in time, so
    machine noise (CPU contention, frequency scaling) hits every cell with
    the same distribution instead of whichever happened to run last.
    ``cells``: {name: thunk}; returns {name: (min_us, median_us)} of
    per-iteration block_until_ready-bracketed timings, warmup excluded.
    On shared CI hosts the MIN is the robust cost estimate (external
    contention only ever adds time); the median documents typical latency."""
    for fn in cells.values():
        out = None
        for _ in range(max(1, warmup)):
            out = fn()
        jax.block_until_ready(out)
    times = {name: [] for name in cells}
    for _ in range(iters):
        for name, fn in cells.items():
            t0 = time.perf_counter()
            out = fn()
            jax.block_until_ready(out)
            times[name].append((time.perf_counter() - t0) * 1e6)
    return {name: (float(np.min(ts)), float(np.median(ts)))
            for name, ts in times.items()}


def toy_cfg(**kw):
    cfg = get_config("toy-lm")
    return dataclasses.replace(cfg, dtype="float32", **kw)


@functools.lru_cache(maxsize=4)
def pretrained_teacher(steps: int = 300, seed: int = 0, vocab: int = 512):
    """Train a small LM on the Zipf-Markov corpus until it clearly beats
    chance; cache to disk (teachers are reused across benches)."""
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE,
                        f"teacher_v{CACHE_VERSION}_{steps}_{seed}_{vocab}.pkl")
    cfg = toy_cfg(vocab_size=vocab)
    if os.path.exists(path):
        with open(path, "rb") as f:
            params = pickle.load(f)
        return cfg, jax.tree.map(jnp.asarray, params)
    key = jax.random.PRNGKey(seed)
    params = model_init(key, cfg, None)
    opt = adamw_init(params)
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                          global_batch=BATCH, seed=seed)

    @jax.jit
    def step(params, opt, tokens):
        def loss_fn(p):
            logits, _ = forward(p, None, {"tokens": tokens}, cfg, None,
                                mode="base")
            return lm_loss(logits, tokens)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params,
                                      lr=cosine_schedule(3e-3, steps))
        return params, opt, loss

    for i in range(steps):
        params, opt, loss = step(params, opt, jnp.asarray(pipe.batch_at(i)))
    with open(path, "wb") as f:
        pickle.dump(jax.device_get(params), f)
    return cfg, params


@functools.lru_cache(maxsize=2)
def pretrained_vit_teacher(steps: int = 300, seed: int = 0):
    """MAE-style pretrained toy ViT encoder (stands in for ViT-MAE-L):
    mask 25% of patch embeddings, train the encoder so masked positions
    reconstruct (cosine) their unmasked input projections. Router
    robustness (paper Fig. 8) is a property of STRUCTURED representations;
    a random encoder gives chance-level router overlap."""
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"vit_v{CACHE_VERSION}_{steps}_{seed}.pkl")
    cfg = dataclasses.replace(get_config("toy-vit"), dtype="float32")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, jax.tree.map(jnp.asarray, pickle.load(f))
    key = jax.random.PRNGKey(seed)
    params = model_init(key, cfg, None)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, emb, mask):
        def loss_fn(p):
            x0 = emb @ p["in_proj"]
            out, _ = forward(p, None, {"embeds": emb * (1 - mask)},
                             cfg, None, mode="base")
            num = jnp.sum(out * x0, -1)
            den = (jnp.linalg.norm(out, axis=-1)
                   * jnp.linalg.norm(x0, axis=-1) + 1e-6)
            return jnp.mean(mask[..., 0] * (1.0 - num / den))
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params,
                                      lr=cosine_schedule(3e-3, steps))
        return params, opt, loss

    for i in range(steps):
        emb, _ = procedural_images(BATCH, cfg.n_image_tokens,
                                   cfg.d_frontend, seed=i)
        mrng = np.random.default_rng(i)
        mask = (mrng.random((BATCH, cfg.n_image_tokens, 1)) < 0.25)
        params, opt, loss = step(params, opt, jnp.asarray(emb),
                                 jnp.asarray(mask, jnp.float32))
    with open(path, "wb") as f:
        pickle.dump(jax.device_get(params), f)
    return cfg, params


def eval_lm_loss(params, rparams, cfg, ecfg, mode: str, seed: int = 123,
                 batches: int = 4):
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                          global_batch=BATCH, seed=seed)

    @jax.jit
    def ev(rp, tokens):
        logits, _ = forward(params, rp, {"tokens": tokens}, cfg, ecfg,
                            mode=mode)
        return lm_loss(logits, tokens)

    losses = [float(ev(rparams, jnp.asarray(pipe.batch_at(1000 + i))))
              for i in range(batches)]
    return float(np.mean(losses))


def distill_routers(params, cfg, ecfg, steps: int = 60, lr: float = 3e-3,
                    seed: int = 7, data_seed: int = 0, policy=None):
    """Train ONLY the ElastiFormer routers by self-distillation.

    ``ecfg``: legacy ElasticConfig or new ElasticSpec; ``policy`` optionally
    sets the (traced) capacity budget for the run — an annealing schedule
    could hand a different policy per step on the same compiled step."""
    rp = router_init(jax.random.PRNGKey(seed), cfg, ecfg)
    state = init_train_state(rp)
    step_fn = jax.jit(make_train_step(cfg, ecfg, lr=cosine_schedule(lr, steps),
                                      chunked=True))
    pipe = LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                          global_batch=BATCH, seed=data_seed)
    m = {}
    for i in range(steps):
        batch = {"tokens": jnp.asarray(pipe.batch_at(i))}
        state, m = (step_fn(state, params, batch) if policy is None
                    else step_fn(state, params, batch, policy))
    return state.router_params, {k: float(v) for k, v in m.items()}
