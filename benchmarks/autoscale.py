"""Autoscale bench: the SLO controller vs fixed budgets under overload.

Replays the same arrival schedule (bursty: 4x rate spike in the middle of
the trace; full mode adds a diurnal sinusoid) and the same heavy-tailed,
mixed-tenant request list through four policies on identical model state:

  * controller — ``SLOController`` closes the loop on the elastic budget:
    degrade admissions, then in-flight rows (``ElasticPolicy.set_row``,
    zero recompiles), then shed, with hysteretic restore after the burst.
  * fixed-1.0 / fixed-0.5 / fixed-0.25 — the open-loop baselines: every
    request pinned to one budget for the whole trace.

All rates and the SLO target are derived from calibrated service rates
(drained on the actual request mix), so the bench is machine-speed
invariant: base load is 45% of the measured full-budget service rate and
the burst runs AT the measured FLOOR-budget service rate — roughly 2x
what budget 1.0 can drain, while the degraded engine serves it at line
rate.

The headline is the goodput-vs-attainment trade: ``goodput_tok_s`` weights
each SLO-met token by the budget it was served at, so fixed-0.25 cannot
win by serving everything cheap, and fixed-1.0 cannot win by serving rich
tokens that miss their SLO. Gates (enforced on the bursty trace):

  G1 controller p95 TTFT <= SLO            G2 fixed-1.0 p95 TTFT > SLO
  G3 controller goodput >= 1.3x best fixed baseline at comparable
     attainment (within 0.02)              G4 queue drains, and the
     controller's backlog peak < fixed-1.0's (no unbounded growth)
  G5 compile_counts == {prefill: 1, decode: 1} through every degradation
     stage (the one-compile contract survives the controller)

Emits ``BENCH_autoscale.json`` rows {policy, trace, slo_ms, attainment,
goodput_tok_s, tok_s, ttft_p95_ms, ...} plus harness `name,us_per_call,
derived` lines (us_per_call = microseconds per generated token).

Run: PYTHONPATH=src python benchmarks/autoscale.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import emit, toy_cfg
from benchmarks.workloads import (arrival_times, bursty_times, make_requests,
                                  replay, summarize)
from repro.configs import ElasticConfig
from repro.models import model_init, router_init
from repro.runtime import SLOController, SLOTarget
from repro.training import GenRequest, ServingEngine

# dense MLP: the paged layout excludes moefied experts (chunked prefill)
ELASTIC = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                        mha_head_topk=2, lora_rank=1)
FLOOR = 0.25
BATCH = 8
FLOP = 2.0     # per-replica step budget: 2 full-budget slots, 8 at floor


def build_engine(state, max_seq, controller=None):
    params, rp, cfg = state
    return ServingEngine(params, rp, cfg, ELASTIC, mode="infer",
                         batch_size=BATCH, max_seq=max_seq,
                         kv_layout="paged", page_size=16,
                         step_flop_budget=FLOP, controller=controller)


def warm(eng, cfg):
    """Compile prefill + decode graphs outside any timed window."""
    hs = [eng.submit(GenRequest(
        np.arange(12, dtype=np.int32) % cfg.vocab_size, 4, seed=990 + i))
        for i in range(2)]
    while not all(h.done for h in hs):
        eng.step()


def _drain_rate(eng, reqs):
    hs = [eng.submit(r) for r in reqs]
    t0 = time.perf_counter()
    while not all(h.done for h in hs):
        eng.step()
    return len(reqs) / (time.perf_counter() - t0)


def calibrate(state, max_seq, reqs):
    """(steady decode step seconds, req/s at budget 1.0, req/s at the
    floor budget). Service rates are measured by draining saturated
    batches of the ACTUAL request mix, so chunked-prefill cost (admission
    streams every chunk inline — a budget-independent ceiling) and
    per-step host overhead are folded in; the analytic concurrency-times-
    tokens-per-step estimate misses both and overstates floor headroom."""
    eng = build_engine(state, max_seq)
    cfg = state[2]
    warm(eng, cfg)
    floor_reqs = [dataclasses.replace(r, budget=FLOOR, seed=r.seed + 500)
                  for r in reqs]
    # best-of-3: a transient background load during ONE measurement must
    # not soften the derived burst pressure / SLO for the whole bench —
    # take the fastest step and the highest service rate observed
    step_s, svc1, svc_floor = float("inf"), 0.0, 0.0
    for _ in range(3):
        hs = [eng.submit(GenRequest(
            np.arange(16, dtype=np.int32) % cfg.vocab_size, 8,
            seed=900 + i)) for i in range(BATCH)]
        t0 = time.perf_counter()
        steps = 0
        while not all(h.done for h in hs):
            eng.step()
            steps += 1
        step_s = min(step_s,
                     (time.perf_counter() - t0) / max(steps, 1))
        svc1 = max(svc1, _drain_rate(eng, reqs))
        svc_floor = max(svc_floor, _drain_rate(eng, floor_reqs))
    return step_s, svc1, svc_floor


def run_policy(state, max_seq, reqs, arrive, targets, step_s, slo_ms,
               controlled):
    ctrl = None
    if controlled:
        # step_down 0.75: admission hits the floor ONE eval after the burst
        # lands (the onset transient is the whole G1 risk); patience 2 +
        # 3x-SLO sample TTL keep restore out of the burst (mid-burst
        # restore thrash re-builds the backlog at budget 1.0)
        ctrl = SLOController(
            targets=targets, floor=FLOOR, step_down=0.75, step_up=0.5,
            window=32, min_samples=3,
            eval_interval_s=max(0.03, 2.0 * step_s),
            hysteresis=0.7, patience=2, queue_factor=1.0,
            escalate_after=10 ** 6,   # single-host paged: no remesh stage
            sample_ttl_s=max(0.5, 3.0 * slo_ms / 1e3))
    # warm BEFORE attaching the controller: compile time must be neither
    # inside the timed trace nor a (huge) TTFT sample in its windows
    eng = build_engine(state, max_seq)
    warm(eng, state[2])
    eng.controller = ctrl
    handles, elapsed, info = replay(eng, reqs, arrive)
    s = summarize(handles, elapsed, targets)
    s["queue_peak"] = info["queue_peak"]
    s["compiles"] = eng.compile_counts()
    s["drained"] = (eng.scheduler.pending == 0 and not eng.has_work)
    if ctrl is not None:
        s["controller"] = ctrl.summary()
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, bursty trace only)")
    ap.add_argument("--out", default="BENCH_autoscale.json")
    args = ap.parse_args()

    if args.smoke:
        # n sets the burst LENGTH (burst_frac * n requests at the burst
        # rate): long enough that fixed-1.0's backlog decisively blows the
        # SLO — a short spike sits on the G2 knife-edge
        n, traces = 140, ("bursty",)
        prompt_hi, new_lo, new_hi, max_seq = 16, 4, 16, 48
    else:
        n, traces = 160, ("bursty", "diurnal")
        prompt_hi, new_lo, new_hi, max_seq = 32, 4, 32, 80

    # 2x the stock toy width: step time must be compute-dominated, not
    # host-overhead-dominated, or calibration drifts vs the timed trace
    cfg = toy_cfg(d_model=256)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ELASTIC)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ELASTIC)
    state = (params, rp, cfg)

    cal_reqs = make_requests(24, cfg.vocab_size, prompt_hi=prompt_hi,
                             max_new_lo=new_lo, max_new_hi=new_hi, seed=5)
    step_s, svc1, svc_floor = calibrate(state, max_seq, cal_reqs)
    print(f"calibrated: decode step {step_s * 1e3:.2f} ms, service "
          f"{svc1:.1f} req/s @1.0, {svc_floor:.1f} req/s @floor")

    # SLO target: a healthy request's TTFT is a few step times of queue
    # wait + chunked prefill; 40x steady step is met with margin at base
    # load (and by the degraded engine's onset transient) and blown once
    # a fixed-1.0 burst backlog builds.
    slo_ms = max(50.0, 40.0 * step_s * 1e3)
    targets = {
        "interactive": SLOTarget(p95_ttft_ms=slo_ms, shed_order=0),
        "batch": SLOTarget(p95_ttft_ms=4.0 * slo_ms, shed_order=1,
                           deadline_ms=40.0 * slo_ms),
        "default": SLOTarget(p95_ttft_ms=slo_ms),
    }
    mix = {"interactive": 0.7, "batch": 0.3}

    # base load = 45% of measured full-budget capacity; the burst runs
    # AT the measured FLOOR capacity — fixed-1.0's backlog grows at
    # roughly half the burst rate (decisive SLO blowout), while the
    # degraded engine serves at line rate and sheds only the onset
    # transient past its keep depth
    base_rate = 0.45 * svc1
    burst_rate = 0.95 * svc_floor
    burst_factor = max(2.0, burst_rate / base_rate)
    print(f"base rate {base_rate:.1f} req/s, burst {burst_rate:.1f} req/s "
          f"({burst_rate / svc1:.1f}x capacity@1.0); "
          f"SLO p95 TTFT {slo_ms:.0f} ms")

    policies = [("controller", None), ("fixed-1.0", 1.0),
                ("fixed-0.5", 0.5), ("fixed-0.25", 0.25)]
    rows, failures = [], []
    for trace in traces:
        if trace == "bursty":
            rate = base_rate
            arrive = bursty_times(np.random.default_rng(3), rate, n,
                                  burst_factor=burst_factor,
                                  burst_frac=0.30)
        else:
            # diurnal swings +-80% around a hotter base: peaks overload
            # budget 1.0, troughs sit under hysteresis so restore fires
            rate = 0.8 * svc1
            arrive = arrival_times(trace, rate, n, seed=3)
        by_policy = {}
        for name, budget in policies:
            reqs = make_requests(n, cfg.vocab_size, prompt_hi=prompt_hi,
                                 max_new_lo=new_lo, max_new_hi=new_hi,
                                 class_mix=mix, budget=budget, seed=11)
            s = run_policy(state, max_seq, reqs, arrive, targets, step_s,
                           slo_ms, controlled=budget is None)
            by_policy[name] = s
            ctrl_sum = s.get("controller")
            rows.append({
                "policy": name, "trace": trace, "slo_ms": round(slo_ms, 2),
                "arrival_rate": round(rate, 2),
                "attainment": round(s["attainment"], 4),
                "goodput_tok_s": round(s["goodput_tok_s"], 2),
                "tok_s": round(s["tok_s"], 2),
                "ttft_p95_ms": s["ttft_p95_ms"], "p95_ms": s["p95_ms"],
                "served": s["served"], "shed": s["shed"],
                "expired": s["expired"], "queue_peak": s["queue_peak"],
                "elapsed_s": round(s["elapsed_s"], 3),
                "admission_budget": (ctrl_sum or {}).get("admission_budget"),
                "inflight_budget": (ctrl_sum or {}).get("inflight_budget"),
            })
            emit(f"autoscale_{trace}_{name}",
                 s["elapsed_s"] / max(s["n_tokens"], 1) * 1e6,
                 f"{s['goodput_tok_s']:.1f}good/s@{s['attainment']:.2f}")
            if s["compiles"] != {"prefill": 1, "decode": 1}:
                failures.append(f"G5 {trace}/{name}: compiles "
                                f"{s['compiles']} != 1/1")
            if not s["drained"]:
                failures.append(f"G4 {trace}/{name}: queue did not drain")

        ctrl, fix1 = by_policy["controller"], by_policy["fixed-1.0"]
        if trace == "bursty":
            if not ctrl["ttft_p95_ms"] <= slo_ms:
                failures.append(f"G1: controller p95 TTFT "
                                f"{ctrl['ttft_p95_ms']:.0f} ms > SLO "
                                f"{slo_ms:.0f} ms")
            if not fix1["ttft_p95_ms"] > slo_ms:
                failures.append(f"G2: fixed-1.0 p95 TTFT "
                                f"{fix1['ttft_p95_ms']:.0f} ms met the SLO "
                                f"— burst too weak to discriminate")
            rivals = [(nm, by_policy[nm]) for nm, b in policies
                      if b is not None
                      and by_policy[nm]["attainment"]
                      >= ctrl["attainment"] - 0.02]
            if rivals:
                best_nm, best = max(rivals,
                                    key=lambda kv: kv[1]["goodput_tok_s"])
                if ctrl["goodput_tok_s"] < 1.3 * best["goodput_tok_s"]:
                    failures.append(
                        f"G3: controller goodput "
                        f"{ctrl['goodput_tok_s']:.1f} < 1.3x {best_nm}'s "
                        f"{best['goodput_tok_s']:.1f} at comparable "
                        f"attainment")
            else:
                print("G3: no fixed baseline reaches the controller's "
                      "attainment — controller dominates outright")
            if not ctrl["queue_peak"] < fix1["queue_peak"]:
                failures.append(
                    f"G4: controller backlog peak {ctrl['queue_peak']} !< "
                    f"fixed-1.0's {fix1['queue_peak']}")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"\nwrote {args.out} ({len(rows)} rows)")
    for r in rows:
        print(f"  {r['trace']:8s} {r['policy']:11s} "
              f"attain={r['attainment']:.2f} "
              f"goodput={r['goodput_tok_s']:7.1f} tok/s "
              f"ttft_p95={r['ttft_p95_ms']:8.1f} ms "
              f"shed={r['shed']:2d} queue_peak={r['queue_peak']}")
    if failures:
        for msg in failures:
            print(f"[autoscale] GATE FAIL: {msg}")
        sys.exit(1)
    print("[autoscale] all gates passed: controller-on dominates "
          "fixed budgets under overload")


if __name__ == "__main__":
    main()
