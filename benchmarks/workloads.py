"""Trace-driven serving workloads: arrival processes, request mixes, replay.

The autoscale benchmark (``benchmarks/autoscale.py``), the serving CLI
(``--trace`` on ``repro.launch.serve``), and the fault-drill tests all
drive the engine through this one harness:

* **Arrival processes** — homogeneous Poisson, bursty (a mid-run rate
  spike: the overload the SLO controller exists for), and diurnal
  (sinusoidal rate via Poisson thinning — the slow load swing that
  exercises hysteretic restore).
* **Request mixes** — heavy-tailed (lognormal) prompt/output lengths and
  mixed tenants with per-tenant SLO classes (``GenRequest.slo_class``).
* **replay()** — the open-loop driver: submits on the arrival schedule,
  steps the engine, and survives faults mid-trace — replica failure via
  ``FailureInjector`` -> drain + re-mesh onto a fallback shape, straggler
  injection against the ``StragglerWatchdog``, and controller-saturation
  escalation (``maybe_escalate``).
* **summarize()** — per-class latency percentiles, SLO attainment
  (a served request meets SLO when its own TTFT is within its class
  target; shed/expired requests are misses), and **goodput**: SLO-met
  tokens/sec weighted by the budget they were served at, so a
  budget-0.25 token counts as a quarter of a full-compute token — the
  currency the goodput-vs-attainment curve trades in.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# ----------------------------- arrival processes ------------------------------

def poisson_times(rng: np.random.Generator, rate: float,
                  n: int) -> np.ndarray:
    """Homogeneous Poisson arrivals: n cumulative times at ``rate`` req/s."""
    return np.cumsum(rng.exponential(1.0 / rate, n))


def piecewise_poisson(rng: np.random.Generator,
                      segments: Sequence[Tuple[float, int]]) -> np.ndarray:
    """Concatenated Poisson segments: [(rate, n), ...] -> sorted times."""
    out, t = [], 0.0
    for rate, n in segments:
        gaps = rng.exponential(1.0 / rate, n)
        ts = t + np.cumsum(gaps)
        out.append(ts)
        if n:
            t = float(ts[-1])
    return np.concatenate(out) if out else np.zeros(0)


def bursty_times(rng: np.random.Generator, rate: float, n: int,
                 burst_factor: float = 4.0,
                 burst_frac: float = 0.4) -> np.ndarray:
    """Pre / burst / post: the middle ``burst_frac`` of requests arrive at
    ``burst_factor`` x the base rate — the overload transient."""
    n_burst = int(round(n * burst_frac))
    n_pre = (n - n_burst) // 2
    n_post = n - n_burst - n_pre
    return piecewise_poisson(rng, [(rate, n_pre),
                                   (rate * burst_factor, n_burst),
                                   (rate, n_post)])


def diurnal_times(rng: np.random.Generator, rate: float, n: int,
                  period_s: Optional[float] = None,
                  swing: float = 0.8) -> np.ndarray:
    """Sinusoidal-rate Poisson via thinning: rate(t) = rate * (1 + swing *
    sin(2 pi t / period)). Default period puts ~2 cycles in the run."""
    if period_s is None:
        period_s = max(1e-6, n / (2.0 * rate))
    rmax = rate * (1.0 + swing)
    out, t = [], 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / rmax))
        lam = rate * (1.0 + swing * math.sin(2 * math.pi * t / period_s))
        if rng.uniform() * rmax <= lam:
            out.append(t)
    return np.asarray(out)


def arrival_times(kind: str, rate: float, n: int,
                  seed: int = 0) -> np.ndarray:
    """Dispatch by trace kind: poisson | bursty | diurnal."""
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        return poisson_times(rng, rate, n)
    if kind == "bursty":
        return bursty_times(rng, rate, n)
    if kind == "diurnal":
        return diurnal_times(rng, rate, n)
    raise ValueError(f"unknown trace kind {kind!r}")


# ------------------------------- request mixes --------------------------------

def heavy_tailed_lengths(rng: np.random.Generator, n: int, lo: int, hi: int,
                         median: Optional[float] = None,
                         sigma: float = 0.6) -> np.ndarray:
    """Lognormal lengths clipped to [lo, hi] — most requests short, a heavy
    tail of long ones (the production prompt/output length shape)."""
    if median is None:
        median = math.sqrt(lo * hi)
    x = rng.lognormal(math.log(median), sigma, n)
    return np.clip(np.round(x), lo, hi).astype(int)


def make_requests(n: int, vocab: int, *,
                  prompt_lo: int = 4, prompt_hi: int = 64,
                  max_new_lo: int = 4, max_new_hi: int = 32,
                  class_mix: Optional[Dict[str, float]] = None,
                  budget: Optional[float] = None,
                  seed: int = 0) -> list:
    """Build n GenRequests with heavy-tailed prompt/output lengths and a
    weighted tenant-class mix (``class_mix`` name -> weight)."""
    from repro.training import GenRequest
    rng = np.random.default_rng(seed)
    plens = heavy_tailed_lengths(rng, n, prompt_lo, prompt_hi)
    nnews = heavy_tailed_lengths(rng, n, max_new_lo, max_new_hi)
    if class_mix:
        names = sorted(class_mix)
        w = np.asarray([class_mix[k] for k in names], float)
        classes = rng.choice(names, n, p=w / w.sum())
    else:
        classes = ["default"] * n
    return [GenRequest(rng.integers(0, vocab, int(plens[i]), dtype=np.int32),
                       int(nnews[i]), budget=budget, seed=i,
                       slo_class=str(classes[i]))
            for i in range(n)]


# ---------------------------------- replay ------------------------------------

def replay(engine, reqs: list, arrive: np.ndarray, *,
           fallback_shapes: Sequence[tuple] = (),
           injector=None, watchdog=None,
           straggle_at: Sequence[int] = (), straggle_s: float = 0.0):
    """Open-loop trace replay with fault drills: submit each request at its
    arrival time (handles' ``t_submit`` pinned to the schedule), step the
    engine continuously, and keep serving through injected faults —
    ``SimulatedFailure`` drains + re-meshes onto the next fallback shape
    (zero lost in-flight requests: their state is the slot caches, which
    ``reshard`` moves), stragglers (``straggle_at`` steps sleep an extra
    ``straggle_s``) feed the watchdog, and controller saturation escalates
    through the SAME fallback-shape list. Returns (handles, elapsed,
    info) — info carries steps/restarts/escalations/queue_peak."""
    from repro.runtime.fault_tolerance import (SimulatedFailure,
                                               maybe_escalate,
                                               remesh_fallback)
    shapes = list(fallback_shapes)
    handles: List[object] = [None] * len(reqs)
    i = 0
    steps = restarts = escalations = 0
    queue_peak = 0
    straggle_at = set(straggle_at)
    t0 = time.perf_counter()
    while i < len(reqs) or engine.has_work:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrive[i] <= now:
            handles[i] = engine.submit(reqs[i])
            handles[i].t_submit = t0 + arrive[i]
            i += 1
        if maybe_escalate(engine, shapes):
            escalations += 1
        try:
            if injector is not None:
                injector.maybe_fail(steps)
            ts = time.perf_counter()
            if steps in straggle_at and straggle_s > 0:
                time.sleep(straggle_s)
            n = engine.step()
            if watchdog is not None:
                watchdog.observe(steps, time.perf_counter() - ts)
            steps += 1
        except SimulatedFailure:
            restarts += 1
            remesh_fallback(engine, shapes)
            n = 1
        queue_peak = max(queue_peak, engine.scheduler.pending)
        if n == 0 and i < len(reqs):
            wait = arrive[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 0.05))
    return handles, time.perf_counter() - t0, {
        "steps": steps, "restarts": restarts,
        "escalations": escalations, "queue_peak": queue_peak}


# --------------------------------- metrics ------------------------------------

def summarize(handles: list, elapsed: float,
              targets: Optional[dict] = None) -> dict:
    """Trace-level serving metrics. A served request MEETS its SLO when
    its own TTFT is within its class's p95 target (per-request
    attainment); shed (``rejected``) and expired (``deadline_exceeded``)
    requests are attainment misses by definition. ``goodput_tok_s`` is
    SLO-met tokens/sec weighted by the budget each was served at
    (``RequestHandle.budget_served``) — degraded tokens count fractionally,
    so a controller cannot win the curve by degrading everything to the
    floor and calling it throughput."""
    from repro.launch.serve import latency_stats
    hs = [h for h in handles if h is not None]
    served = [h for h in hs if h.status == "done"]
    shed = sum(h.finish_reason == "rejected" for h in hs)
    expired = sum(h.finish_reason == "deadline_exceeded" for h in hs)
    n_tok = sum(len(h.output) for h in served)

    def _target_ms(h) -> float:
        if not targets:
            return math.inf
        tgt = targets.get(h.tenant) or targets.get("default")
        return tgt.p95_ttft_ms if tgt is not None else math.inf

    met = [h for h in served
           if h.ttft is not None and h.ttft * 1e3 <= _target_ms(h)]
    goodput = sum(len(h.output) * float(getattr(h, "budget_served", 1.0))
                  for h in met)
    out = {
        "n": len(hs), "served": len(served), "shed": int(shed),
        "expired": int(expired), "n_tokens": int(n_tok),
        "elapsed_s": float(elapsed),
        "tok_s": n_tok / elapsed if elapsed > 0 else 0.0,
        "attainment": len(met) / len(hs) if hs else 0.0,
        "goodput_tok_s": goodput / elapsed if elapsed > 0 else 0.0,
    }
    out.update(latency_stats(served))
    classes = sorted({h.tenant for h in served})
    if len(classes) > 1:
        out["per_class"] = {
            c: latency_stats([h for h in served if h.tenant == c])
            for c in classes}
    return out
