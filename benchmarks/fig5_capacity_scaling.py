"""Paper Fig. 5: scaling of each Elasti-LLM routing scheme vs capacity.

Four independent ablations on the frozen teacher (each router type alone):
  mha_tokens   — input subset selection around attention (paper: WORST
                 without LoRA; context-free routing hurts MHA)
  mlp_tokens   — input subset selection around the MLP
  heads        — parameter subset selection over attention heads
  experts      — parameter subset selection over the moefied MLP
Metric: eval LM loss vs teacher at each capacity level."""
from __future__ import annotations

import time

from benchmarks.common import (distill_routers, emit, eval_lm_loss,
                               pretrained_teacher)
from repro.configs import ElasticConfig


def _ecfg(kind: str, cap: float, n_heads: int, m_exp: int = 8):
    base = dict(mlp_token_capacity=None, mha_token_capacity=None,
                mha_head_topk=None, mlp_n_experts=None, mlp_expert_topk=None,
                lora_rank=0)
    if kind == "mha_tokens":
        base["mha_token_capacity"] = cap
    elif kind == "mlp_tokens":
        base["mlp_token_capacity"] = cap
    elif kind == "heads":
        base["mha_head_topk"] = max(1, round(cap * n_heads))
    elif kind == "experts":
        base["mlp_n_experts"] = m_exp
        base["mlp_expert_topk"] = max(1, round(cap * m_exp))
    return ElasticConfig(**base)


def main(steps: int = 40):
    cfg, params = pretrained_teacher()
    teacher = eval_lm_loss(params, None, cfg, None, "base")
    emit("fig5_teacher", 0.0, f"lm_loss={teacher:.4f}")
    summary = {}
    for kind in ("mha_tokens", "mlp_tokens", "heads", "experts"):
        for cap in (0.25, 0.5, 0.75, 1.0):
            ecfg = _ecfg(kind, cap, cfg.n_heads)
            t0 = time.perf_counter()
            rp, _ = distill_routers(params, cfg, ecfg, steps=steps)
            dt = (time.perf_counter() - t0) / steps * 1e6
            loss = eval_lm_loss(params, rp, cfg, ecfg, "train")
            summary[(kind, cap)] = loss
            emit(f"fig5_{kind}_c{cap}", dt,
                 f"eval_lm_loss={loss:.4f};gap={loss - teacher:+.4f}")
    # paper's qualitative claim: at matched 0.5 capacity, token routing hurts
    # MHA more than MLP
    emit("fig5_mha_vs_mlp_tokens_at_0.5", 0.0,
         f"mha={summary[('mha_tokens', 0.5)]:.4f};"
         f"mlp={summary[('mlp_tokens', 0.5)]:.4f}")


if __name__ == "__main__":
    main()
