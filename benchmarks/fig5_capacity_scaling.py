"""Paper Fig. 5: scaling of each Elasti-LLM routing scheme vs capacity.

Four independent ablations on the frozen teacher (each router type alone):
  mha_tokens   — input subset selection around attention (paper: WORST
                 without LoRA; context-free routing hurts MHA)
  mlp_tokens   — input subset selection around the MLP
  heads        — parameter subset selection over attention heads
  experts      — parameter subset selection over the moefied MLP
Metric: eval LM loss vs teacher at each capacity level.

The sweep exercises the spec/policy split: per router kind, ONE jitted
train step and ONE jitted eval serve every capacity — the capacity is a
traced ``ElasticPolicy`` argument, so the 4-point sweep compiles exactly
once per kind (asserted via the jit cache)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import BATCH, SEQ, emit, pretrained_teacher
from repro.core.policy import ElasticPolicy, ElasticSpec
from repro.data import LMDataPipeline
from repro.models import forward
from repro.optim import cosine_schedule
from repro.training import init_train_state, lm_loss, make_train_step
from repro.models import router_init

CAPACITIES = (0.25, 0.5, 0.75, 1.0)
M_EXPERTS = 8


def _spec(kind: str) -> ElasticSpec:
    base = dict(mlp_token_routed=False, mha_token_routed=False,
                mha_head_routed=False, mlp_n_experts=None,
                expert_routed=False, lora_rank=0)
    if kind == "mha_tokens":
        base["mha_token_routed"] = True
    elif kind == "mlp_tokens":
        base["mlp_token_routed"] = True
    elif kind == "heads":
        base["mha_head_routed"] = True
    elif kind == "experts":
        base.update(mlp_n_experts=M_EXPERTS, expert_routed=True)
    return ElasticSpec(**base)


def _policy(cfg, cap: float) -> ElasticPolicy:
    # traced leaves: every capacity re-uses the same compiled graphs
    return ElasticPolicy.uniform(cap, n_heads=cfg.n_heads,
                                 n_experts=M_EXPERTS)


def main(steps: int = 40):
    cfg, params = pretrained_teacher()
    pipe = lambda seed: LMDataPipeline(vocab=cfg.vocab_size, seq_len=SEQ,
                                       global_batch=BATCH, seed=seed)

    @jax.jit
    def teacher_eval(tokens):
        logits, _ = forward(params, None, {"tokens": tokens}, cfg, None,
                            mode="base")
        return lm_loss(logits, tokens)

    ev = pipe(123)
    teacher = float(jnp.mean(jnp.stack(
        [teacher_eval(jnp.asarray(ev.batch_at(1000 + i))) for i in range(4)])))
    emit("fig5_teacher", 0.0, f"lm_loss={teacher:.4f}")

    summary = {}
    for kind in ("mha_tokens", "mlp_tokens", "heads", "experts"):
        spec = _spec(kind)
        step_fn = jax.jit(make_train_step(
            cfg, spec, lr=cosine_schedule(3e-3, steps), chunked=True))

        @jax.jit
        def eval_fn(rp, tokens, policy):
            logits, _ = forward(params, rp, {"tokens": tokens}, cfg, spec,
                                mode="train", policy=policy)
            return lm_loss(logits, tokens)

        for cap in CAPACITIES:
            policy = _policy(cfg, cap)
            state = init_train_state(
                router_init(jax.random.PRNGKey(7), cfg, spec))
            data = pipe(0)
            t0 = time.perf_counter()
            for i in range(steps):
                state, _ = step_fn(state, params,
                                   {"tokens": jnp.asarray(data.batch_at(i))},
                                   policy)
            dt = (time.perf_counter() - t0) / steps * 1e6
            losses = [eval_fn(state.router_params,
                              jnp.asarray(ev.batch_at(1000 + i)), policy)
                      for i in range(4)]
            loss = float(jnp.mean(jnp.stack(losses)))
            summary[(kind, cap)] = loss
            emit(f"fig5_{kind}_c{cap}", dt,
                 f"eval_lm_loss={loss:.4f};gap={loss - teacher:+.4f}")
        # the whole capacity sweep must ride ONE compiled train step and
        # ONE compiled eval — the point of the ElasticPolicy redesign
        n_train, n_eval = step_fn._cache_size(), eval_fn._cache_size()
        assert n_train == 1, f"{kind}: train step compiled {n_train}x"
        assert n_eval == 1, f"{kind}: eval compiled {n_eval}x"
        emit(f"fig5_{kind}_compiles", 0.0,
             f"train={n_train};eval={n_eval};capacities={len(CAPACITIES)}")
    # paper's qualitative claim: at matched 0.5 capacity, token routing hurts
    # MHA more than MLP
    emit("fig5_mha_vs_mlp_tokens_at_0.5", 0.0,
         f"mha={summary[('mha_tokens', 0.5)]:.4f};"
         f"mlp={summary[('mlp_tokens', 0.5)]:.4f}")


if __name__ == "__main__":
    main()
