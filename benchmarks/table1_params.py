"""Paper Table 1: number of trainable parameters introduced by ElastiFormer
routers as a fraction of the frozen base model, per (module x selection) and
per assigned architecture.

Router param formulas (paper Table 1): input selection = L x (D + 2) approx
(we count exactly what router_init allocates); parameter selection =
L x (D x M). Verifies the paper's headline ".00006%-0.3% additional
trainable parameters" on the production configs without allocating them
(eval_shape only)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import ASSIGNED, get_config, get_elastic
from repro.models import model_init, router_init


def _count(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def main():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        ecfg = get_elastic(arch, cfg)
        params = jax.eval_shape(
            lambda cfg=cfg, ecfg=ecfg: model_init(
                jax.random.PRNGKey(0), cfg, ecfg))
        rp = jax.eval_shape(
            lambda cfg=cfg, ecfg=ecfg: router_init(
                jax.random.PRNGKey(0), cfg, ecfg))
        n_base, n_router = _count(params), _count(rp)
        frac = 100.0 * n_router / max(n_base, 1)
        emit(f"table1_{arch}", 0.0,
             f"base={n_base};router={n_router};pct={frac:.5f}%;"
             f"within_paper_range={frac <= 0.3}")


if __name__ == "__main__":
    main()
