"""Ragged capacity-bucket execution benchmark -> BENCH_ragged.json.

For each budget in a sweep, lowers the toy-config train-mode forward under
(a) the ragged capacity-bucket path and (b) the dense rank-masked reference
path, and records per-step lowered FLOPs (XLA cost analysis — the number the
CI FLOP gate asserts on), the compiled ragged step's ``bytes_read``
(``hloprof.bytes_moved`` — the memory-bound cost FLOPs miss), plus
wall-clock of the jitted forward. Dense is the
pre-refactor behavior: every budget costs full-budget compute; ragged FLOPs
must track the budget — and, since the RoutingPlan/identity-path refactor,
so must WALL-CLOCK (the gates at the bottom are the CI regression fence):

  * budget 1.0 rides the identity graph — no partition/gather/scatter at
    all — so it must stay within 1.15x of the dense teacher forward;
  * budget 0.5 must be strictly faster than the dense budget-1.0 forward
    (FLOP savings that don't reach the clock are the bug this fence holds).

Timing methodology: explicit warmup excluded from the timed region, every
timed iteration bracketed by block_until_ready, each budget's ragged/dense
cells sampled ROUND-ROBIN so time-varying machine noise hits both equally
(``common.timed_median_grid`` — the pre-refactor sequential timing is how
a 0.53x-FLOP forward once "measured" slower than dense). Rows report the
min-of-N as ``us_*`` (the robust graph-cost estimate on shared CI hosts,
where contention only ever adds time) plus the median-of-N as
``us_*_med``, and carry the resolved kernel backend.

Usage:
    python benchmarks/ragged_speedup.py [--smoke] [--out BENCH_ragged.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")
from common import emit, timed_median_grid  # noqa: E402

from repro.configs.elasti_toy import toy_lm  # noqa: E402
from repro.core.policy import ElasticPolicy, ElasticSpec, ragged_bucket  # noqa: E402
from repro.kernels.ops import resolve_backend  # noqa: E402
from repro.launch.hloprof import bytes_moved, lowered_flops  # noqa: E402
from repro.models import forward, model_init, router_init  # noqa: E402

BUDGETS = (1.0, 0.75, 0.5, 0.25)


def build(seq: int, batch: int, vocab: int, d_model: int, n_layers: int):
    cfg = dataclasses.replace(
        toy_lm(n_layers=n_layers, d_model=d_model, vocab=vocab),
        dtype="float32")
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    rng = np.random.default_rng(0)
    tokens = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32))}
    return cfg, spec, params, rp, tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--out", default="BENCH_ragged.json")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--iters", type=int, default=7,
                    help="timed iterations (min + median reported)")
    ap.add_argument("--attempts", type=int, default=4,
                    help="re-time passes on a wall-clock gate miss "
                         "(contention only inflates; best min kept)")
    args = ap.parse_args()
    # smoke stays >= 384: below that the toy forward is dominated by
    # per-op XLA-CPU overheads (the ragged graph carries ~1.8x the op
    # count for its plan machinery) and fusion-shape luck, turning the
    # wall-clock gates into a lottery; from ~384 compute dominates and
    # the ratios track FLOPs (0.46x at seq 512, budget 0.5)
    seq = args.seq or (384 if args.smoke else 512)
    cfg, spec, params, rp, batch = build(
        seq, args.batch, vocab=256, d_model=128, n_layers=4)
    dense = dataclasses.replace(spec, routing_impl="dense_mask")
    backend = resolve_backend(spec.kernel_backend)

    def make_fwd(sp):
        def f(rp, batch, policy, bucket=None):
            return forward(params, rp, batch, cfg, sp, mode="train",
                           policy=policy, bucket=bucket)[0]
        return f

    f_ragged = make_fwd(spec)
    f_dense = make_fwd(dense)
    jit_ragged = jax.jit(f_ragged, static_argnames=("bucket",))
    # one jit object PER dense cell: the dense graph is budget-independent,
    # and sharing one executable across all four budget cells would hand it
    # 4x the executions per round-robin pass — a systematic icache/branch
    # hotness edge over the per-bucket ragged executables it is compared to
    jit_dense_cells = {b: jax.jit(f_dense, static_argnames=("bucket",))
                       for b in BUDGETS}

    # ONE round-robin grid over every (impl, budget) cell: all the gate
    # comparisons below — including the cross-budget ragged(0.5) vs
    # dense(1.0) one — are between samples interleaved in time, so
    # drifting machine load cannot favor whichever cell ran in a quieter
    # minute
    cells, meta = {}, {}
    for b in BUDGETS:
        pol = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(b))
        bkt = ragged_bucket(pol, seq)
        meta[b] = (bkt,
                   lowered_flops(f_ragged, rp, batch, pol, bucket=bkt,
                                 static_argnames=("bucket",)),
                   lowered_flops(f_dense, rp, batch, pol,
                                 static_argnames=("bucket",)),
                   # bytes touched (reads + writes) by the compiled ragged
                   # step — the memory-bound cost FLOPs miss
                   bytes_moved(jit_ragged.lower(
                       rp, batch, pol, bucket=bkt).compile().as_text()))
        cells[("ragged", b)] = (
            lambda pol=pol, bkt=bkt: jit_ragged(rp, batch, pol, bucket=bkt))
        cells[("dense", b)] = (
            lambda pol=pol, b=b: jit_dense_cells[b](rp, batch, pol))

    def gates_pass(us):
        r10, d10 = us[("ragged", 1.0)][0], us[("dense", 1.0)][0]
        return (r10 <= 1.15 * d10
                and us[("ragged", 0.5)][0] < d10)

    # Shared CI hosts show +-20% minute-scale load swings even on min-of-N
    # (four IDENTICAL dense graphs can spread 49-66ms in one pass), and
    # contention only ever INFLATES a timing — so on a gate miss, re-time
    # (compiles are cached; this is seconds) and keep each cell's best
    # observed min. A genuinely regressed graph keeps failing; a noisy
    # window does not.
    us = timed_median_grid(cells, iters=args.iters)
    for _ in range(args.attempts - 1):
        # the retries only serve the ref-backend CI gates asserted below
        if backend != "ref" or gates_pass(us):
            break
        again = timed_median_grid(cells, iters=args.iters, warmup=1)
        us = {k: (min(us[k][0], again[k][0]), min(us[k][1], again[k][1]))
              for k in us}

    rows = []
    for b in BUDGETS:
        bkt, fl_r, fl_d, br = meta[b]
        rows.append({"budget": b, "bucket": bkt, "seq": seq,
                     "backend": backend,
                     "flops_ragged": fl_r, "flops_dense": fl_d,
                     "bytes_read": br,
                     "us_ragged": us[("ragged", b)][0],
                     "us_dense": us[("dense", b)][0],
                     "us_ragged_med": us[("ragged", b)][1],
                     "us_dense_med": us[("dense", b)][1]})
        emit(f"ragged_fwd_b{b:g}", us[("ragged", b)][0],
             f"{fl_r / 1e6:.1f}MF_vs_{fl_d / 1e6:.1f}MF_dense")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)

    base = rows[0]
    half = next(r for r in rows if r["budget"] == 0.5)
    ratio = half["flops_ragged"] / max(base["flops_ragged"], 1.0)
    flops = [r["flops_ragged"] for r in rows]
    assert flops == sorted(flops, reverse=True), \
        f"ragged FLOPs must decrease with budget: {flops}"
    assert ratio <= 0.7, f"budget-0.5 FLOP ratio {ratio:.3f} > 0.7"
    # ---- wall-clock regression gates (the FLOPs -> latency fence) ----
    # On the CPU ref backend these are deterministic enough for CI: the
    # identity graph must not cost more than the dense teacher, and a
    # half-budget ragged forward must beat the dense full-budget one.
    if backend == "ref":
        assert base["us_ragged"] <= 1.15 * base["us_dense"], (
            f"identity path regressed: ragged(1.0) {base['us_ragged']:.0f}us"
            f" > 1.15x dense(1.0) {base['us_dense']:.0f}us")
        assert half["us_ragged"] < base["us_dense"], (
            f"FLOP savings not reaching the clock: ragged(0.5) "
            f"{half['us_ragged']:.0f}us >= dense(1.0) "
            f"{base['us_dense']:.0f}us")
        detail = ", ".join(f"{r['budget']:g}: {r['us_ragged']:.0f}"
                           for r in rows)
        print("wall-clock by budget (us): " + detail)
    print(f"\nwrote {args.out}: budget-0.5 lowers {ratio:.2f}x the FLOPs of "
          f"budget-1.0 (dense reference is "
          f"{half['flops_dense'] / max(rows[0]['flops_dense'], 1.0):.2f}x); "
          f"ragged(0.5) {half['us_ragged']:.0f}us vs dense(1.0) "
          f"{base['us_dense']:.0f}us [{backend}]")


if __name__ == "__main__":
    main()
